"""Distributed-optimization example: int8 error-feedback gradient all-reduce.

Data-parallel training over a 4-device host mesh via shard_map, comparing
exact f32 gradient pmean vs the int8 error-feedback compressed_psum
(`repro.optim.grad_compress`). On the production multi-pod mesh this is the
pod-axis (DCN, 25 GB/s) collective — compressing it 4× moves the §Roofline
DCN term directly.

    PYTHONPATH=src python examples/grad_compression_dp.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.dist.sharding import materialize_params
from repro.launch.mesh import rules_for
from repro.models.api import build_model, synth_batch
from repro.models.layers import ModelContext
from repro.optim.grad_compress import tree_compressed_pmean


def main() -> int:
    mesh = jax.make_mesh((4, 1), ("data", "model"))
    cfg = get_smoke_config("smollm-135m")
    rules = rules_for(mesh)
    with mesh:
        ctx = ModelContext(cfg, mesh, rules)
        model = build_model(ctx)
        params0 = materialize_params(model.param_specs(), jax.random.PRNGKey(0))
        lr = 0.5  # plain SGD on the smoke model needs a big step to move

        def make_step(compress: bool):
            @functools.partial(
                jax.shard_map, mesh=mesh,
                in_specs=(P(), P("data"), P()),
                out_specs=(P(), P(), P()),
                check_vma=False,
            )
            def step(params, batch, errs):
                loss, grads = jax.value_and_grad(
                    lambda p: model.loss(p, batch)[0]
                )(params)
                if compress:
                    grads, errs = tree_compressed_pmean(grads, errs, "data")
                else:
                    grads = jax.tree.map(
                        lambda g: jax.lax.pmean(g, "data"), grads
                    )
                new_params = jax.tree.map(
                    lambda p, g: p - lr * g.astype(p.dtype), params, grads
                )
                loss = jax.lax.pmean(loss, "data")
                return new_params, loss, errs

            return jax.jit(step)

        results = {}
        for compress in (False, True):
            params = params0
            errs = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params0
            )
            step = make_step(compress)
            losses = []
            t0 = time.perf_counter()
            for i in range(30):
                batch = synth_batch(cfg, 8, 64, rng=i)
                params, loss, errs = step(params, batch, errs)
                losses.append(float(loss))
            dt = time.perf_counter() - t0
            results[compress] = (losses, dt)

        l_exact, _ = results[False]
        l_comp, _ = results[True]
        n_params = sum(x.size for x in jax.tree.leaves(params0))
        wire_exact = n_params * 4          # f32 grads
        wire_comp = n_params * 1 + 4       # int8 + one scale/tensor (≈)
        print("grad_compression_dp (4-way DP, smollm smoke):")
        print(f"  exact  loss: first {l_exact[0]:.3f} last {l_exact[-1]:.3f}")
        print(f"  int8EF loss: first {l_comp[0]:.3f} last {l_comp[-1]:.3f}")
        gap = abs(l_comp[-1] - l_exact[-1])
        print(f"  final-loss gap: {gap:.4f} (error feedback keeps parity)")
        print(f"  gradient wire bytes: {wire_exact/1e6:.1f} MB -> "
              f"{wire_comp/1e6:.1f} MB per step ({wire_exact/wire_comp:.1f}x)")
        assert gap < 0.15, "compressed training diverged from exact"
        return 0


if __name__ == "__main__":
    raise SystemExit(main())
