"""Quickstart: end-to-end training with the full production stack.

Trains a reduced SmolLM-family model for a few hundred steps on CPU using
every layer of the framework: ProxyStream input pipeline, fault-tolerant
Trainer (async proxy-backed checkpoints, straggler watchdog), AdamW, and the
same model/sharding definitions the 256-chip dry-run lowers.

    PYTHONPATH=src python examples/quickstart.py [--steps 200]
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_smoke_config
from repro.data.pipeline import StreamingDataLoader, SyntheticCorpus
from repro.launch.mesh import make_host_mesh, rules_for
from repro.models.layers import ModelContext
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_smoke_config("smollm-135m")
    mesh = make_host_mesh()
    ctx = ModelContext(cfg, mesh, rules_for(mesh))

    trainer = Trainer(
        ctx,
        TrainerConfig(
            opt=AdamWConfig(lr=3e-3, warmup_steps=20),
            ckpt_every=100,
            ckpt_dir="/tmp/quickstart-ckpt",
        ),
    )
    trainer.init_state()

    corpus = SyntheticCorpus(cfg, args.batch, args.seq)
    loader = StreamingDataLoader(corpus.next_batch, num_steps=args.steps + 4)

    t0 = time.perf_counter()
    history = trainer.train(loader, args.steps)
    wall = time.perf_counter() - t0
    loader.stop()

    losses = [h["loss"] for h in history]
    print(
        f"\nquickstart: {len(history)} steps in {wall:.1f}s "
        f"({args.batch * args.seq * len(history) / wall:.0f} tok/s)\n"
        f"loss: first {losses[0]:.3f} / min {min(losses):.3f} / last {losses[-1]:.3f}\n"
        f"pipeline store metrics: {loader.metrics()}"
    )
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
