"""1000-Genomes analogue: a staged scientific workflow pipelined with
ProxyFutures (paper §VI, Fig 8).

Five stages mirroring the paper's bioinformatics pipeline, with the same
data-flow topology (fan-out → merge → score → pairwise overlap → frequency),
each task having a startup-overhead phase that ProxyFutures overlap across
stage boundaries:

  stage 1  (fan-out): N "chromosome chunk" tasks extract variants
  stage 2  (merge):   combine per-individual results
  stage 3  (score):   select variants by phenotypic effect
  stage 4  (overlap): pairwise-overlap tasks (no intra-stage deps)
  stage 5  (freq):    final frequency computation

Baseline submits each stage when the previous stage's results arrive
(control-flow order); the ProxyFutures version submits ALL stages up front
with future-proxies as inputs (data-flow order).  The paper reports 36%
makespan reduction; the scaled-down topology here shows the same effect.

    PYTHONPATH=src python examples/pipelined_workflow.py
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core import Store
from repro.core.proxy import Proxy, extract

N_CHUNKS = 4
N_PAIRS = 4
OVERHEAD_S = 0.15  # library-import / model-load phase per task
COMPUTE_S = 0.10


def _work(inputs, out_future=None, seed=0):
    """Generic task: overhead → resolve inputs → compute → produce."""
    time.sleep(OVERHEAD_S)  # overlappable startup
    vals = [extract(x) if isinstance(x, Proxy) else x for x in inputs]
    time.sleep(COMPUTE_S)
    rng = np.random.default_rng(seed)
    out = np.concatenate([np.atleast_1d(v).ravel()[:64] for v in vals] or [rng.integers(0, 9, 64)])
    if out_future is not None:
        out_future.set_result(out)
    return out


def run_baseline(pool: ThreadPoolExecutor) -> float:
    t0 = time.perf_counter()
    raw = [np.arange(64) + i for i in range(N_CHUNKS)]
    # stage 1 — wait for all chunks, then 2, then 3 ... (control flow)
    s1 = [f.result() for f in [pool.submit(_work, [r], None, i) for i, r in enumerate(raw)]]
    s2 = pool.submit(_work, s1, None, 10).result()
    s3 = pool.submit(_work, [s2], None, 20).result()
    s4 = [f.result() for f in [pool.submit(_work, [s3], None, 30 + i) for i in range(N_PAIRS)]]
    pool.submit(_work, s4, None, 40).result()
    return time.perf_counter() - t0


def run_proxyfutures(pool: ThreadPoolExecutor, store: Store) -> float:
    t0 = time.perf_counter()
    raw = [np.arange(64) + i for i in range(N_CHUNKS)]
    f1 = [store.future() for _ in range(N_CHUNKS)]
    f2, f3 = store.future(), store.future()
    f4 = [store.future() for _ in range(N_PAIRS)]
    f5 = store.future()
    # submit EVERY stage immediately; inputs are future-proxies (data flow)
    handles = [pool.submit(_work, [r], f1[i], i) for i, r in enumerate(raw)]
    handles.append(pool.submit(_work, [f.proxy() for f in f1], f2, 10))
    handles.append(pool.submit(_work, [f2.proxy()], f3, 20))
    handles += [pool.submit(_work, [f3.proxy()], f4[i], 30 + i) for i in range(N_PAIRS)]
    handles.append(pool.submit(_work, [f.proxy() for f in f4], f5, 40))
    f5.result()
    for h in handles:
        h.result()
    return time.perf_counter() - t0


def main():
    workers = N_CHUNKS + N_PAIRS + 3
    with Store("genomes") as store, ThreadPoolExecutor(workers) as pool:
        t_base = run_baseline(pool)
        t_pf = run_proxyfutures(pool, store)
    reduction = 1 - t_pf / t_base
    print(
        f"pipelined_workflow (1000-Genomes analogue):\n"
        f"  control-flow baseline : {t_base:.2f}s\n"
        f"  ProxyFutures pipelined: {t_pf:.2f}s\n"
        f"  makespan reduction    : {reduction:.1%} (paper: 36%)"
    )
    assert reduction > 0.10, "pipelining must reduce makespan"


if __name__ == "__main__":
    main()
