"""Speculative decoding over owned KV pages: draft-model multi-token steps.

The serve loop's tentpole perf pattern: a small *draft* model proposes k
tokens per slot per step, the target model verifies all k+1 positions in
ONE jit'd paged forward (multi-query paged attention), and the engine
accepts the longest draft prefix matching the target's own argmaxes plus
one corrected token.  Emitted tokens are therefore **always the target's
argmaxes** — the output is bit-identical to plain greedy decode no matter
how good or bad the draft is; draft quality only moves the accepted
tokens/step rate.  Rejected draft KV is "rolled back" by simply never
scattering those positions into the page pool (a PageTable never shrinks),
and the draft runs its own PageTable + Owned page cells in lockstep.

This example serves the same request set twice — spec_k=3 with a
self-draft (the acceptance-maximizing degenerate case) and spec_k=0 — and
asserts the two transcripts are identical while the speculative run
accepted strictly more than one token per slot-step.

    PYTHONPATH=src python examples/speculative_serving.py
"""
from __future__ import annotations

import threading

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.connectors import new_key
from repro.core.store import Store
from repro.core.streaming import (
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)
from repro.dist.sharding import materialize_params
from repro.models.api import build_model
from repro.serve.engine import ServeEngine, serve_context

N_REQUESTS = 4
MAX_NEW = 10
SPEC_K = 3


def serve_once(ctx, params, requests, *, spec_k, draft_model=None,
               draft_params=None):
    ns = f"spec-demo-{new_key()}"
    store = Store(f"{ns}-req")
    producer = StreamProducer(QueuePublisher(ns), {"requests": store})
    consumer = StreamConsumer(QueueSubscriber("requests", ns), timeout=30.0)

    def client():
        for rid, prompt in requests.items():
            producer.send(
                "requests",
                {"prompt": prompt},
                metadata={"req_id": rid, "max_new_tokens": MAX_NEW},
            )
            producer.flush_topic("requests")
        producer.close_topic("requests")

    engine = ServeEngine(
        ctx, params, slots=2, max_len=48, page_size=8, eos_id=-1,
        spec_k=spec_k, draft_model=draft_model, draft_params=draft_params,
    )
    t = threading.Thread(target=client, daemon=True)
    t.start()
    completed = engine.run(consumer)
    t.join(timeout=30)
    tokens = {rid: completed[rid]["tokens"] for rid in requests}
    metrics = dict(engine.metrics)
    assert engine.pages.pages_in_use() == 0
    assert engine.draft_pages is None or engine.draft_pages.pages_in_use() == 0
    engine.close()
    store.close()
    return tokens, metrics


def main():
    cfg = get_smoke_config("smollm-135m")
    ctx = serve_context(cfg)
    model = build_model(ctx)
    params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))

    rng = np.random.default_rng(7)
    requests = {
        f"spec-{i}": rng.integers(1, cfg.vocab, 12).astype(np.int32)
        for i in range(N_REQUESTS)
    }

    spec_tokens, spec_m = serve_once(
        ctx, params, requests, spec_k=SPEC_K,
        draft_model=model, draft_params=params,  # self-draft
    )
    plain_tokens, plain_m = serve_once(ctx, params, requests, spec_k=0)

    rate = spec_m["spec_accepted_tokens"] / spec_m["spec_slot_steps"]
    print(
        f"speculative_serving: {N_REQUESTS} requests × {MAX_NEW} tokens\n"
        f"  spec_k={SPEC_K} (self-draft): {spec_m['decode_steps']} decode "
        f"steps, {rate:.2f} accepted tokens/slot-step\n"
        f"  spec_k=0 (plain):            {plain_m['decode_steps']} decode "
        f"steps, 1.00 accepted tokens/slot-step\n"
        f"  transcripts identical: "
        f"{all(spec_tokens[r] == plain_tokens[r] for r in requests)}"
    )
    assert spec_tokens == plain_tokens, (
        "speculative decode must be bit-identical to plain greedy decode"
    )
    assert rate > 1.0, "a self-draft must accept more than one token/step"
    assert spec_m["decode_steps"] < plain_m["decode_steps"], (
        "speculation must finish in fewer engine steps"
    )


if __name__ == "__main__":
    main()
