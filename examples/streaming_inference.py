"""DeepDriveMD analogue: persistent streaming inference (paper §VI, Fig 9).

A *persistent* inference engine consumes batches from a ProxyStream — one
long-lived task instead of one task per batch, eliminating per-task model
reload and scheduling overheads.  ProxyFutures announce new "model weights"
to the running engine (the paper's model-update channel), and results stream
back to the client, which only ever touches metadata.

Baseline for comparison: per-batch tasks that each "load" the model (sleep +
device_put) before inferring — the pattern the paper replaces.

    PYTHONPATH=src python examples/streaming_inference.py
"""
from __future__ import annotations

import threading
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import Store
from repro.core.proxy import Proxy, extract
from repro.core.streaming import (
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)
from repro.dist.sharding import materialize_params
from repro.launch.mesh import make_host_mesh, rules_for
from repro.models.api import build_model
from repro.models.layers import ModelContext

N_BATCHES = 12
BATCH, SEQ = 4, 64
MODEL_LOAD_S = 0.25  # simulated per-task model-load overhead (paper: 5–60 s)


def make_model():
    cfg = get_smoke_config("smollm-135m")
    mesh = make_host_mesh()
    ctx = ModelContext(cfg, mesh, rules_for(mesh))
    model = build_model(ctx)
    params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))
    fwd = jax.jit(lambda p, t: model.loss(p, {"tokens": t, "labels": t})[0])
    return cfg, params, fwd


def run_per_task(cfg, params, fwd, batches) -> float:
    """One task per batch: reload model, infer (the baseline DeepDriveMD)."""
    t0 = time.perf_counter()
    for b in batches:
        # simulated task startup cost (import + weight load), not a poll
        time.sleep(MODEL_LOAD_S)  # proxylint: disable=no-sleep-poll
        fwd(params, b).block_until_ready()
    return time.perf_counter() - t0


def run_persistent(cfg, params, fwd, batches) -> tuple[float, int]:
    """Persistent engine: stream in, stream out, zero reloads."""
    ns = "ddmd"
    in_store, out_store = Store("ddmd-in"), Store("ddmd-out")
    producer = StreamProducer(QueuePublisher(ns), {"batches": in_store},
                              evict_on_resolve=True)
    results = StreamProducer(QueuePublisher(ns), {"results": out_store})
    consumer = StreamConsumer(QueueSubscriber("batches", ns), timeout=30.0)
    result_consumer = StreamConsumer(QueueSubscriber("results", ns), timeout=30.0)

    model_updates = in_store.future()  # ProxyFuture model-update channel

    def engine():
        time.sleep(MODEL_LOAD_S)  # loads ONCE
        weights = extract(model_updates.proxy())  # blocks until announced
        n = 0
        for proxy in consumer:
            batch = extract(proxy)
            loss = float(fwd(weights, batch))
            # metadata-only progress delta (PR 5 streaming API): the scalar
            # rides the broker event itself — no store round trip
            results.send_meta("results", {"i": n, "kind": "delta", "loss": loss})
            results.send("results", {"loss": loss},
                         metadata={"i": n, "kind": "done"})
            results.flush_topic("results")
            n += 1
        results.close_topic("results")

    t0 = time.perf_counter()
    eng = threading.Thread(target=engine, daemon=True)
    eng.start()
    model_updates.set_result(params)  # announce initial weights
    for i, b in enumerate(batches):
        producer.send("batches", b, metadata={"i": i})
        producer.flush_topic("batches")
    producer.close_topic("batches")
    got = deltas = 0
    while True:
        try:
            _, meta = result_consumer.next_with_metadata()
        except StopIteration:
            break
        if meta.get("kind") == "delta":
            deltas += 1  # the client reads losses off the event, store-free
        else:
            got += 1
    assert deltas == got, "every result must be announced by a delta first"
    eng.join()
    return time.perf_counter() - t0, got


def main():
    cfg, params, fwd, = make_model()
    rng = np.random.default_rng(0)
    batches = [
        rng.integers(0, cfg.vocab, (BATCH, SEQ)).astype(np.int32)
        for _ in range(N_BATCHES)
    ]
    fwd(params, batches[0]).block_until_ready()  # compile once, outside timing

    t_task = run_per_task(cfg, params, fwd, batches)
    t_stream, got = run_persistent(cfg, params, fwd, batches)
    assert got == N_BATCHES
    print(
        f"streaming_inference (DeepDriveMD analogue, {N_BATCHES} batches):\n"
        f"  per-task (reload each time): {t_task:.2f}s "
        f"({t_task/N_BATCHES*1e3:.0f} ms/batch)\n"
        f"  persistent ProxyStream     : {t_stream:.2f}s "
        f"({t_stream/N_BATCHES*1e3:.0f} ms/batch)\n"
        f"  round-trip improvement     : {1 - t_stream/t_task:.1%} (paper: 32%)"
    )
    assert t_stream < t_task, "persistent engine must beat per-task reloads"


if __name__ == "__main__":
    main()
