"""MOF-Generation analogue: ownership-managed generation loop (paper §VI,
Fig 10) — here as a continuous-batching LLM serving run where every
sequence's KV pages and payloads are ownership-managed.

A client streams prompt requests that all open with the same system
prompt; the ServeEngine admits them into slots, decodes over a paged KV
pool whose page lists are OwnedProxies, and *aliases* the shared prefix:
concurrently-live sequences borrow the first requester's prefix pages
through refcounted ownership cells instead of re-prefilling and re-storing
them (copy-on-write protects the boundary).  Everything is freed
deterministically at sequence end — a borrowed page returns to the pool
only when its last referencing sequence finishes.  The assertions at the
bottom are the paper's Fig 10 claim (active proxied objects return to
zero, no manual bookkeeping) plus this PR's sharing claim (prefix pages
were actually aliased, not copied).

    PYTHONPATH=src python examples/ownership_serving.py
"""
from __future__ import annotations

import threading
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.store import Store
from repro.core.streaming import (
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)
from repro.dist.sharding import materialize_params
from repro.models.api import build_model
from repro.serve.engine import ServeEngine, serve_context

N_REQUESTS = 6
MAX_NEW = 8


def main():
    cfg = get_smoke_config("smollm-135m")
    ctx = serve_context(cfg)  # serve rules profile (kv_seq over model axis)
    model = build_model(ctx)
    params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))

    ns = "mof"
    store = Store("mof-req")
    producer = StreamProducer(QueuePublisher(ns), {"requests": store})
    consumer = StreamConsumer(QueueSubscriber("requests", ns), timeout=0.05)

    rng = np.random.default_rng(1)
    active_trace: list[int] = []
    # every request opens with the same 16-token "system prompt" — exactly
    # one full KV page at page_size=16, so concurrent sequences alias it
    system_prompt = rng.integers(1, cfg.vocab, 16).astype(np.int32)

    def client():
        for i in range(N_REQUESTS):
            user = rng.integers(1, cfg.vocab, 6).astype(np.int32)
            prompt = np.concatenate([system_prompt, user])
            producer.send(
                "requests",
                {"prompt": prompt},
                metadata={"req_id": f"mof-{i}", "max_new_tokens": MAX_NEW},
            )
            producer.flush_topic("requests")
        producer.close_topic("requests")

    engine = ServeEngine(ctx, params, slots=3, max_len=48, page_size=16,
                         eos_id=-1)

    def tracer():
        while not done.is_set():
            active_trace.append(engine.pages.pages_in_use())
            time.sleep(0.05)  # proxylint: disable=no-sleep-poll (sampling tracer)

    done = threading.Event()
    threading.Thread(target=client, daemon=True).start()
    threading.Thread(target=tracer, daemon=True).start()
    completed = engine.run(consumer)
    done.set()

    # The ownership claim now reaches the store itself: freeing a sequence
    # evicts its per-page KV cells, so the kv_store holds zero page keys.
    kv_keys_left = sum(
        1
        for seq in [f"mof-{i}" for i in range(N_REQUESTS)]
        for p in range(engine.pages.num_pages)
        if engine.kv_store.exists(engine.pages.page_key(seq, p))
    )
    print(
        f"ownership_serving (MOF analogue): {len(completed)}/{N_REQUESTS} "
        f"sequences served, {engine.metrics['tokens']} tokens\n"
        f"  pages-in-use trace (sampled): {active_trace}\n"
        f"  peak pages {max(active_trace or [0])}, final pages "
        f"{engine.pages.pages_in_use()}, kv cells left {kv_keys_left} "
        f"(paper Fig 10: returns to zero)\n"
        f"  system-prompt pages aliased (not copied): "
        f"{engine.metrics['prefix_shared_pages']}, copy-on-write copies: "
        f"{engine.metrics['cow_page_copies']}"
    )
    assert len(completed) == N_REQUESTS
    assert engine.pages.pages_in_use() == 0, "ownership must reclaim all pages"
    assert kv_keys_left == 0, "ownership must release the store memory too"
    assert max(active_trace or [0]) > 0, "pages were actually used"
    assert engine.metrics["prefix_shared_pages"] > 0, (
        "concurrent sequences must alias the shared system prompt"
    )
    engine.close()


if __name__ == "__main__":
    main()
