"""repro.dist.sharding contract tests: profile resolution, counts, init.

logical_to_spec accepts a plain ``{axis: size}`` mapping wherever a Mesh is
expected, so production-mesh-shaped resolution is testable on a 1-device
box (the real 16×16 / 2×16×16 meshes only exist under the dry-run's forced
device count).
"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.dist.sharding import (
    DEFAULT_RULES,
    FLAT_DP_RULES,
    MULTIPOD_RULES,
    RULE_PROFILES,
    count_params,
    logical_to_spec,
    materialize_params,
)
from repro.launch.mesh import make_host_mesh, rules_for
from repro.models.api import build_model
from repro.models.layers import ModelContext

POD = {"data": 16, "model": 16}
MULTIPOD = {"pod": 2, "data": 16, "model": 16}


class TestProfileResolution:
    def test_default_tp_and_dp(self):
        assert logical_to_spec((4096, 1536), ("embed", "mlp"), DEFAULT_RULES, POD) \
            == P(None, "model")
        assert logical_to_spec((256, 4096), ("batch", None), DEFAULT_RULES, POD) \
            == P("data", None)
        assert logical_to_spec((49408, 512), ("vocab", "embed"), DEFAULT_RULES, POD) \
            == P("model", None)

    def test_indivisible_dims_replicate(self):
        # smollm: 9 heads / 3 kv heads on a 16-way model axis → replicated
        assert logical_to_spec((576, 9, 64), ("embed", "heads", None),
                               DEFAULT_RULES, POD) == P(None, None, None)
        assert logical_to_spec((576, 3, 64), ("embed", "kv_heads", None),
                               DEFAULT_RULES, POD) == P(None, None, None)

    def test_multipod_batch_spans_pod_and_data(self):
        assert logical_to_spec((256, 4096), ("batch", None),
                               MULTIPOD_RULES, MULTIPOD) == P(("pod", "data"), None)
        # same rules degrade on a pod-less mesh: pod axis dropped
        assert logical_to_spec((256, 4096), ("batch", None),
                               MULTIPOD_RULES, POD) == P("data", None)

    def test_flat_dp_replicates_params(self):
        assert logical_to_spec((256, 128), ("batch", None), FLAT_DP_RULES, POD) \
            == P(("data", "model"), None)
        assert logical_to_spec((512, 2048), ("embed", "mlp"), FLAT_DP_RULES, POD) \
            == P(None, None)

    def test_serve_kv_seq_wins_model_axis(self):
        serve, _ = RULE_PROFILES["serve"]
        spec = logical_to_spec((32, 4096, 16, 64),
                               ("batch", "kv_seq", "kv_heads", None), serve, POD)
        # kv_seq takes the model axis; kv_heads must not reuse it
        assert spec == P("data", "model", None, None)

    def test_no_mesh_axis_used_twice(self):
        # rwkv channel-mix wr is (E, E) with embed on both sides under a
        # profile that shards embed: the second occurrence must replicate
        rules = DEFAULT_RULES.with_("fsdp-ish", embed=("model",),
                                    embed2=("model",))
        spec = logical_to_spec((512, 512), ("embed", "embed2"), rules, POD)
        assert spec == P("model", None)

    def test_every_profile_resolves_on_host_mesh(self):
        mesh = make_host_mesh()
        for name, (pod_rules, multipod_rules) in RULE_PROFILES.items():
            assert rules_for(mesh, name) is pod_rules
            for rules in (pod_rules, multipod_rules):
                spec = logical_to_spec((256, 64), ("batch", "embed"), rules, mesh)
                assert isinstance(spec, P)


class TestShardConstraint:
    def test_one_device_noop_warns_once(self):
        """The 1-device drop is explicit: one warning per process, then
        silent — and the value passes through untouched."""
        import warnings

        from repro.dist import sharding as sh

        mesh = make_host_mesh()
        x = np.ones((8, 4), np.float32)
        old = sh._noop_constraint_warned
        try:
            sh._noop_constraint_warned = False
            with warnings.catch_warnings(record=True) as w:
                warnings.simplefilter("always")
                y = sh.shard_constraint(x, ("batch", None), DEFAULT_RULES, mesh)
                sh.shard_constraint(x, ("batch", None), DEFAULT_RULES, mesh)
            assert y is x  # no-op returns the operand itself
            msgs = [str(m.message) for m in w if "shard_constraint" in str(m.message)]
            assert len(msgs) == 1  # warned exactly once
            assert "no-op" in msgs[0]
        finally:
            sh._noop_constraint_warned = old

    def test_multi_device_places_real_constraint(self):
        """Dry-run under a forced 4-device mesh: the lowered HLO carries a
        Sharding custom-call and the constrained output lands sharded over
        the data axis (subprocess — the main process must keep 1 device)."""
        import os
        import subprocess
        import sys
        import textwrap

        body = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp, numpy as np
            from repro.dist.sharding import DEFAULT_RULES, shard_constraint

            mesh = jax.make_mesh((4, 1), ("data", "model"))

            def f(x):
                return shard_constraint(x, ("batch", None), DEFAULT_RULES, mesh)

            x = jnp.zeros((8, 4), jnp.float32)
            txt = jax.jit(f).lower(x).as_text()
            assert "Sharding" in txt, txt  # constraint reached the HLO
            out = jax.jit(f)(x)
            shards = {s.device.id: s.index for s in out.addressable_shards}
            assert len(shards) == 4  # one shard per device over batch
            rows = sorted(idx[0].start or 0 for idx in shards.values())
            assert rows == [0, 2, 4, 6], rows
            print("OK")
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src"),
             env.get("PYTHONPATH", "")]
        )
        r = subprocess.run([sys.executable, "-c", body], env=env,
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stdout + r.stderr
        assert "OK" in r.stdout


class TestCountParams:
    @pytest.mark.parametrize("name,lo,hi", [
        ("smollm-135m", 5e4, 5e6),
        ("granite-moe-1b-a400m", 5e4, 2e7),
    ])
    def test_count_matches_materialized_size(self, name, lo, hi):
        cfg = get_smoke_config(name)
        ctx = ModelContext(cfg, make_host_mesh(), DEFAULT_RULES)
        specs = build_model(ctx).param_specs()
        n = count_params(specs)
        assert lo < n < hi
        params = materialize_params(specs, jax.random.PRNGKey(0))
        assert n == sum(int(np.asarray(x).size) for x in jax.tree.leaves(params))


class TestMaterializeDeterminism:
    def test_same_seed_identical_leaves(self):
        cfg = get_smoke_config("smollm-135m")
        ctx = ModelContext(cfg, make_host_mesh(), DEFAULT_RULES)
        specs = build_model(ctx).param_specs()
        a = materialize_params(specs, jax.random.PRNGKey(7))
        b = materialize_params(specs, jax.random.PRNGKey(7))
        jax.tree.map(
            lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
            a, b,
        )
        c = materialize_params(specs, jax.random.PRNGKey(8))
        diffs = [
            not np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(c))
            if np.asarray(x).ndim >= 2 and np.asarray(x).std() > 0
        ]
        assert any(diffs)  # a different seed actually changes weights

    def test_mesh_shape_independent(self):
        """Init depends only on (seed, path): identical under any mesh/rules."""
        cfg = get_smoke_config("smollm-135m")
        specs = build_model(
            ModelContext(cfg, make_host_mesh(), DEFAULT_RULES)
        ).param_specs()
        with make_host_mesh():
            a = materialize_params(specs, jax.random.PRNGKey(0))
        with jax.make_mesh((1,), ("model",)):
            b = materialize_params(specs, jax.random.PRNGKey(0))
        jax.tree.map(
            lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
            a, b,
        )

    def test_profile_mesh_matrix_bitwise_identical(self):
        """Across every RULE_PROFILES profile × mesh shape, materialized
        (and device_put-sharded) params are bitwise the same logical
        arrays — the exact invariant the PR 4 remesh driver and resharded
        checkpoint restore rely on (a remesh may swap both the mesh axes
        and the rules profile; the weights must not move a ULP)."""
        from repro.dist.sharding import ParamSpec, sharding_tree

        specs = {
            "emb": ParamSpec((64, 32), ("vocab", "embed"), np.float32, 0.02),
            "w": ParamSpec((32, 128), ("embed", "mlp"), np.float32),
            "heads": ParamSpec((32, 4, 8), ("embed", "heads", None), np.float32),
            "scale": ParamSpec((32,), ("embed",), np.float32, 1.0),
        }
        ref = jax.tree.map(
            np.asarray, materialize_params(specs, jax.random.PRNGKey(3))
        )
        meshes = [
            jax.make_mesh((1, 1), ("data", "model")),
            jax.make_mesh((1,), ("model",)),
            jax.make_mesh((1, 1, 1), ("pod", "data", "model")),
        ]
        for profile in RULE_PROFILES:
            for mesh in meshes:
                rules = rules_for(mesh, profile)
                with mesh:
                    params = materialize_params(specs, jax.random.PRNGKey(3))
                    placed = jax.device_put(
                        params, sharding_tree(specs, rules, mesh)
                    )
                jax.tree.map(
                    lambda r, x: np.testing.assert_array_equal(r, np.asarray(x)),
                    ref, placed,
                )

    def test_init_scale_semantics(self):
        from repro.dist.sharding import ParamSpec

        specs = {
            "scale": ParamSpec((16,), (None,), np.float32, init_scale=1.0),
            "bias": ParamSpec((16,), (None,), np.float32, init_scale=0.0),
            "cache": ParamSpec((2, 8, 4), ("batch", None, None), np.float32, 0.0),
            "emb": ParamSpec((64, 32), ("vocab", "embed"), np.float32, 0.02),
            "w": ParamSpec((64, 32), ("embed", "mlp"), np.float32),
        }
        p = materialize_params(specs, jax.random.PRNGKey(0))
        assert np.all(np.asarray(p["scale"]) == 1.0)
        assert np.all(np.asarray(p["bias"]) == 0.0)
        assert np.all(np.asarray(p["cache"]) == 0.0)
        assert 0.01 < np.asarray(p["emb"]).std() < 0.03
        assert 0.06 < np.asarray(p["w"]).std() < 0.25  # ≈ 1/√64
