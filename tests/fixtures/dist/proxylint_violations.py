"""Seeded ProxyLint violations — at least one per rule.

Never imported or executed: the lint tests point ``proxy_lint`` at this
file and assert the run exits non-zero with every rule represented.
Lives under a ``dist/`` directory on purpose, so the cross-process-only
``mutable-key-fresh`` rule is in scope.
"""
import time

import jax


def sleep_poll(flag):
    while not flag():
        time.sleep(0.01)  # violation: no-sleep-poll


def busy_wait(store, key):
    while not store.exists(key):  # violation: connector-wait-protocol
        pass


def stale_read(store, key, obj):
    store.put(obj, key=key)  # overwrite: `key` is a mutable cell
    return store.get(key)  # violation: mutable-key-fresh


def donated_reuse(params, cache, tokens):
    step = jax.jit(lambda p, c, t: (c, t), donate_argnums=(1,))
    out, logits = step(params, cache, tokens)
    return cache, logits  # violation: donated-reuse (cache died at the call)


def discarded_mint(store, obj):
    owned_proxy(store, obj)  # violation: owned-lifetime (mint discarded)


def swallow(risky):
    try:
        risky()
    except Exception:
        pass  # violation: swallowed-error
