"""Tiered MultiConnector: routing policy, fall-through, demotion, Store wiring."""
import pickle
import threading
import time

import pytest

from repro.core import Store
from repro.core import connectors as C
from repro.core.connectors import FileConnector, InMemoryConnector, new_key
from repro.core.multi import MultiConnector, Tier, key_tags


@pytest.fixture
def stack(tmp_path):
    hot = InMemoryConnector(new_key())
    cold = FileConnector(str(tmp_path / "cold"))
    m = MultiConnector([
        Tier("hot", hot, max_bytes=256),
        Tier("cold", cold, tags=frozenset({"bulk"})),
    ])
    yield m, hot, cold
    m.close()


class TestRouting:
    def test_size_threshold_routes(self, stack):
        m, hot, cold = stack
        m.put("small", b"s" * 16)
        m.put("big", b"B" * 4096)
        assert hot.exists("small") and not cold.exists("small")
        assert cold.exists("big") and not hot.exists("big")
        assert m.tier_of("small") == "hot"
        assert m.tier_of("big") == "cold"

    def test_tag_routes_override_size(self, stack):
        m, hot, cold = stack
        # tiny payload, but the #bulk tag pins it to the cold tier
        m.put("k#bulk", b"x")
        assert cold.exists("k#bulk") and not hot.exists("k#bulk")
        assert key_tags("k#bulk#extra") == frozenset({"bulk", "extra"})
        assert key_tags("plain") == frozenset()

    def test_pin_overrides_everything(self, stack):
        m, hot, cold = stack
        m.pin("p", "cold")
        m.put("p", b"tiny")
        assert cold.exists("p") and not hot.exists("p")
        with pytest.raises(KeyError):
            m.pin("q", "nonexistent-tier")

    def test_no_tier_admits_falls_to_last(self, tmp_path):
        m = MultiConnector([
            Tier("a", InMemoryConnector(new_key()), max_bytes=10),
            Tier("b", InMemoryConnector(new_key()), max_bytes=20),
        ])
        m.put("huge", b"x" * 1000)  # admitted nowhere: last tier takes it
        assert m.tier_of("huge") == "b"
        m.close()

    def test_overwrite_reroute_evicts_stale_copy(self, stack):
        m, hot, cold = stack
        m.put("k", b"small")
        assert hot.exists("k")
        m.put("k", b"B" * 4096)  # grew: re-routes to cold
        assert not hot.exists("k"), "stale hot copy must be evicted"
        assert m.get("k") == b"B" * 4096
        m.put("k", b"small-again")  # shrank: back to hot
        assert not cold.exists("k")
        assert m.get("k") == b"small-again"


class TestFallThrough:
    def test_foreign_put_found_by_probe(self, stack):
        m, hot, cold = stack
        # another process's put lands in a tier this instance never routed
        cold.put("foreign", b"f")
        assert m.exists("foreign")
        assert m.get("foreign") == b"f"
        assert m.tier_of("foreign") == "cold"

    def test_stale_route_hint_recovers(self, stack):
        m, hot, cold = stack
        m.put("k", b"v")
        hot.evict("k")  # evicted behind the route map's back
        cold.put("k", b"moved")
        assert m.get("k") == b"moved"
        assert m.tier_of("k") == "cold"

    def test_get_view_and_parts_fall_through(self, stack):
        m, hot, cold = stack
        cold.put("f", b"payload")
        assert bytes(m.get_view("f")) == b"payload"
        parts = m.get_parts("f")
        assert b"".join(bytes(p) for p in parts) == b"payload"

    def test_evict_sweeps_all_tiers(self, stack):
        m, hot, cold = stack
        hot.put("k", b"hot-copy")
        cold.put("k", b"cold-copy")  # pathological double residency
        m.evict("k")
        assert not hot.exists("k") and not cold.exists("k")


class TestBatchAndPutNew:
    def test_put_batch_splits_by_tier(self, stack):
        m, hot, cold = stack
        n = m.put_batch([
            ("s1", (b"a" * 10,)),
            ("s2", (b"b" * 20,)),
            ("big", (b"c" * 1000,)),
        ])
        assert n == 1030
        assert hot.exists("s1") and hot.exists("s2") and cold.exists("big")

    def test_put_parts_new_atomicity(self, stack):
        m, hot, cold = stack
        assert m.put_parts_new("n", (b"first",)) == 5
        assert m.put_parts_new("n", (b"later",)) is None
        assert m.get("n") == b"first"

    def test_put_parts_new_rejects_cross_tier_resident(self, stack):
        m, hot, cold = stack
        cold.put("n", b"resident")  # already in a tier the put wouldn't route to
        assert m.put_parts_new("n", (b"x",)) is None
        assert m.get("n") == b"resident"


class TestWaits:
    def test_wait_for_any_across_tiers(self, stack):
        m, hot, cold = stack

        def later():
            time.sleep(0.15)
            cold.put("w-cold", b"x")

        threading.Thread(target=later, daemon=True).start()
        t0 = time.monotonic()
        won = m.wait_for_any(["w-hot", "w-cold"], timeout=10.0)
        dt = time.monotonic() - t0
        assert won == "w-cold"
        assert dt < 5.0
        assert m.tier_of("w-cold") == "cold"

    def test_wait_for_timeout(self, stack):
        m, _, _ = stack
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            m.wait_for("never", timeout=0.3)
        dt = time.monotonic() - t0
        assert 0.29 <= dt < 1.0, dt


class TestDemotion:
    def test_demote_moves_payload(self, stack):
        m, hot, cold = stack
        m.put("d", b"data" * 8)
        assert m.tier_of("d") == "hot"
        assert m.demote("d", "cold")
        assert m.tier_of("d") == "cold"
        assert not hot.exists("d")
        assert m.get("d") == b"data" * 8

    def test_demote_missing_and_same_tier(self, stack):
        m, hot, cold = stack
        assert not m.demote("ghost", "cold")
        m.put("k", b"v")
        assert m.demote("k", "hot")  # already there: trivially true
        with pytest.raises(KeyError):
            m.demote("k", "bogus")

    def test_store_demote_invalidates_resolve_cache(self, stack):
        m, hot, cold = stack
        s = Store("tiered", m)
        key = s.put([1, 2, 3])
        assert s.get(key) == [1, 2, 3]  # warm the resolve cache
        assert s.demote(key, "cold")
        assert s.tier_of(key) == "cold"
        assert not hot.exists(key)
        assert s.get(key) == [1, 2, 3]  # re-fetched from the cold tier

    def test_store_demote_on_plain_connector_is_noop(self):
        s = Store("plain", InMemoryConnector(new_key()))
        key = s.put("x")
        assert s.tier_of(key) is None
        assert s.demote(key, "anywhere") is False
        assert s.get(key) == "x"


class TestStoreIntegration:
    def test_proxy_resolves_through_tiers(self, stack):
        m, hot, cold = stack
        s = Store("tiered", m)
        small = s.proxy({"k": 1})
        bulk = s.proxy(list(range(10_000)))
        assert small["k"] == 1
        assert len(bulk) == 10_000
        # the bulk payload routed cold, the small one hot
        from repro.core import get_factory

        assert m.tier_of(get_factory(bulk).key) == "cold"
        assert m.tier_of(get_factory(small).key) == "hot"

    def test_pickled_connector_same_channel(self, stack):
        m, hot, cold = stack
        m.put("k", b"v")
        clone = pickle.loads(pickle.dumps(m))
        assert clone.channel_id == m.channel_id
        assert clone.get("k") == b"v"  # file tier survives; route re-probed
