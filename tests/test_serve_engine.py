"""Serving-engine suite (PR 5): continuous batching, termination,
per-slot position correctness, admission backpressure, the no-poll loop
contract, and PageTable store-level ownership.

Engine correctness rides on ``_serve_toy.CountingModel``: a deterministic
integer "LM" whose next token depends on the whole prefix *and* the exact
position, so any cache/position/slot bug changes tokens immediately, and
engine-vs-reference comparisons are bit-identical (no float caveats).
"""
from __future__ import annotations

import inspect
import threading
import time

import numpy as np
import pytest

from _serve_toy import CountingModel, reference_decode
from repro.configs import get_smoke_config
from repro.core.connectors import new_key
from repro.core.store import Store
from repro.core.streaming import (
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)
from repro.serve.engine import ServeEngine, serve_context

CFG = get_smoke_config("smollm-135m")


def make_streams(*, timeout=30.0, resp_timeout=30.0):
    ns = f"se-{new_key()}"
    req_store = Store(f"{ns}-req")
    resp_store = Store(f"{ns}-resp")
    return {
        "producer": StreamProducer(QueuePublisher(ns), {"requests": req_store}),
        "consumer": StreamConsumer(
            QueueSubscriber("requests", ns), timeout=timeout
        ),
        "resp_producer": StreamProducer(
            QueuePublisher(ns), {"responses": resp_store}
        ),
        "resp_consumer": StreamConsumer(
            QueueSubscriber("responses", ns), timeout=resp_timeout
        ),
    }


def make_engine(
    *, slots=2, max_len=32, page_size=4, eos_id=-1, num_pages=None, **kw
):
    ctx = serve_context(CFG)
    engine = ServeEngine(
        ctx,
        {},
        slots=slots,
        max_len=max_len,
        page_size=page_size,
        eos_id=eos_id,
        model=CountingModel(CFG),
        **kw,
    )
    if num_pages is not None:  # shrink the pool to force backpressure
        engine.pages.num_pages = num_pages
        engine.pages._free = list(range(num_pages))
    return engine


def send_request(producer, req_id, prompt, max_new, topic="requests"):
    producer.send(
        topic,
        {"prompt": np.asarray(prompt, np.int32)},
        metadata={"req_id": req_id, "max_new_tokens": max_new},
    )
    producer.flush_topic(topic)


def serve(engine, requests, *, with_responses=False, **run_kw):
    """Publish ``requests`` (req_id → (prompt, max_new)), close, run."""
    s = make_streams()
    for rid, (prompt, max_new) in requests.items():
        send_request(s["producer"], rid, prompt, max_new)
    s["producer"].close_topic("requests")
    resp = s["resp_producer"] if with_responses else None
    completed = engine.run(s["consumer"], resp, **run_kw)
    return completed, s


class TestContinuousBatching:
    def test_serves_more_requests_than_slots(self):
        """2× slots requests drain through refilling slots."""
        rng = np.random.default_rng(0)
        engine = make_engine(slots=2)
        reqs = {
            f"r{i}": (rng.integers(1, CFG.vocab, 5).astype(np.int32), 4)
            for i in range(4)
        }
        completed, _ = serve(engine, reqs)
        assert sorted(completed) == sorted(reqs)
        assert all(len(c["tokens"]) == 4 for c in completed.values())
        engine.close()

    def test_slots_refill_as_requests_finish(self):
        """A short request's slot is reused mid-flight by a later request:
        total decode steps stay near the continuous-batching ideal, far
        under the static-batching cost."""
        rng = np.random.default_rng(1)
        engine = make_engine(slots=2, max_len=64, page_size=4)
        # two long + two short: the shorts' slots must host the 2nd long
        reqs = {
            "long0": (rng.integers(1, CFG.vocab, 4).astype(np.int32), 20),
            "short0": (rng.integers(1, CFG.vocab, 4).astype(np.int32), 2),
            "short1": (rng.integers(1, CFG.vocab, 4).astype(np.int32), 2),
            "long1": (rng.integers(1, CFG.vocab, 4).astype(np.int32), 20),
        }
        completed, _ = serve(engine, reqs)
        assert sorted(completed) == sorted(reqs)
        # static batching would cost ≥ 2 batches × 19 steps = 38; continuous
        # overlaps long1 with long0's tail (first token is prefill-produced,
        # so a k-token request needs k-1 decode steps)
        assert engine.metrics["decode_steps"] <= 25
        engine.close()

    def test_max_requests_stops_early_and_resumes(self):
        """run(max_requests=k) serves exactly k and leaves the rest for a
        later run on the same consumer (the restart path)."""
        rng = np.random.default_rng(2)
        engine = make_engine(slots=2)
        s = make_streams()
        reqs = {
            f"r{i}": (rng.integers(1, CFG.vocab, 4).astype(np.int32), 3)
            for i in range(5)
        }
        for rid, (p, mn) in reqs.items():
            send_request(s["producer"], rid, p, mn)
        s["producer"].close_topic("requests")
        first = dict(engine.run(s["consumer"], max_requests=2))
        assert len(first) == 2
        rest = engine.run(s["consumer"])
        assert sorted(rest) == sorted(reqs)  # completed accumulates
        engine.close()

    def test_completed_bookkeeping(self):
        rng = np.random.default_rng(3)
        engine = make_engine(slots=2)
        reqs = {
            "a": (rng.integers(1, CFG.vocab, 6).astype(np.int32), 5),
            "b": (rng.integers(1, CFG.vocab, 3).astype(np.int32), 2),
        }
        completed, _ = serve(engine, reqs)
        for rid, (prompt, max_new) in reqs.items():
            entry = completed[rid]
            assert len(entry["tokens"]) == max_new
            assert entry["latency"] > 0
            assert 0 < entry["ttft"] <= entry["latency"]
        assert engine.metrics["tokens"] == sum(m for _, m in reqs.values())
        engine.close()


class TestDecodeCorrectness:
    def test_tokens_bit_identical_to_sequential_reference(self):
        """Continuous batching must not change a single token: every
        request's output equals a sequential single-request greedy decode."""
        rng = np.random.default_rng(4)
        engine = make_engine(slots=3, max_len=32)
        reqs = {
            f"r{i}": (
                rng.integers(1, CFG.vocab, int(rng.integers(3, 9))).astype(
                    np.int32
                ),
                int(rng.integers(2, 8)),
            )
            for i in range(7)
        }
        completed, _ = serve(engine, reqs)
        for rid, (prompt, max_new) in reqs.items():
            ref = reference_decode(CFG, prompt, max_new, max_len=32)
            assert completed[rid]["tokens"] == ref, rid
        engine.close()

    def test_idle_slots_do_not_perturb_active_ones(self):
        """A request served alone on a wide engine (3 idle slots decoding
        masked garbage) produces the same tokens as on a 1-slot engine."""
        prompt = np.arange(1, 7, dtype=np.int32)
        wide = make_engine(slots=4)
        narrow = make_engine(slots=1)
        got_wide, _ = serve(wide, {"x": (prompt, 6)})
        got_narrow, _ = serve(narrow, {"x": (prompt, 6)})
        assert got_wide["x"]["tokens"] == got_narrow["x"]["tokens"]
        assert got_wide["x"]["tokens"] == reference_decode(CFG, prompt, 6, max_len=32)
        wide.close()
        narrow.close()

    def test_per_slot_positions_differ(self):
        """Slots decode at different positions in the same batched step —
        staggered admissions (different prompt lengths) stay correct."""
        engine = make_engine(slots=2, max_len=32)
        reqs = {
            "shortp": (np.asarray([5, 9], np.int32), 6),
            "longp": (np.asarray(range(1, 12), np.int32), 6),
        }
        completed, _ = serve(engine, reqs)
        for rid, (prompt, max_new) in reqs.items():
            assert completed[rid]["tokens"] == reference_decode(
                CFG, prompt, max_new, max_len=32
            ), rid
        engine.close()


class TestTermination:
    def test_eos_stops_generation(self):
        prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
        ref = reference_decode(CFG, prompt, 10, max_len=32)
        eos = ref[2]  # make the 3rd greedy token the stop token
        engine = make_engine(slots=2, eos_id=eos)
        completed, _ = serve(engine, {"e": (prompt, 10)})
        assert completed["e"]["tokens"] == ref[:3]  # eos included, then stop
        assert engine.pages.pages_in_use() == 0
        engine.close()

    def test_eos_on_first_token_finishes_at_admission(self):
        prompt = np.asarray([7, 7, 2], np.int32)
        ref = reference_decode(CFG, prompt, 10, max_len=32)
        engine = make_engine(slots=2, eos_id=ref[0])
        completed, _ = serve(engine, {"e": (prompt, 10)})
        assert completed["e"]["tokens"] == [ref[0]]
        assert engine.metrics["decode_steps"] == 0  # prefill alone served it
        engine.close()

    def test_max_new_tokens(self):
        prompt = np.asarray([2, 4, 6], np.int32)
        engine = make_engine(slots=1)
        completed, _ = serve(engine, {"m": (prompt, 4)})
        assert len(completed["m"]["tokens"]) == 4
        engine.close()

    def test_max_len_caps_generation(self):
        """A request whose max_new would overflow the cache stops at the
        engine's max_len boundary."""
        engine = make_engine(slots=1, max_len=16, page_size=4)
        prompt = np.asarray(range(1, 9), np.int32)  # 8 prompt tokens
        completed, _ = serve(engine, {"cap": (prompt, 100)})
        # pos starts at 8; decode may run until pos == max_len - 1
        assert len(completed["cap"]["tokens"]) == 16 - 1 - 8 + 1
        assert engine.pages.pages_in_use() == 0
        engine.close()


class TestAdmissionControl:
    def test_backpressure_queues_when_pool_tight(self):
        """A pool with room for one sequence serves 2×slots requests
        sequentially instead of OOMing."""
        engine = make_engine(slots=2, max_len=32, page_size=4, num_pages=3)
        rng = np.random.default_rng(5)
        reqs = {
            f"r{i}": (rng.integers(1, CFG.vocab, 4).astype(np.int32), 6)
            for i in range(4)
        }
        # each request reserves ceil((4+6)/4) = 3 pages = the whole pool
        completed, _ = serve(engine, reqs)
        assert sorted(completed) == sorted(reqs)
        assert engine.metrics["queued_admissions"] > 0
        assert engine.pages.pages_in_use() == 0
        for rid, (prompt, max_new) in reqs.items():
            assert completed[rid]["tokens"] == reference_decode(
                CFG, prompt, max_new, max_len=32
            )
        engine.close()

    def test_oversized_request_rejected_not_wedged(self):
        """A request that can never fit is rejected onto the response
        stream; later requests still serve."""
        engine = make_engine(slots=2, max_len=32, page_size=4, num_pages=2)
        reqs = {
            "huge": (np.asarray(range(1, 8), np.int32), 20),  # needs 7 pages
            "ok": (np.asarray([1, 2, 3], np.int32), 3),  # needs 2
        }
        completed, s = serve(engine, reqs, with_responses=True)
        assert "huge" in engine.rejected
        assert "huge" not in completed
        assert completed["ok"]["tokens"] == reference_decode(
            CFG, np.asarray([1, 2, 3], np.int32), 3, max_len=32
        )
        kinds = {}
        while True:
            try:
                _, meta = s["resp_consumer"].next_with_metadata(timeout=5)
            except StopIteration:
                break
            kinds.setdefault(meta["req_id"], []).append(meta["kind"])
        assert "error" in kinds["huge"]
        assert kinds["ok"][-1] == "done"
        engine.close()

    def test_overlong_prompt_rejected_not_crashed(self):
        """A prompt that alone overflows the decode cache is rejected at
        admission instead of crashing the jit'd cache insert."""
        engine = make_engine(slots=2, max_len=16, page_size=4)
        reqs = {
            "big": (np.asarray(range(1, 20), np.int32), 2),  # 19 > 15
            "ok": (np.asarray([1, 2], np.int32), 2),
        }
        completed, _ = serve(engine, reqs)
        assert "big" in engine.rejected and "prompt" in engine.rejected["big"]
        assert completed["ok"]["tokens"] == reference_decode(
            CFG, np.asarray([1, 2], np.int32), 2, max_len=16
        )
        engine.close()

    def test_reservation_prevents_mid_decode_oom(self):
        """Two long sequences that would collide on extends are never
        co-admitted: reservations make admission's promise real."""
        # pool: 4 pages; each request: 2-token prompt (1 page) growing to
        # 10 tokens (3 pages).  Naive prompt-only admission would co-admit
        # both (2 pages ≤ 4) and OOM around token 8.
        engine = make_engine(slots=2, max_len=32, page_size=4, num_pages=4)
        reqs = {
            "g0": (np.asarray([1, 2], np.int32), 8),
            "g1": (np.asarray([3, 4], np.int32), 8),
        }
        completed, _ = serve(engine, reqs)  # MemoryError = test failure
        assert sorted(completed) == ["g0", "g1"]
        assert engine.metrics["queued_admissions"] > 0  # g1 waited
        engine.close()


class TestNotificationDrivenLoop:
    def test_no_sleep_poll_in_run(self):
        # the whole engine module must be clean under every ProxyLint rule
        # (no-sleep-poll flags ANY time.sleep here: serve/engine.py is a
        # designated notification-driven hot-path module)
        import repro.serve.engine as engine_mod
        from repro.analysis.lint import lint_paths

        violations = lint_paths([engine_mod.__file__])
        assert violations == [], "\n".join(v.render() for v in violations)
        # and the idle path is a condition-variable wait, not a poll
        src = inspect.getsource(ServeEngine.run)
        assert "cond.wait" in src

    @pytest.mark.multiproc(timeout=60)  # threads + watchdog: never wedge
    def test_gappy_stream_never_busy_waits(self):
        """2× slots requests with stream gaps: the loop runs ~one iteration
        per decode step / admission / wake — a 5 ms sleep-poll (the seed
        engine) or any busy-spin would add hundreds of iterations across
        the ~1.2 s of enforced gaps."""
        engine = make_engine(slots=2)
        s = make_streams()
        rng = np.random.default_rng(6)
        n = 4

        def client():
            for i in range(n):
                time.sleep(0.3)  # stream gap ≫ decode time
                send_request(
                    s["producer"], f"g{i}",
                    rng.integers(1, CFG.vocab, 4).astype(np.int32), 3,
                )
            s["producer"].close_topic("requests")

        t = threading.Thread(target=client)
        t.start()
        completed = engine.run(s["consumer"])
        t.join()
        assert len(completed) == n
        m = engine.metrics
        # every loop iteration is accounted for by real work or a wake
        assert m["loop_iters"] <= m["decode_steps"] + m["idle_waits"] + n + 4
        # idle wakes are notifications (+ the bounded shutdown tick), not a
        # poll: ~1.2 s of gaps at the seed's 5 ms poll would be ~240
        assert m["idle_waits"] <= 6 * n
        engine.close()

    def test_decode_not_delayed_by_open_stream(self):
        """With the request stream still open but slots active, the loop
        decodes instead of blocking on the consumer (the decode deadline)."""
        engine = make_engine(slots=2)
        s = make_streams()
        send_request(
            s["producer"], "now", np.asarray([1, 2, 3], np.int32), 5
        )
        done = {}

        def finish_later():
            time.sleep(2.5)
            s["producer"].close_topic("requests")

        t = threading.Thread(target=finish_later)
        t.start()
        t0 = time.perf_counter()
        completed = engine.run(s["consumer"])
        done["wall"] = time.perf_counter() - t0
        t.join()
        assert "now" in completed
        # the request itself decoded long before the topic closed: its
        # latency must not include the 2.5 s close delay (2.0 leaves
        # headroom for jit warmup + ProxySan stack-capture overhead)
        assert completed["now"]["latency"] < 2.0
        engine.close()


class TestFailurePaths:
    def test_engine_exception_kills_puller_and_frees_the_stream(self):
        """A decode failure must not orphan the puller thread: requests
        published after the crash stay on the stream for the next engine
        instead of being stolen into the dead run's pending deque."""
        engine = make_engine(slots=2)
        s = make_streams()
        send_request(s["producer"], "boom", np.asarray([1, 2, 3], np.int32), 4)

        def explode(*a, **k):
            raise RuntimeError("injected decode failure")

        engine._decode = explode
        with pytest.raises(RuntimeError, match="injected"):
            engine.run(s["consumer"])
        engine.close()
        # the crashed run's puller is gone: this request must be served by
        # a fresh engine on the same consumer, not swallowed by an orphan
        send_request(s["producer"], "after", np.asarray([4, 5], np.int32), 3)
        s["producer"].close_topic("requests")
        engine2 = make_engine(slots=2)
        completed = engine2.run(s["consumer"])
        assert "after" in completed
        engine2.close()

    def test_malformed_request_rejected_not_fatal(self):
        """A request whose bulk can't be used (missing 'prompt') becomes a
        per-request rejection; other tenants' requests still serve and the
        run completes — no dead puller, no engine-wide abort."""
        engine = make_engine(slots=2)
        s = make_streams()
        s["producer"].send(
            "requests", {"noprompt": True},
            metadata={"req_id": "bad", "max_new_tokens": 3},
        )
        s["producer"].flush_topic("requests")
        send_request(s["producer"], "good", np.asarray([1, 2, 3], np.int32), 3)
        s["producer"].close_topic("requests")
        completed = engine.run(s["consumer"], s["resp_producer"])
        assert "bad" in engine.rejected and "bad" not in completed
        assert completed["good"]["tokens"] == reference_decode(
            CFG, np.asarray([1, 2, 3], np.int32), 3, max_len=32
        )
        engine.close()

    def test_unaddressable_event_counted_not_fatal(self):
        """An event with no req_id can't be rejected back — it is counted
        and skipped, and the run still completes."""
        engine = make_engine(slots=2)
        s = make_streams()
        s["producer"].send("requests", {"prompt": [1, 2]}, metadata={})
        s["producer"].flush_topic("requests")
        send_request(s["producer"], "ok", np.asarray([4, 5], np.int32), 2)
        s["producer"].close_topic("requests")
        completed = engine.run(s["consumer"])
        assert engine.metrics["malformed_events"] == 1
        assert "ok" in completed
        engine.close()
        # the skipped event's bulk was reclaimed, not left resident forever
        # (nobody else ever pulls this topic)
        req_store = s["producer"].store_for("requests")
        assert list(req_store.connector.keys()) == []

    def test_stream_level_failure_still_fatal(self):
        """A broker/subscriber failure (not one request's fault) aborts
        the run loudly — that one must never be swallowed."""
        engine = make_engine(slots=2)
        s = make_streams()

        def broken(timeout=None):
            raise RuntimeError("broker down")

        s["consumer"].subscriber.next_event = broken
        with pytest.raises(RuntimeError, match="broker down"):
            engine.run(s["consumer"])
        engine.close()

    def test_duplicate_req_id_rejected_not_fatal(self):
        """A req_id colliding with a live sequence is rejected onto the
        response stream; the original request is unaffected."""
        engine = make_engine(slots=2)
        prompt = np.asarray([1, 2, 3], np.int32)
        s = make_streams()  # sent manually: serve() keys by id (would dedup)
        send_request(s["producer"], "dup", prompt, 8)
        send_request(s["producer"], "dup", prompt, 8)
        s["producer"].close_topic("requests")
        completed = engine.run(s["consumer"], s["resp_producer"])
        assert "dup" in engine.rejected  # the second one
        assert completed["dup"]["tokens"] == reference_decode(
            CFG, prompt, 8, max_len=32
        )
        engine.close()

    def test_pull_ahead_is_bounded(self):
        """The puller resolves at most 2×slots requests ahead of admission
        (the seed engine's slots-bounded drain, kept): a deep request
        backlog must not materialize every prompt into memory."""
        engine = make_engine(slots=2)
        rng = np.random.default_rng(9)
        reqs = {
            f"b{i}": (rng.integers(1, CFG.vocab, 4).astype(np.int32), 3)
            for i in range(12)
        }
        completed, _ = serve(engine, reqs)
        assert sorted(completed) == sorted(reqs)
        assert 0 < engine.metrics["max_pending"] <= 2 * len(engine.slots)
        engine.close()

    def test_free_sequence_while_borrowed_is_retryable(self):
        """A rejected free (outstanding borrow) must leave the sequence
        intact — no leaked pages, no wedged retry."""
        from repro.core.ownership import OwnershipError, borrow, release
        from repro.serve.kvcache import PageTable

        store = Store(f"fb-{new_key()}")
        pt = PageTable(num_pages=8, page_size=4, store=store, page_bytes=16)
        pt.allocate("b", 6)
        ref = borrow(pt._owners["b"])
        with pytest.raises(OwnershipError):
            pt.free_sequence("b")
        assert "b" in pt.live_sequences()  # nothing mutated
        assert pt.pages_in_use() == 2
        release(ref)
        pt.free_sequence("b")  # retry succeeds
        assert pt.pages_free() == 8 and not pt.live_sequences()
        store.close()

    def test_close_spares_a_caller_provided_store(self):
        shared = Store(f"shared-{new_key()}")
        shared.put({"keep": 1}, key="other-data")
        ctx = serve_context(CFG)
        engine = ServeEngine(
            ctx, {}, slots=1, max_len=32, page_size=4,
            model=CountingModel(CFG), kv_store=shared,
        )
        engine.close()
        assert shared.get("other-data") == {"keep": 1}  # store untouched
        shared.close()


class TestServeSharding:
    def test_serve_profile_shards_kv_seq_over_model_axis(self):
        """The serve rules profile resolves the cache's kv_seq axis onto
        the model mesh axis (dict-mesh unit form of the production mesh)."""
        from repro.dist.sharding import RULE_PROFILES, logical_to_spec

        serve_rules, _ = RULE_PROFILES["serve"]
        spec = logical_to_spec(
            (4, 64, 2, 8),
            ("batch", "kv_seq", "kv_heads", None),
            serve_rules,
            {"data": 2, "model": 16},
        )
        assert spec[1] == "model"  # kv_seq claims the model axis
        default_rules, _ = RULE_PROFILES["default"]
        dspec = logical_to_spec(
            (4, 64, 2, 8),
            ("batch", "kv_seq", "kv_heads", None),
            default_rules,
            {"data": 2, "model": 16},
        )
        assert dspec[1] != "model"

    def test_engine_context_uses_serve_rules(self):
        ctx = serve_context(CFG)
        assert "serve" in ctx.rules.name
        assert ctx.rules.get("kv_seq") == ("model",)

    def test_engine_applies_cache_shardings(self):
        engine = make_engine(slots=2)
        engine._ensure_cache()
        import jax

        leaves = jax.tree.leaves(engine._cache)
        shard_leaves = jax.tree.leaves(
            engine._cache_shardings,
            is_leaf=lambda x: hasattr(x, "mesh"),
        )
        assert len(leaves) == len(shard_leaves)
        for leaf, sh in zip(leaves, shard_leaves):
            assert leaf.sharding.is_equivalent_to(sh, leaf.ndim)
        engine.close()


class TestPageOwnership:
    def test_free_sequence_releases_store_memory(self):
        """Finishing a sequence evicts its per-page KV cells — the store
        holds zero bytes for it afterwards (the ownership claim, now at
        the store level, not just the free-list level)."""
        engine = make_engine(slots=2, max_len=32, page_size=4)
        store = engine.kv_store
        completed, _ = serve(
            engine, {"s": (np.asarray([1, 2, 3, 4, 5], np.int32), 6)}
        )
        assert completed["s"]["tokens"]
        assert engine.pages.pages_in_use() == 0
        for p in range(engine.pages.num_pages):
            assert not store.exists(engine.pages.page_key("s", p))
        assert not store.exists("pages-s")
        engine.close()

    def test_kv_cells_exist_while_sequence_live(self):
        from repro.serve.kvcache import PageTable

        store = Store(f"pt-{new_key()}")
        pt = PageTable(num_pages=8, page_size=4, store=store, page_bytes=64)
        pages = pt.allocate("seq", 6)  # 2 pages
        assert len(pages) == 2
        for p in pages:
            assert store.exists(pt.page_key("seq", p))
            assert len(store.get(pt.page_key("seq", p))) == 64
        pt.extend("seq", 9)  # 3rd page
        assert pt.pages_in_use() == 3
        pt.free_sequence("seq")
        assert pt.pages_free() == 8
        store.close()

    def test_page_bytes_sized_from_model_cache(self):
        engine = make_engine(slots=2, max_len=32, page_size=4)
        # CountingModel cache: 1 float32 per token per (L=1) layer
        assert engine.pages.page_bytes == 4 * 1 * np.dtype(CFG.dtype).itemsize
        engine.close()


class TestLaunchServe:
    """The launch driver end to end, in-process (the PR 5 exit-path
    regression: a blocked client must never deadlock the driver, and every
    page must be back in the pool at exit)."""

    @pytest.mark.multiproc(timeout=240)  # watchdog: a wedged driver fails fast
    def test_launch_serve_smoke_exits_clean(self, capsys):
        from repro.launch import serve as launch_serve

        rc = launch_serve.main(
            ["--requests", "5", "--slots", "2", "--max-new", "4",
             "--max-len", "32", "--prompt-len", "6"]
        )
        out = capsys.readouterr().out
        assert rc == 0, out
        # rc==0 already implies it, but pin the exit-path claims explicitly
        assert "pages in use at exit: 0" in out
        assert "5/5 requests" in out


class TestPagedDecode:
    """The paged pool rework: batched prefill admission, prefix sharing
    with copy-on-write, orphaned shared pages, and the dense fallback —
    all bit-identical to the sequential reference."""

    def test_batched_admission_bit_identical(self):
        """A backlog admitted into 4 free slots goes through ONE padded
        prefill + one multi-page insert, and changes no tokens."""
        rng = np.random.default_rng(7)
        engine = make_engine(slots=4, max_len=32, page_size=4)
        reqs = {
            f"b{i}": (rng.integers(1, CFG.vocab, 3 + i).astype(np.int32), 6)
            for i in range(4)
        }
        completed, _ = serve(engine, reqs)
        for rid, (prompt, max_new) in reqs.items():
            assert completed[rid]["tokens"] == reference_decode(
                CFG, prompt, max_new, max_len=32
            ), rid
        assert engine.metrics["batched_prefills"] >= 1
        engine.close()

    def test_batched_prefill_off_still_correct(self):
        rng = np.random.default_rng(8)
        engine = make_engine(slots=4, max_len=32, batch_prefill=False)
        reqs = {
            f"s{i}": (rng.integers(1, CFG.vocab, 4).astype(np.int32), 5)
            for i in range(4)
        }
        completed, _ = serve(engine, reqs)
        for rid, (prompt, max_new) in reqs.items():
            assert completed[rid]["tokens"] == reference_decode(
                CFG, prompt, max_new, max_len=32
            ), rid
        assert engine.metrics["batched_prefills"] == 0
        engine.close()

    def test_prefix_sharing_aliases_full_pages(self):
        """Two prompts sharing a page-aligned prefix: the second borrows
        the first's pages (no duplicate allocation) and still decodes
        bit-identically."""
        common = np.asarray([5, 6, 7, 8], np.int32)  # exactly one page
        p1 = np.concatenate([common, [1, 2, 3]]).astype(np.int32)
        p2 = np.concatenate([common, [9, 9]]).astype(np.int32)
        engine = make_engine(slots=2, max_len=32, page_size=4)
        completed, _ = serve(engine, {"a": (p1, 5), "b": (p2, 5)})
        for rid, (prompt, max_new) in {"a": (p1, 5), "b": (p2, 5)}.items():
            assert completed[rid]["tokens"] == reference_decode(
                CFG, prompt, max_new, max_len=32
            ), rid
        assert engine.metrics["prefix_shared_pages"] >= 1
        # everything reclaimed: shared refcounts drained to zero
        assert engine.pages.pages_in_use() == 0
        assert engine.pages.pages_free() == engine.pages.num_pages
        engine.close()

    def test_prefix_sharing_cow_on_divergent_boundary_page(self):
        """A prefix that ends mid-page triggers copy-on-write — at
        allocation when the prompt already diverges inside the boundary
        page, at first extend when it diverges later.  Neither changes a
        token of either sequence."""
        # lcp = 6 ends inside page 2 (page_size 4); "c" diverges at
        # allocate, "d" only when its decode extends past the prefix
        p1 = np.asarray([5, 6, 7, 8, 1, 2, 3], np.int32)
        p_div = np.asarray([5, 6, 7, 8, 1, 2, 9, 9], np.int32)
        p_ext = np.asarray([5, 6, 7, 8, 1, 2], np.int32)
        engine = make_engine(slots=3, max_len=32, page_size=4)
        reqs = {"a": (p1, 5), "c": (p_div, 5), "d": (p_ext, 5)}
        completed, _ = serve(engine, reqs)
        for rid, (prompt, max_new) in reqs.items():
            assert completed[rid]["tokens"] == reference_decode(
                CFG, prompt, max_new, max_len=32
            ), rid
        assert engine.metrics["prefix_shared_pages"] >= 2
        assert engine.metrics["cow_page_copies"] >= 2
        assert engine.pages.pages_in_use() == 0
        engine.close()

    def test_parent_finishing_first_orphans_then_reclaims(self):
        """The prefix creator finishes while a borrower still decodes: the
        shared cells outlive their creator (orphaned, not freed) and the
        borrower's tokens are unaffected; the pool and store drain fully
        once the borrower finishes."""
        common = np.asarray([3, 1, 4, 1, 5, 9, 2, 6], np.int32)  # 2 pages
        p_parent = common
        p_child = np.concatenate([common, [7, 7]]).astype(np.int32)
        engine = make_engine(slots=2, max_len=32, page_size=4)
        store = engine.kv_store
        reqs = {"parent": (p_parent, 1), "child": (p_child, 8)}
        completed, _ = serve(engine, reqs)
        assert completed["child"]["tokens"] == reference_decode(
            CFG, p_child, 8, max_len=32
        )
        assert engine.metrics["prefix_shared_pages"] >= 2
        assert engine.pages.pages_in_use() == 0
        assert engine.pages.orphan_pages() == set()
        assert sorted(engine.pages._free) == list(range(engine.pages.num_pages))
        for key in list(getattr(store, "_data", {})) or []:
            assert not str(key).startswith("kvpage-")
        engine.close()

    def test_dense_fallback_bit_identical(self):
        """paged=False keeps the dense (L, B, S, ...) layout end to end."""
        rng = np.random.default_rng(9)
        engine = make_engine(slots=2, max_len=32, paged=False)
        assert engine.paged is False
        reqs = {
            f"d{i}": (rng.integers(1, CFG.vocab, 5).astype(np.int32), 6)
            for i in range(3)
        }
        completed, _ = serve(engine, reqs)
        for rid, (prompt, max_new) in reqs.items():
            assert completed[rid]["tokens"] == reference_decode(
                CFG, prompt, max_new, max_len=32
            ), rid
        engine.close()

    def test_indivisible_page_size_falls_back_to_dense(self):
        engine = make_engine(slots=1, max_len=30, page_size=4)
        assert engine.paged is False
        prompt = np.asarray([1, 2, 3], np.int32)
        completed, _ = serve(engine, {"x": (prompt, 4)})
        assert completed["x"]["tokens"] == reference_decode(
            CFG, prompt, 4, max_len=30
        )
        engine.close()

    def test_pool_cache_is_page_granular(self):
        """The device cache is (L, P+1, page_size, ...) — page pool plus
        one null scratch page — not (L, B, max_len, ...)."""
        engine = make_engine(slots=2, max_len=32, page_size=4)
        engine._ensure_cache()
        leaf = engine._cache["hist"]
        assert leaf.shape[1] == engine._null_page + 1
        assert leaf.shape[2] == engine.pages.page_size
        engine.close()
