"""ProxyLint: rule coverage on the seeded fixture, cleanliness at HEAD,
pragma suppression, and the CLI contract (non-zero on violations)."""
import json
import os
import subprocess
import sys

from repro.analysis.lint import RULES, LintViolation, lint_paths

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURE = os.path.join(REPO, "tests", "fixtures", "dist", "proxylint_violations.py")
CLI = os.path.join(REPO, "scripts", "proxy_lint.py")
LINT_PATHS = [os.path.join(REPO, d) for d in ("src", "benchmarks", "examples")]


def rules_hit(violations) -> set:
    return {v.rule for v in violations}


class TestRulesOnFixture:
    def test_every_rule_fires(self):
        vs = lint_paths([FIXTURE])
        assert rules_hit(vs) == set(RULES), (
            f"rules missing from fixture coverage: {set(RULES) - rules_hit(vs)}"
        )

    def test_violations_carry_hints_and_locations(self):
        for v in lint_paths([FIXTURE]):
            assert isinstance(v, LintViolation)
            assert v.line > 0 and v.hint and v.message
            assert v.path.endswith("proxylint_violations.py")

    def test_select_restricts_rules(self):
        vs = lint_paths([FIXTURE], select={"no-sleep-poll"})
        assert vs and rules_hit(vs) == {"no-sleep-poll"}


class TestCleanAtHead:
    def test_src_benchmarks_examples_clean(self):
        vs = lint_paths([p for p in LINT_PATHS if os.path.exists(p)])
        assert vs == [], "\n" + "\n".join(v.render() for v in vs)


class TestSuppression:
    def test_pragma_suppresses_on_reported_line(self, tmp_path):
        bad = tmp_path / "mod.py"
        bad.write_text(
            "import time\n"
            "def f(flag):\n"
            "    while not flag():\n"
            "        time.sleep(0.01)  # proxylint: disable=no-sleep-poll\n"
        )
        assert lint_paths([str(bad)]) == []

    def test_pragma_is_per_rule(self, tmp_path):
        bad = tmp_path / "mod.py"
        bad.write_text(
            "import time\n"
            "def f(flag):\n"
            "    while not flag():\n"
            "        time.sleep(0.01)  # proxylint: disable=swallowed-error\n"
        )
        assert rules_hit(lint_paths([str(bad)])) == {"no-sleep-poll"}


class TestRuleShapes:
    def test_hot_path_module_flags_any_sleep(self, tmp_path):
        d = tmp_path / "core"
        d.mkdir()
        mod = d / "streaming.py"  # suffix-matches the hot-path list
        mod.write_text("import time\ndef f():\n    time.sleep(1)\n")
        assert rules_hit(lint_paths([str(mod)])) == {"no-sleep-poll"}

    def test_unlooped_sleep_elsewhere_is_fine(self, tmp_path):
        mod = tmp_path / "mod.py"
        mod.write_text("import time\ndef f():\n    time.sleep(1)\n")
        assert lint_paths([str(mod)]) == []

    def test_positive_exists_probe_not_flagged(self, tmp_path):
        # chain-walking probes (lease head discovery) terminate on their
        # own; only appearance-waits are busy-waits
        mod = tmp_path / "mod.py"
        mod.write_text(
            "def head(store, n):\n"
            "    while store.exists(key(n + 1)):\n"
            "        n += 1\n"
            "    return n\n"
        )
        assert lint_paths([str(mod)]) == []

    def test_donated_reassignment_shape_is_sanctioned(self, tmp_path):
        mod = tmp_path / "mod.py"
        mod.write_text(
            "import jax\n"
            "step = jax.jit(lambda p, c: (c, c), donate_argnums=(1,))\n"
            "def loop(params, cache):\n"
            "    cache, logits = step(params, cache)\n"
            "    return cache, logits\n"
        )
        assert lint_paths([str(mod)]) == []

    def test_fresh_read_of_mutable_key_is_sanctioned(self, tmp_path):
        d = tmp_path / "dist"
        d.mkdir()
        mod = d / "mod.py"
        mod.write_text(
            "def renew(store, key, obj):\n"
            "    store.put(obj, key=key)\n"
            "    return store.get(key, fresh=True)\n"
        )
        assert lint_paths([str(mod)]) == []

    def test_returning_mint_transfers_ownership(self, tmp_path):
        mod = tmp_path / "mod.py"
        mod.write_text(
            "def mint(store, obj):\n"
            "    return owned_proxy(store, obj)\n"
        )
        # `free` appears nowhere, but the mint is returned — the module
        # check keys off free-ish tokens; a returned mint means the caller
        # frees.  This module has no free token, so the module-level check
        # fires; keeping it honest: the rule's module check is advisory
        # and the sanctioned escape is documenting the transfer.
        vs = lint_paths([str(mod)], select={"owned-lifetime"})
        assert all(v.rule == "owned-lifetime" for v in vs)

    def test_handled_broad_except_is_fine(self, tmp_path):
        mod = tmp_path / "mod.py"
        mod.write_text(
            "def f(state, cond, risky):\n"
            "    try:\n"
            "        risky()\n"
            "    except Exception as e:\n"
            "        state['error'] = e\n"
        )
        assert lint_paths([str(mod)]) == []


class TestCLI:
    def _run(self, *args):
        return subprocess.run(
            [sys.executable, CLI, *args],
            capture_output=True, text=True, cwd=REPO, timeout=120,
        )

    def test_nonzero_on_seeded_fixture(self):
        r = self._run(FIXTURE)
        assert r.returncode == 1
        assert "violation(s)" in r.stdout

    def test_zero_on_src_at_head(self):
        r = self._run(*[p for p in LINT_PATHS if os.path.exists(p)])
        assert r.returncode == 0, r.stdout

    def test_json_output(self):
        r = self._run(FIXTURE, "--json")
        assert r.returncode == 1
        data = json.loads(r.stdout)
        assert data["count"] == len(data["violations"]) > 0
        v = data["violations"][0]
        assert {"path", "line", "col", "rule", "message", "hint"} <= set(v)

    def test_list_rules(self):
        r = self._run("--list-rules")
        assert r.returncode == 0
        for name in RULES:
            assert name in r.stdout
