"""Lease-service tests: CAS registry/generations, fencing, expiry,
notification-driven watch — including the cross-process worker (PR 4).

The subprocess tests mirror the producer-subprocess pattern of
``tests/test_stream_fastpath.py``: the worker heartbeats over a
``FileConnector`` from its own interpreter while the parent's monitor
observes the live → dead → re-register transitions through the channel.
"""
from __future__ import annotations

import os
import subprocess
import sys
import threading
import time

import pytest

from repro.core import FileConnector, InMemoryConnector, Store
from repro.dist.fault import HeartbeatMonitor
from repro.dist.lease import (
    LeaseExpired,
    LeaseLost,
    LeaseService,
    MembershipSnapshot,
)


_OPEN_STORES: list[Store] = []


def _store(name, conn=None):
    s = Store(name, conn or InMemoryConnector(), register=False)
    _OPEN_STORES.append(s)
    return s


@pytest.fixture(autouse=True)
def _close_test_stores():
    """Close every helper-made store (and its in-memory namespace): lease
    registry chains persist for the service lifetime by design, so an
    unclosed test store reads as a pile of leaks in ProxySan's report."""
    yield
    while _OPEN_STORES:
        s = _OPEN_STORES.pop()
        for k in list(s.connector.keys()):  # FileConnector.close is a no-op
            s.evict(k)
        s.close()
        s.connector.close()


def _svc(conn=None, ttl=5.0, name=None):
    return LeaseService(_store(name or f"ls-{id(object())}", conn), ttl=ttl)


def _subprocess_env():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _wait_until(predicate, timeout, what):
    deadline = time.time() + timeout
    while not predicate():
        assert time.time() < deadline, f"timed out waiting for {what}"
        time.sleep(0.02)


# ---------------------------------------------------------------------------
# Core protocol
# ---------------------------------------------------------------------------


class TestLeaseProtocol:
    def test_register_renew_expire_reregister(self):
        svc = _svc(ttl=0.3)
        g = svc.register("w0")
        assert g == 1
        assert svc.live() == ["w0"]
        svc.renew("w0")
        time.sleep(0.45)
        assert svc.dead() == ["w0"]
        with pytest.raises(TimeoutError):  # LeaseExpired IS a TimeoutError
            svc.renew("w0")
        g2 = svc.register("w0")
        assert g2 == 2  # a fresh generation, not a resurrected lease
        assert svc.live() == ["w0"]

    def test_fencing_newer_generation_wins(self):
        """A re-registration fences the old owner out (split-brain guard)."""
        conn = InMemoryConnector()
        old = _svc(conn, ttl=5.0)
        new = _svc(conn, ttl=5.0)
        g1 = old.register("w0")
        g2 = new.register("w0")
        assert g2 == g1 + 1
        with pytest.raises(LeaseLost):
            old.renew("w0")  # stale generation must not silently renew
        new.renew("w0")  # the current owner still can

    def test_lease_carries_generation_and_expiry(self):
        svc = _svc(ttl=1.0)
        svc.register("w0")
        lease = svc.lease("w0")
        assert lease.worker == "w0" and lease.generation == 1
        assert lease.live()
        assert svc.lease("ghost") is None

    def test_snapshot_is_comparable(self):
        svc = _svc(ttl=5.0)
        a = svc.snapshot()
        assert isinstance(a, MembershipSnapshot)
        svc.register("w0")
        b = svc.snapshot()
        assert a != b and b.live == ("w0",)
        assert b == svc.snapshot()  # no membership event ⇒ equal snapshots

    def test_registry_concurrent_registration_race(self):
        """The PR 1 read-modify-write registry lost concurrent updates; the
        CAS-append chain must keep every racing registrant."""
        conn = InMemoryConnector()
        names = [f"w{i}" for i in range(8)]
        barrier = threading.Barrier(len(names))
        errors = []

        def reg(name):
            svc = _svc(conn, ttl=30.0, name=f"race-{name}")
            barrier.wait()
            try:
                svc.register(name)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=reg, args=(n,)) for n in names]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        assert not errors
        assert _svc(conn, ttl=30.0).members() == sorted(names)

    def test_heartbeat_monitor_api_preserved(self):
        """The PR 1 HeartbeatMonitor surface rides on the lease service."""
        store = _store(f"hbapi-{id(object())}")
        mon = HeartbeatMonitor(store, ttl=0.3)
        mon.register("a")
        mon.heartbeat("a")
        assert mon.live_workers() == ["a"]
        time.sleep(0.45)
        assert mon.dead_workers() == ["a"]
        with pytest.raises(TimeoutError):
            mon.heartbeat("a")
        mon.register("a")
        assert mon.live_workers() == ["a"]


# ---------------------------------------------------------------------------
# Watch (notification-driven membership subscription)
# ---------------------------------------------------------------------------


class TestWatch:
    def test_watch_wakes_on_registration(self):
        conn = InMemoryConnector()
        svc = _svc(conn, ttl=30.0)
        snap = svc.snapshot()
        woke = {}

        def watcher():
            t0 = time.perf_counter()
            woke["snap"] = svc.watch(snap, timeout=10.0)
            woke["dt"] = time.perf_counter() - t0

        th = threading.Thread(target=watcher)
        th.start()
        time.sleep(0.1)
        _svc(conn, ttl=30.0).register("w0")
        th.join(timeout=10)
        assert not th.is_alive()
        assert "w0" in woke["snap"].live
        assert woke["dt"] < 5.0  # notification wake, not the 10 s timeout

    def test_watch_returns_after_lease_deadline(self):
        """Deaths are the absence of writes: the watch deadline is capped at
        the earliest live-lease expiry, so an expired worker is noticed
        without any registration event."""
        svc = _svc(ttl=0.3)
        svc.register("w0")
        snap = svc.snapshot()
        assert snap.live == ("w0",)
        t0 = time.perf_counter()
        out = svc.watch(snap, timeout=10.0)
        assert time.perf_counter() - t0 < 5.0  # woke at the TTL, not the cap
        assert out.live == () and out.dead == ("w0",)

    def test_watch_changed_snapshot_returns_immediately(self):
        svc = _svc(ttl=30.0)
        stale = svc.snapshot()
        svc.register("w0")
        t0 = time.perf_counter()
        out = svc.watch(stale, timeout=10.0)
        assert time.perf_counter() - t0 < 1.0
        assert out.live == ("w0",)


# ---------------------------------------------------------------------------
# Cross-process: worker heartbeats from a subprocess over FileConnector
# ---------------------------------------------------------------------------


_XP_WORKER = """
import sys, time
from repro.core import FileConnector, Store
from repro.dist.lease import LeaseService

directory, name, ttl, beats = sys.argv[1], sys.argv[2], float(sys.argv[3]), int(sys.argv[4])
svc = LeaseService(
    Store(f"xp-worker-{name}", FileConnector(directory), register=False), ttl=ttl
)
svc.register(name)
for _ in range(beats):
    time.sleep(ttl / 4)
    svc.renew(name)
"""


@pytest.mark.multiproc
class TestCrossProcessLease:
    def test_subprocess_worker_live_dead_reregister(self, tmp_path):
        directory = str(tmp_path / "leases")
        ttl = 0.8
        monitor = LeaseService(
            _store("xp-monitor", FileConnector(directory)), ttl=ttl
        )
        proc = subprocess.Popen(
            [sys.executable, "-c", _XP_WORKER, directory, "w0", str(ttl), "6"],
            env=_subprocess_env(),
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        try:
            # live: the subprocess registered and keeps beating (~1.2 s)
            _wait_until(lambda: monitor.live() == ["w0"], 15, "worker live")
            gen_live = monitor.lease("w0").generation
            assert gen_live == 1
            # dead: the subprocess exits; its lease must lapse after ttl
            _wait_until(lambda: monitor.dead() == ["w0"], 15, "worker dead")
            assert monitor.live() == []
        finally:
            out, err = proc.communicate(timeout=30)
        assert proc.returncode == 0, err.decode()
        # re-register: a second incarnation claims the next generation
        proc2 = subprocess.Popen(
            [sys.executable, "-c", _XP_WORKER, directory, "w0", str(ttl), "2"],
            env=_subprocess_env(),
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        try:
            _wait_until(lambda: monitor.is_live("w0"), 15, "worker re-registered")
            assert monitor.lease("w0").generation == gen_live + 1
        finally:
            out, err = proc2.communicate(timeout=30)
        assert proc2.returncode == 0, err.decode()

    def test_parent_fences_subprocess_worker(self, tmp_path):
        """Parent re-registers the worker name mid-beat: the subprocess's
        next renewal must die on LeaseLost (exit code ≠ 0)."""
        directory = str(tmp_path / "fence")
        ttl = 1.0
        monitor = LeaseService(
            _store("xp-fencer", FileConnector(directory)), ttl=ttl
        )
        proc = subprocess.Popen(
            [sys.executable, "-c", _XP_WORKER, directory, "w0", str(ttl), "8"],
            env=_subprocess_env(),
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        try:
            _wait_until(lambda: monitor.is_live("w0"), 15, "worker live")
            monitor.register("w0")  # fence the subprocess out
        finally:
            out, err = proc.communicate(timeout=30)
        assert proc.returncode != 0
        assert b"LeaseLost" in err
