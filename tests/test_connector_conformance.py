"""Connector-protocol conformance: every connector, one contract.

Runs all five connectors — in-memory, file, shared-memory, TCP
store-server, tiered multi — through the same matrix: put/get/exists/
evict round trips, parts/batch/put-new atomicity, zero-copy views, wait
semantics (prompt wake, exact timeout), pickling.  Plus pins for the PR 9
connector-protocol bugfix sweep: fallback-wait timeout overshoot, fork
key-prefix reseeding, and the FileConnector wait_for_any stat storm.
"""
import os
import pickle
import threading
import time

import pytest

from repro.core import connectors as C
from repro.core.connectors import (
    FileConnector,
    InMemoryConnector,
    SharedMemoryConnector,
    channel_identity,
    new_key,
)
from repro.core.connectors_net import StoreServerConnector
from repro.core.multi import MultiConnector, Tier

from _store_server_util import store_server

KINDS = ["memory", "file", "shm", "server", "multi"]


@pytest.fixture(scope="module")
def server_address():
    with store_server("--backing", "memory:conformance") as (addr, _proc):
        yield addr


@pytest.fixture(params=KINDS)
def conn(request, tmp_path):
    kind = request.param
    if kind == "memory":
        c = InMemoryConnector(new_key())
    elif kind == "file":
        c = FileConnector(str(tmp_path / "fc"))
    elif kind == "shm":
        c = SharedMemoryConnector()
    elif kind == "server":
        addr = request.getfixturevalue("server_address")
        c = StoreServerConnector(addr, namespace=new_key())
    else:
        c = MultiConnector([
            Tier("hot", InMemoryConnector(new_key()), max_bytes=256),
            Tier("cold", FileConnector(str(tmp_path / "cold"))),
        ])
    yield c
    for k in list(getattr(c, "keys", lambda: ())()):
        c.evict(k)
    c.close()


class TestRoundTrips:
    def test_put_get_exists_evict(self, conn):
        assert not conn.exists("k")
        assert conn.get("k") is None
        conn.put("k", b"value")
        assert conn.exists("k")
        assert conn.get("k") == b"value"
        conn.evict("k")
        assert not conn.exists("k")
        assert conn.get("k") is None
        conn.evict("k")  # evicting a missing key is a no-op, not an error

    def test_overwrite_serves_latest(self, conn):
        conn.put("k", b"first")
        conn.put("k", b"second-and-longer")
        assert conn.get("k") == b"second-and-longer"
        conn.put("k", b"3")
        assert conn.get("k") == b"3"

    @pytest.mark.parametrize("size", [0, 1, 1024, 1 << 20])
    def test_payload_sizes(self, conn, size):
        data = os.urandom(size)
        conn.put("k", data)
        assert conn.get("k") == data

    def test_put_parts_and_payload(self, conn):
        parts = (b"head", b"x" * 1000, b"", b"tail")
        n = C.put_payload(conn, "p", parts)
        assert n == sum(len(p) for p in parts)
        payload = C.get_payload(conn, "p")
        joined = (
            b"".join(bytes(x) for x in payload)
            if isinstance(payload, (tuple, list))
            else bytes(payload)
        )
        assert joined == b"".join(parts)

    def test_put_batch(self, conn):
        items = [(f"b{i}", (bytes([i]) * (i * 100 + 1),)) for i in range(5)]
        total = C.put_batch_payloads(conn, items)
        assert total == sum(len(p[0]) for _, p in items)
        for key, parts in items:
            assert conn.get(key) == parts[0]

    def test_put_new_is_first_writer_wins(self, conn):
        assert C.put_payload_new(conn, "n", (b"first",)) == 5
        assert C.put_payload_new(conn, "n", (b"loser",)) is None
        assert conn.get("n") == b"first"
        conn.evict("n")
        assert C.put_payload_new(conn, "n", (b"again",)) == 5

    def test_get_view(self, conn):
        data = os.urandom(2048)
        conn.put("v", data)
        view = C.get_view(conn, "v")
        assert view is not None
        assert bytes(view) == data
        assert C.get_view(conn, "missing") is None


class TestWaits:
    def test_wait_for_present_returns_immediately(self, conn):
        conn.put("w", b"x")
        t0 = time.monotonic()
        C.wait_for(conn, "w", timeout=5.0)
        assert time.monotonic() - t0 < 1.0

    def test_wait_for_late_put_wakes(self, conn):
        def later():
            time.sleep(0.15)
            conn.put("late", b"x")

        threading.Thread(target=later, daemon=True).start()
        t0 = time.monotonic()
        C.wait_for(conn, "late", timeout=10.0)
        dt = time.monotonic() - t0
        assert 0.1 < dt < 5.0

    def test_wait_for_timeout_is_exact(self, conn):
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            C.wait_for(conn, "never", timeout=0.25)
        dt = time.monotonic() - t0
        assert 0.24 <= dt < 1.0, dt

    def test_wait_for_any_returns_winner(self, conn):
        def later():
            time.sleep(0.15)
            conn.put("win", b"x")

        threading.Thread(target=later, daemon=True).start()
        keys = [f"lose{i}" for i in range(20)] + ["win"]
        assert C.wait_for_any(conn, keys, timeout=10.0) == "win"

    def test_wait_for_any_timeout_is_shared(self, conn):
        # ONE deadline across the whole set: 30 keys must not multiply it
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            C.wait_for_any(conn, [f"k{i}" for i in range(30)], timeout=0.25)
        dt = time.monotonic() - t0
        assert 0.24 <= dt < 1.0, dt


class TestChannel:
    def test_pickle_round_trip(self, conn):
        conn.put("pk", b"payload")
        clone = pickle.loads(pickle.dumps(conn))
        try:
            assert clone.get("pk") == b"payload"
            assert channel_identity(clone) == channel_identity(conn)
        finally:
            if clone is not conn and not isinstance(clone, MultiConnector):
                # MultiConnector.close closes the shared child connectors
                clone.close()

    def test_channel_identity_is_stable(self, conn):
        assert channel_identity(conn) == channel_identity(conn)
        other = InMemoryConnector(new_key())
        assert channel_identity(conn) != channel_identity(other)
        other.close()


# ---------------------------------------------------------------------------
# Bugfix pins (the PR 9 sweep)
# ---------------------------------------------------------------------------


class _BytesOnly:
    """Minimal connector: exercises every duck-typed fallback path."""

    def __init__(self):
        self.d = {}

    def put(self, key, data):
        self.d[key] = bytes(data)

    def get(self, key):
        return self.d.get(key)

    def exists(self, key):
        return key in self.d

    def evict(self, key):
        self.d.pop(key, None)

    def close(self):
        self.d.clear()


class TestFallbackWaitTimeout:
    """Pin: fallback waits never overshoot ``timeout`` by a backoff step."""

    def test_wait_for_clamps_final_sleep(self):
        c = _BytesOnly()
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            # aggressive backoff: unclamped sleeps would run 0.05+0.1+0.2
            # = 0.35s+ against a 0.25s budget
            C.wait_for(c, "never", timeout=0.25, poll_min=0.05, poll_max=1.0)
        dt = time.monotonic() - t0
        assert 0.24 <= dt < 0.35, dt

    def test_wait_for_any_clamps_final_sleep(self):
        c = _BytesOnly()
        keys = [f"k{i}" for i in range(50)]
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            C.wait_for_any(c, keys, timeout=0.25, poll_min=0.05, poll_max=1.0)
        dt = time.monotonic() - t0
        assert 0.24 <= dt < 0.35, dt

    def test_wait_for_any_late_key_still_prompt(self):
        c = _BytesOnly()

        def later():
            time.sleep(0.1)
            c.put("k49", b"x")

        threading.Thread(target=later, daemon=True).start()
        won = C.wait_for_any(c, [f"k{i}" for i in range(50)], timeout=5.0)
        assert won == "k49"


class TestForkKeyUniqueness:
    """Pin: ``new_key()`` reseeds its prefix in forked children."""

    @pytest.mark.skipif(not hasattr(os, "fork"), reason="needs fork")
    def test_forked_children_generate_disjoint_keys(self):
        n = 200
        readers = []
        pids = []
        for _ in range(2):
            r, w = os.pipe()
            pid = os.fork()
            if pid == 0:  # child
                os.close(r)
                try:
                    payload = "\n".join(new_key() for _ in range(n)).encode()
                    os.write(w, payload)
                finally:
                    os.close(w)
                    os._exit(0)
            os.close(w)
            readers.append(r)
            pids.append(pid)
        parent_keys = {new_key() for _ in range(n)}
        sets = [parent_keys]
        for r, pid in zip(readers, pids):
            chunks = []
            while True:
                b = os.read(r, 65536)
                if not b:
                    break
                chunks.append(b)
            os.close(r)
            os.waitpid(pid, 0)
            child_keys = set(b"".join(chunks).decode().split("\n"))
            assert len(child_keys) == n
            sets.append(child_keys)
        for i in range(len(sets)):
            for j in range(i + 1, len(sets)):
                assert not (sets[i] & sets[j]), (i, j)


class TestFileWaitAnyStatStorm:
    """Pin: FileConnector.wait_for_any stats the directory, not every key."""

    class _CountingFileConnector(FileConnector):
        def __init__(self, directory):
            super().__init__(directory)
            self.exists_calls = 0

        def exists(self, key):
            self.exists_calls += 1
            return super().exists(key)

    def test_ready_sweep_uses_one_listdir(self, tmp_path):
        c = self._CountingFileConnector(str(tmp_path / "fc"))
        keys = [f"k{i}" for i in range(500)]
        c.put("k499", b"x")
        c.exists_calls = 0
        assert c.wait_for_any(keys, timeout=5.0) == "k499"
        # the wide sweep must not degrade to per-key stat(2) calls
        assert c.exists_calls == 0

    def test_late_put_with_wide_key_set(self, tmp_path):
        c = self._CountingFileConnector(str(tmp_path / "fc"))
        keys = [f"k{i}" for i in range(500)]

        def later():
            time.sleep(0.1)
            c.put("k250", b"x")

        threading.Thread(target=later, daemon=True).start()
        c.exists_calls = 0
        assert c.wait_for_any(keys, timeout=10.0) == "k250"
        assert c.exists_calls == 0
