"""Hot-path regression tests: framing, zero-copy views, resolve cache,
metrics symmetry, reattach atomicity/fidelity, shm segment reuse, and the
cross-process stream path (PR 2)."""
from __future__ import annotations

import os
import pickle
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import (
    FileConnector,
    FileLogPublisher,
    FileLogSubscriber,
    InMemoryConnector,
    SharedMemoryConnector,
    Store,
    StreamConsumer,
    StreamProducer,
    extract,
    framing,
    free,
    owned_proxy,
    reset,
)
from repro.core.connectors import get_view, put_payload
from repro.core.store import _STORE_REGISTRY, default_serializer


@pytest.fixture()
def store():
    with Store(f"hot-{id(object())}", InMemoryConnector()) as s:
        yield s


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------


class TestFraming:
    @pytest.mark.parametrize(
        "obj",
        [
            np.arange(100, dtype=np.float64),
            np.zeros((8, 8), dtype=np.int32),
            np.array(3.5),
            {"a": np.ones(16), "b": [1, "x", None]},
            [np.arange(4, dtype=np.uint8), np.arange(4, dtype=np.float32)],
            "plain string",
            12345,
            b"raw bytes",
        ],
    )
    def test_roundtrip(self, obj):
        parts = framing.encode(obj)
        out = framing.decode(framing.join_parts(parts))
        if isinstance(obj, np.ndarray):
            np.testing.assert_array_equal(out, obj)
        elif isinstance(obj, dict):
            np.testing.assert_array_equal(out["a"], obj["a"])
            assert out["b"] == obj["b"]
        elif isinstance(obj, list) and isinstance(obj[0], np.ndarray):
            for got, want in zip(out, obj):
                np.testing.assert_array_equal(got, want)
        else:
            assert out == obj

    def test_bare_array_uses_array_frame(self):
        arr = np.arange(1000, dtype=np.float64)
        parts = framing.encode(arr)
        # dtype/shape header + one raw buffer (no pickle stream at all), and
        # the raw buffer is a view over the array's own memory (no copy)
        assert len(parts) == 2
        assert bytes(parts[0][:4]) == framing.MAGIC_ARR
        raw = parts[-1]
        assert isinstance(raw, memoryview)
        assert raw.nbytes == arr.nbytes
        assert np.shares_memory(np.frombuffer(raw, dtype=np.float64), arr)

    def test_nested_array_buffers_out_of_band(self):
        arr = np.arange(1000, dtype=np.float64)
        parts = framing.encode({"a": arr})
        # generic frame: header + pickle stream + one out-of-band raw buffer
        assert bytes(parts[0][:4]) == framing.MAGIC
        assert len(parts) == 3
        raw = parts[-1]
        assert isinstance(raw, memoryview)
        assert raw.nbytes == arr.nbytes
        assert np.shares_memory(np.frombuffer(raw, dtype=np.float64), arr)

    def test_decode_is_zero_copy(self):
        arr = np.arange(256, dtype=np.float64)
        data = framing.join_parts(framing.encode(arr))
        out = framing.decode(memoryview(data))
        # reconstructed over the channel view, not copied out of it
        assert not out.flags.owndata
        assert not out.flags.writeable
        np.testing.assert_array_equal(out, arr)

    def test_legacy_plain_pickle_accepted(self):
        legacy = pickle.dumps({"old": [1, 2, 3]}, protocol=pickle.HIGHEST_PROTOCOL)
        assert framing.decode(legacy) == {"old": [1, 2, 3]}

    def test_non_contiguous_array_falls_back(self):
        arr = np.arange(100, dtype=np.float64)[::2]  # strided view
        out = framing.decode(framing.join_parts(framing.encode(arr)))
        np.testing.assert_array_equal(out, arr)

    def test_estimated_nbytes(self):
        arr = np.zeros(1000, dtype=np.float64)
        assert framing.estimated_nbytes(arr) == arr.nbytes  # no serialization
        assert framing.estimated_nbytes(list(range(1000))) > 1000


# ---------------------------------------------------------------------------
# Connector view/vectored paths
# ---------------------------------------------------------------------------


class TestConnectorViews:
    @pytest.mark.parametrize("kind", ["memory", "file", "shm"])
    def test_put_parts_and_get_view(self, kind, tmp_path):
        if kind == "memory":
            c = InMemoryConnector()
        elif kind == "file":
            c = FileConnector(str(tmp_path / "s"))
        else:
            c = SharedMemoryConnector()
        try:
            parts = [b"head", memoryview(b"middle"), b"tail"]
            n = put_payload(c, "k", parts)
            assert n == len(b"headmiddletail")
            view = get_view(c, "k")
            assert isinstance(view, memoryview)
            assert bytes(view) == b"headmiddletail"
            assert c.get("k") == b"headmiddletail"  # bytes path agrees
            assert get_view(c, "missing") is None
            del view
            c.evict("k")
        finally:
            c.close()

    def test_shm_recreate_reuses_segment_when_payload_fits(self):
        from multiprocessing import shared_memory

        c = SharedMemoryConnector()
        try:
            c.put("k", b"x" * 4096)
            seg = shared_memory.SharedMemory(name=c._name("k"))
            big_size = seg.size
            seg.close()
            c.put("k", b"y" * 10)  # smaller: must reuse, not unlink+create
            seg = shared_memory.SharedMemory(name=c._name("k"))
            assert seg.size == big_size  # same segment survived
            seg.close()
            assert c.get("k") == b"y" * 10  # header masks stale tail bytes
            c.put("k", b"z" * (2 * big_size))  # larger: replaced
            assert c.get("k") == b"z" * (2 * big_size)
            c.evict("k")
        finally:
            c.close()

    def test_file_connector_mmap_view(self, tmp_path):
        c = FileConnector(str(tmp_path / "s"))
        payload = np.arange(512, dtype=np.int64)
        with Store(f"mm-{id(c)}", c) as s:
            key = s.put(payload)
            view = get_view(c, key)
            out = framing.decode(view)
            np.testing.assert_array_equal(out, payload)
            # evict while mapped is safe on Linux; the view stays readable
            del out
            c.evict(key)
            assert c.get(key) is None


# ---------------------------------------------------------------------------
# Shm attach cache (open/attach amortization)
# ---------------------------------------------------------------------------


@pytest.mark.skipif(not os.path.isdir("/dev/shm"), reason="needs /dev/shm")
class TestShmAttachCache:
    """``get``/``exists`` amortize shm_open+mmap across calls: one cached
    read-only attachment per segment *generation* (the /dev/shm inode),
    invalidated on local evict/replace and on cross-process recreate."""

    def _count_attaches(self, monkeypatch):
        import multiprocessing.shared_memory as shm_mod

        calls = []
        real = shm_mod.SharedMemory

        class Counting(real):
            def __init__(self, *a, **kw):
                if not kw.get("create", False):
                    calls.append(kw.get("name", a[0] if a else None))
                super().__init__(*a, **kw)

        monkeypatch.setattr(shm_mod, "SharedMemory", Counting)
        return calls

    def test_polling_reads_attach_once(self, monkeypatch):
        calls = self._count_attaches(monkeypatch)
        c = SharedMemoryConnector()
        try:
            c.put("k", b"payload")
            base = len(calls)
            for _ in range(10):
                assert c.exists("k")
                assert c.get("k") == b"payload"
            assert len(calls) == base + 1  # 20 reads, one attach
            c.evict("k")
        finally:
            c.close()

    def test_evict_drops_cached_attachment(self):
        c = SharedMemoryConnector()
        try:
            c.put("k", b"v")
            assert c.get("k") == b"v"
            assert "k" in c._attached
            c.evict("k")
            assert "k" not in c._attached
            assert c.get("k") is None
            assert not c.exists("k")
        finally:
            c.close()

    def test_cross_process_recreate_detected_by_inode(self):
        # a second connector on the same namespace stands in for another
        # process: its evict+recreate changes the /dev/shm inode, which the
        # first connector's stat check must treat as a new generation
        c = SharedMemoryConnector()
        peer = SharedMemoryConnector(c.namespace)
        try:
            c.put("k", b"old")
            assert c.get("k") == b"old"  # fills the attach cache
            peer.evict("k")
            peer.put("k", b"new!")
            assert c.get("k") == b"new!"  # stale mapping not served
            assert c.exists("k")
        finally:
            c.evict("k")
            for x in (c, peer):
                x.close()

    def test_in_place_overwrite_visible_through_cache(self):
        # same-size overwrite reuses the segment (same inode): the cached
        # mapping aliases the shared pages, so new bytes show through it
        c = SharedMemoryConnector()
        peer = SharedMemoryConnector(c.namespace)
        try:
            c.put("k", b"x" * 64)
            assert c.get("k") == b"x" * 64
            peer.put("k", b"y" * 8)  # fits: rewritten in place
            assert c.get("k") == b"y" * 8
        finally:
            c.evict("k")
            for x in (c, peer):
                x.close()


# ---------------------------------------------------------------------------
# Resolve cache
# ---------------------------------------------------------------------------


class _CountingConnector(InMemoryConnector):
    def __init__(self, namespace=None):
        super().__init__(namespace)
        self.gets = 0

    def get_view(self, key):
        self.gets += 1
        return super().get_view(key)

    def get_parts(self, key):
        self.gets += 1
        return super().get_parts(key)

    def get(self, key):
        self.gets += 1
        return super().get(key)


class TestResolveCache:
    def test_warm_resolve_skips_connector(self):
        c = _CountingConnector()
        with Store(f"rc-{id(c)}", c) as s:
            p = s.proxy([1, 2, 3])
            assert extract(p) == [1, 2, 3]
            assert c.gets == 1
            reset(p)
            assert extract(p) == [1, 2, 3]  # served from the resolve cache
            assert c.gets == 1
            assert s.metrics.cache_hits == 1
            assert s.metrics.cache_misses == 1

    def test_store_get_uses_cache(self):
        c = _CountingConnector()
        with Store(f"rg-{id(c)}", c) as s:
            k = s.put({"v": 9})
            assert s.get(k) == {"v": 9}
            assert s.get(k) == {"v": 9}
            assert c.gets == 1
            assert s.metrics.cache_hits == 1

    def test_evict_invalidates_cache(self):
        c = _CountingConnector()
        with Store(f"ev-{id(c)}", c) as s:
            p = s.proxy("val")
            assert extract(p) == "val"
            s.evict(object.__getattribute__(p, "__proxy_metadata__")["key"])
            reset(p)
            with pytest.raises(KeyError):
                extract(p)  # a cached resolve must never serve a freed object

    def test_evict_on_resolve_not_cached(self):
        c = _CountingConnector()
        with Store(f"er-{id(c)}", c) as s:
            p = s.proxy("one-shot", evict_on_resolve=True)
            assert extract(p) == "one-shot"
            reset(p)
            with pytest.raises(KeyError):
                extract(p)
            assert s.metrics.cache_hits == 0

    def test_ownership_free_invalidates_cache(self, store):
        o = owned_proxy(store, [7, 8])
        assert o[0] == 7  # resolve (cached)
        free(o)
        p = store.proxy_from_key(
            object.__getattribute__(o, "__proxy_metadata__")["key"]
        )
        with pytest.raises(KeyError):
            extract(p)

    def test_put_overwrite_invalidates_cache(self, store):
        k = store.put({"n": 1})
        assert store.get(k) == {"n": 1}
        store.put({"n": 2}, key=k)
        assert store.get(k) == {"n": 2}

    def test_lru_eviction_bounded(self):
        with Store(f"lru-{id(object())}", InMemoryConnector(), cache_size=4) as s:
            keys = [s.put(i) for i in range(8)]
            for k in keys:
                s.get(k)
            assert len(s._cache) == 4  # bounded by cache_size
            # least-recently-used entries fell out; newest are hits
            hits0 = s.metrics.cache_hits
            s.get(keys[-1])
            assert s.metrics.cache_hits == hits0 + 1

    def test_racing_invalidate_blocks_stale_cache_fill(self, store):
        # a resolver that snapshotted the payload before an overwrite must
        # not install its stale object after the overwrite's invalidate
        k = store.put({"v": "old"})
        gen = store._cache.generation
        stale = {"v": "old"}  # what the slow resolver decoded
        store.put({"v": "new"}, key=k)  # bumps the cache generation
        store._cache.set_if((k, store.deserializer), stale, gen)
        assert store.get(k) == {"v": "new"}

    def test_default_shm_connectors_get_distinct_namespaces(self):
        a, b = SharedMemoryConnector(), SharedMemoryConnector()
        try:
            assert a.namespace != b.namespace
            a.put("weights", b"AAAA")
            b.put("weights", b"BBBB")
            assert a.get("weights") == b"AAAA"
        finally:
            for c in (a, b):
                c.evict("weights")
                c.close()

    def test_evict_on_resolve_honored_on_cache_hit(self, store):
        # a prior plain resolve caches the object; a later one-shot resolve
        # of the same key must still reclaim the channel payload
        k = store.put("shared")
        assert extract(store.proxy_from_key(k)) == "shared"  # fills cache
        p = Store.get_or_reattach(store.name, store.connector).proxy_from_key(k)
        factory = object.__getattribute__(p, "__factory__")
        factory.evict_on_resolve = True
        assert extract(p) == "shared"
        assert not store.exists(k)

    def test_mut_borrow_array_mutation_roundtrip(self, store):
        from repro.core import mut_borrow, release, update

        o = owned_proxy(store, np.arange(10, dtype=np.int64))
        m = mut_borrow(o)
        m[0] = 99  # writable private copy, not a read-only channel view
        update(m)
        release(m)
        reset(o)
        assert int(o[0]) == 99
        free(o)

    def test_plain_resolve_is_readonly_view(self, store):
        arr = np.arange(8, dtype=np.float64)
        p = store.proxy(arr)
        got = extract(p)
        assert not got.flags.writeable  # zero-copy alias of the channel

    def test_shm_overwrite_does_not_mutate_resolved_array(self):
        c = SharedMemoryConnector()
        name = f"shmw-{id(c)}"
        with Store(name, c) as s:
            k = s.put(np.zeros(64, dtype=np.int64))
            arr = extract(s.proxy_from_key(k))
            assert int(arr[0]) == 0
            s.put(np.ones(64, dtype=np.int64), key=k)  # fits the segment
            assert int(arr[0]) == 0  # user-held result not rewritten
            fresh = extract(s.proxy_from_key(k))
            assert int(fresh[0]) == 1
            del arr, fresh
            s.evict(k)

    def test_shm_resolved_array_is_readonly(self):
        c = SharedMemoryConnector()
        with Store(f"shmro-{id(c)}", c) as s:
            k = s.put(np.arange(16, dtype=np.int64))
            arr = extract(s.proxy_from_key(k))
            assert not arr.flags.writeable  # cannot scribble on the segment
            with pytest.raises(ValueError):
                arr[0] = 99
            del arr
            s.evict(k)

    def test_clone_carries_custom_deserializer(self):
        from repro.core import clone

        name = f"clone-codec-{id(object())}"
        s = Store(
            name,
            InMemoryConnector(),
            serializer=_tag_serializer,
            deserializer=_tag_deserializer,
        )
        o = owned_proxy(s, [1, 2, 3])
        c = clone(o)
        factory = object.__getattribute__(c, "__factory__")
        assert factory.deserializer is _tag_deserializer
        assert extract(c) == [1, 2, 3]
        free(o)
        free(c)
        s.close()

    def test_get_propagates_deserializer_errors(self):
        def bad_deserializer(data):
            raise KeyError("unknown type tag")

        with Store(
            f"bad-{id(object())}",
            InMemoryConnector(),
            deserializer=bad_deserializer,
        ) as s:
            k = s.put("payload")
            with pytest.raises(KeyError, match="unknown type tag"):
                s.get(k, default="swallowed?")  # key exists: codec error surfaces
            assert s.get("truly-missing", default="absent") == "absent"

    def test_fresh_read_sees_other_writers(self):
        # mutable-key pattern (dist lease renewal): another Store instance
        # over the same channel re-puts the key; a fresh read must not be
        # pinned to this store's cache
        conn = InMemoryConnector()
        writer = Store(f"hb-w-{id(conn)}", conn, register=False)
        reader = Store(f"hb-r-{id(conn)}", conn, register=False)
        from repro.core import sanitize

        k = writer.put({"expires": 100})
        assert reader.get(k) == {"expires": 100}  # cached
        writer.put({"expires": 200}, key=k)
        # the unfresh read is the documented-stale demonstration — under
        # ProxySan it is (correctly) a stale_cache_read, so scope it
        with sanitize.expecting() as exp:
            assert reader.get(k) == {"expires": 100}  # documented cache behavior
        assert exp.categories() <= {"stale_cache_read"}
        assert reader.get(k, fresh=True) == {"expires": 200}
        conn.close()

    def test_heartbeat_lease_renewal_across_store_instances(self):
        from repro.dist.fault import HeartbeatMonitor

        conn = InMemoryConnector()
        worker_side = HeartbeatMonitor(
            Store(f"hbw-{id(conn)}", conn, register=False), ttl=30.0
        )
        monitor_side = HeartbeatMonitor(
            Store(f"hbm-{id(conn)}", conn, register=False), ttl=30.0
        )
        worker_side.register("w0")
        assert monitor_side.live_workers() == ["w0"]
        worker_side.heartbeat("w0")  # renewal re-puts the lease key
        assert monitor_side.live_workers() == ["w0"]  # not pinned to 1st read
        conn.close()

    def test_put_batch_accepts_generator(self, store):
        keys = store.put_batch(({"i": i} for i in range(3)))
        assert len(keys) == 3
        for i, k in enumerate(keys):
            assert store.exists(k)
            assert store.get(k) == {"i": i}

    def test_update_after_move_keeps_custom_codec(self):
        from repro.core import mut_borrow, release, update

        name = f"upd-codec-{id(object())}"
        s = Store(
            name,
            InMemoryConnector(),
            serializer=_tag_serializer,
            deserializer=_tag_deserializer,
        )
        o = owned_proxy(s, {"n": 1})
        blob = pickle.dumps(o)  # move to a "fresh process"
        _STORE_REGISTRY.pop(name, None)
        o2 = pickle.loads(blob)
        m = mut_borrow(o2)
        m["n"] = 99
        update(m)  # must write with the carried custom serializer
        release(m)
        reset(o2)
        assert o2["n"] == 99  # decoded by the carried custom deserializer
        free(o2)
        _STORE_REGISTRY.pop(name, None)
        s.connector.close()

    def test_cache_size_zero_disables(self):
        c = _CountingConnector()
        with Store(f"z-{id(c)}", c, cache_size=0) as s:
            k = s.put("v")
            assert s.get(k) == "v"
            assert s.get(k) == "v"
            assert c.gets == 2
            assert s.metrics.cache_hits == 0


# ---------------------------------------------------------------------------
# Metrics symmetry
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_store_get_times_fetch(self, store):
        k = store.put(np.zeros(10_000))
        assert store.metrics.get_time == 0.0
        store.get(k)
        assert store.metrics.get_time > 0.0
        assert store.metrics.get_count == 1

    def test_blocking_resolve_times_wait(self, store):
        f = store.future()
        p = f.proxy()

        def producer():
            time.sleep(0.05)
            f.set_result("late")

        t = threading.Thread(target=producer)
        t.start()
        assert p == "late"
        t.join()
        # the ~50 ms the consumer blocked is fetch time, not invisible
        assert store.metrics.get_time >= 0.04


# ---------------------------------------------------------------------------
# Reattach: atomicity + codec fidelity
# ---------------------------------------------------------------------------


def _tag_serializer(obj) -> bytes:
    return b"TAG:" + default_serializer(obj)


def _tag_deserializer(data) -> object:
    data = bytes(data)
    assert data.startswith(b"TAG:"), "custom-codec payload lost its tag"
    return framing.decode(memoryview(data)[4:])


class TestReattach:
    def test_get_or_reattach_is_atomic(self):
        name = f"race-{id(object())}"
        conn = InMemoryConnector()
        results = []
        barrier = threading.Barrier(8)

        def attach():
            barrier.wait()
            results.append(Store.get_or_reattach(name, conn))

        threads = [threading.Thread(target=attach) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len({id(s) for s in results}) == 1  # no clobbered duplicates
        results[0].close()

    def test_store_pickle_carries_custom_codec(self):
        name = f"codec-{id(object())}"
        with Store(
            name,
            InMemoryConnector(),
            serializer=_tag_serializer,
            deserializer=_tag_deserializer,
        ) as s:
            blob = pickle.dumps(s)
            # simulate a fresh process: the registry forgets the store
            with threading.Lock():
                _STORE_REGISTRY.pop(name, None)
            s2 = pickle.loads(blob)
            assert s2.serializer is _tag_serializer
            assert s2.deserializer is _tag_deserializer
            k = s2.put({"x": 1})
            assert s2.get(k) == {"x": 1}
            s2.close()

    def test_proxy_resolves_with_custom_codec_after_reattach(self):
        name = f"codecp-{id(object())}"
        s = Store(
            name,
            InMemoryConnector(),
            serializer=_tag_serializer,
            deserializer=_tag_deserializer,
        )
        p = s.proxy([9, 9, 9])
        blob = pickle.dumps(p)
        # store forgotten (fresh-process simulation; channel data survives):
        # resolution must use the codec the data was written with (carried
        # by the factory), not the reattached store's defaults
        _STORE_REGISTRY.pop(name, None)
        q = pickle.loads(blob)
        assert extract(q) == [9, 9, 9]
        _STORE_REGISTRY.pop(name, None)
        s.connector.close()

    def test_reattach_upgrades_default_codecs_in_place(self):
        # a plain resolve registers the store with defaults *before* the
        # pickled original (carrying the real codec) arrives; the late
        # carried codec must win, not be silently dropped
        name = f"adopt-{id(object())}"
        conn = InMemoryConnector()
        try:
            early = Store.get_or_reattach(name, conn)  # defaults
            adopted = Store.get_or_reattach(
                name, conn,
                serializer=_tag_serializer, deserializer=_tag_deserializer,
            )
            assert adopted is early
            assert early.serializer is _tag_serializer
            assert early.deserializer is _tag_deserializer
            k = early.put([1, 2])
            assert early.get(k) == [1, 2]
        finally:
            Store.get_or_reattach(name, conn).close()

    def test_reattach_conflicting_codecs_fails_loudly(self):
        name = f"conflict-{id(object())}"
        conn = InMemoryConnector()
        try:
            Store.get_or_reattach(name, conn, deserializer=_tag_deserializer)
            with pytest.raises(ValueError):
                Store.get_or_reattach(
                    name, conn, deserializer=lambda b: framing.decode(b)
                )
        finally:
            Store.get_or_reattach(name, conn).close()

    def test_reattach_accepts_equal_partial_codecs(self):
        import functools

        name = f"partial-{id(object())}"
        conn = InMemoryConnector()
        try:
            a = functools.partial(_tag_deserializer)
            b = functools.partial(_tag_deserializer)  # equal, not identical
            Store.get_or_reattach(name, conn, deserializer=a)
            st = Store.get_or_reattach(name, conn, deserializer=b)  # no raise
            assert st.deserializer is a
        finally:
            Store.get_or_reattach(name, conn).close()

    def test_unpicklable_codec_fails_loudly(self):
        with Store(
            f"loud-{id(object())}",
            InMemoryConnector(),
            serializer=lambda o: default_serializer(o),
            deserializer=lambda b: framing.decode(b),
        ) as s:
            with pytest.raises(Exception):  # pickling error, not silent defaults
                pickle.dumps(s)


# ---------------------------------------------------------------------------
# Batched puts + streaming integration
# ---------------------------------------------------------------------------


class TestPutBatch:
    def test_put_batch_roundtrip(self, store):
        objs = [np.arange(i + 1) for i in range(5)]
        keys = store.put_batch(objs)
        assert len(keys) == len(set(keys)) == 5
        assert store.metrics.put_count == 5
        for k, want in zip(keys, objs):
            np.testing.assert_array_equal(store.get(k), want)

    def test_unpicklable_payload_passes_by_value_in_executor(self, store):
        from concurrent.futures import ThreadPoolExecutor

        from repro.core import StoreExecutor

        with StoreExecutor(ThreadPoolExecutor(1), store) as ex:
            # a big memoryview has .nbytes but cannot be serialized; it must
            # fall through to pass-by-value on a thread engine, not crash
            mv = memoryview(bytearray(200_000))
            assert ex.submit(len, mv).result() == 200_000

    def test_lambda_codec_stream_works_in_process(self):
        from repro.core import QueuePublisher, QueueSubscriber

        name = f"lam-{id(object())}"
        s = Store(
            name,
            InMemoryConnector(),
            serializer=lambda o: b"L:" + framing.join_parts(framing.encode(o)),
            deserializer=lambda b: framing.decode(memoryview(bytes(b))[2:]),
        )
        ns = f"lam-ns-{id(s)}"
        sub = QueueSubscriber("t", ns)
        prod = StreamProducer(QueuePublisher(ns), {"t": s}, evict_on_resolve=False)
        prod.send("t", {"x": 1})  # must not fail pickling the lambda codec
        prod.flush()
        p, _ = StreamConsumer(sub, timeout=5).next_with_metadata()
        assert extract(p) == {"x": 1}  # resolved via the registered store
        s.close()

    def test_stream_batch_uses_put_batch(self, store):
        from repro.core import QueuePublisher, QueueSubscriber

        ns = f"pb-{id(store)}"
        sub = QueueSubscriber("t", ns)
        prod = StreamProducer(
            QueuePublisher(ns), {"t": store}, batch_size=4, evict_on_resolve=False
        )
        for i in range(4):
            prod.send("t", i)
        prod.close_topic("t")
        got = [extract(p) for p in StreamConsumer(sub, timeout=5)]
        assert got == [0, 1, 2, 3]


_PRODUCER_SCRIPT = """
import sys
import numpy as np
from repro.core import FileConnector, FileLogPublisher, Store, StreamProducer

data_dir, broker_dir = sys.argv[1], sys.argv[2]
store = Store("xp-hot-stream", FileConnector(data_dir))
prod = StreamProducer(FileLogPublisher(broker_dir), {"t": store})
for i in range(3):
    prod.send("t", np.full(64, i, dtype=np.int64), metadata={"i": i})
prod.close_topic("t")
"""


class TestCrossProcessStream:
    def test_file_stream_producer_subprocess_consumer_parent(self, tmp_path):
        data_dir, broker_dir = str(tmp_path / "data"), str(tmp_path / "broker")
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.Popen(
            [sys.executable, "-c", _PRODUCER_SCRIPT, data_dir, broker_dir],
            env=env,
            stdout=subprocess.PIPE,
            stderr=subprocess.PIPE,
        )
        try:
            sub = FileLogSubscriber("t", broker_dir)
            got = {}
            with StreamConsumer(sub, timeout=60) as cons:
                for proxy in cons:
                    meta = object.__getattribute__(proxy, "__proxy_metadata__")
                    arr = extract(proxy)
                    assert arr.dtype == np.int64 and arr.shape == (64,)
                    got[meta["i"]] = int(arr[0])
        finally:
            out, err = proc.communicate(timeout=60)
        assert proc.returncode == 0, err.decode()
        assert got == {0: 0, 1: 1, 2: 2}
        # default evict_on_resolve=True: resolved payloads were reclaimed
        remaining = [f for f in os.listdir(data_dir) if ".tmp." not in f]
        assert remaining == []
        _STORE_REGISTRY.pop("xp-hot-stream", None)
