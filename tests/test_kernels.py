"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles
(ref.py), swept across shapes and dtypes (assignment §c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import attention_ref, ssd_ref, wkv6_ref


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-3, atol=2e-3
    )


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_SWEEP = [
    # (B, Sq, Sk, H, Hkv, D, causal, dtype)
    (1, 128, 128, 2, 2, 64, True, jnp.float32),
    (2, 256, 256, 4, 2, 64, True, jnp.float32),   # GQA group=2
    (1, 128, 128, 4, 1, 32, True, jnp.bfloat16),  # MQA
    (2, 128, 128, 2, 2, 128, False, jnp.float32),  # non-causal (encoder)
    (1, 256, 256, 8, 2, 64, True, jnp.bfloat16),
    (1, 64, 256, 2, 2, 64, True, jnp.float32),     # Sq < Sk (chunked prefill)
]


@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,D,causal,dtype", ATTN_SWEEP)
def test_flash_attention_vs_ref(B, Sq, Sk, H, Hkv, D, causal, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(k1, (B, Sq, H, D), dtype)
    k = rand(k2, (B, Sk, Hkv, D), dtype)
    v = rand(k3, (B, Sk, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, impl="interpret",
                              block_q=64, block_k=64)
    kx = jnp.repeat(k, H // Hkv, axis=2)
    vx = jnp.repeat(v, H // Hkv, axis=2)
    ref = attention_ref(q, kx, vx, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


def test_flash_attention_jnp_fallback_matches_ref():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(k1, (2, 128, 4, 64), jnp.float32)
    k = rand(k2, (2, 128, 2, 64), jnp.float32)
    v = rand(k3, (2, 128, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, impl="jnp")
    ref = attention_ref(
        q, jnp.repeat(k, 2, 2), jnp.repeat(v, 2, 2), causal=True
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# paged attention (decode over a block-table-indexed KV pool)
# ---------------------------------------------------------------------------


def paged_ref(q, k_pages, v_pages, block_tables, lens):
    """Dense oracle: gather each row's pages, mask by length, softmax."""
    B, _, H, D = q.shape
    P, ps, Hkv, Dv = v_pages.shape
    rows = []
    for b in range(B):
        L = int(lens[b])
        if L == 0:
            rows.append(jnp.zeros((1, H, Dv), jnp.float32))
            continue
        k = k_pages[block_tables[b]].reshape(-1, Hkv, D)[:L]
        v = v_pages[block_tables[b]].reshape(-1, Hkv, Dv)[:L]
        kx = jnp.repeat(k, H // Hkv, axis=1).astype(jnp.float32)
        vx = jnp.repeat(v, H // Hkv, axis=1).astype(jnp.float32)
        s = jnp.einsum("qhd,khd->hqk", q[b].astype(jnp.float32), kx)
        p = jax.nn.softmax(s * (D ** -0.5), axis=-1)
        rows.append(jnp.einsum("hqk,khd->qhd", p, vx))
    return jnp.stack(rows)


def paged_case(seed, B, P, n, ps, H, Hkv, D, dtype, *, lens=None, T=1):
    """Random pool + per-row unique block tables + mixed lengths."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = rand(ks[0], (B, T, H, D), dtype)
    k_pages = rand(ks[1], (P, ps, Hkv, D), dtype)
    v_pages = rand(ks[2], (P, ps, Hkv, D), dtype)
    rng = np.random.default_rng(seed)
    bt = np.stack([rng.permutation(P)[:n] for _ in range(B)]).astype(np.int32)
    if lens is None:  # cover empty, partial-page, and full-coverage rows
        lens = rng.integers(0, n * ps + 1, B).astype(np.int32)
        lens[0] = n * ps
        if B > 1:
            lens[1] = max(1, ps - 1)  # mid-page boundary
    return q, k_pages, v_pages, jnp.asarray(bt), jnp.asarray(lens)


PAGED_SWEEP = [
    # (B, pool_pages, n, page_size, H, Hkv, D, dtype)
    (3, 24, 4, 8, 4, 2, 64, jnp.float32),
    (2, 16, 2, 16, 4, 1, 32, jnp.float32),   # MQA
    (4, 32, 4, 8, 8, 2, 64, jnp.bfloat16),
    (1, 12, 8, 4, 2, 2, 128, jnp.float32),   # many small pages
]


@pytest.mark.parametrize("B,P,n,ps,H,Hkv,D,dtype", PAGED_SWEEP)
def test_paged_attention_vs_ref(B, P, n, ps, H, Hkv, D, dtype):
    """Interpret-mode Pallas paged decode == dense gather-and-softmax."""
    q, kp, vp, bt, lens = paged_case(7, B, P, n, ps, H, Hkv, D, dtype)
    out = ops.paged_attention(q, kp, vp, bt, lens, impl="interpret")
    ref = paged_ref(q, kp, vp, np.asarray(bt), np.asarray(lens))
    assert np.all(np.isfinite(np.asarray(out, np.float32)))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


@pytest.mark.parametrize("B,P,n,ps,H,Hkv,D,dtype", PAGED_SWEEP[:2])
def test_paged_attention_jnp_fallback_matches_ref(B, P, n, ps, H, Hkv, D, dtype):
    q, kp, vp, bt, lens = paged_case(11, B, P, n, ps, H, Hkv, D, dtype)
    out = ops.paged_attention(q, kp, vp, bt, lens, impl="jnp")
    ref = paged_ref(q, kp, vp, np.asarray(bt), np.asarray(lens))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


@pytest.mark.parametrize("impl", ["interpret", "jnp"])
def test_paged_attention_ignores_padding_pages(impl):
    """Block-table entries beyond a row's length are never read: garbage
    (even out-of-range) padding ids change nothing — the property the
    engine's null-page padding relies on."""
    B, P, n, ps, H, Hkv, D = 2, 16, 4, 8, 4, 2, 64
    q, kp, vp, bt, lens = paged_case(
        13, B, P, n, ps, H, Hkv, D, jnp.float32,
        lens=np.asarray([ps + 3, 2 * ps], np.int32),  # cover ≤ 2 of 4 pages
    )
    base = ops.paged_attention(q, kp, vp, bt, lens, impl=impl)
    junk = np.asarray(bt).copy()
    junk[:, 2:] = 10_000  # uncovered slots → nonsense (clipped internally)
    out = ops.paged_attention(q, kp, vp, jnp.asarray(junk), lens, impl=impl)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


@pytest.mark.parametrize("impl", ["interpret", "jnp"])
def test_paged_attention_zero_length_row_is_finite(impl):
    q, kp, vp, bt, _ = paged_case(17, 2, 8, 2, 4, 2, 1, 32, jnp.float32)
    lens = jnp.asarray([0, 5], jnp.int32)
    out = ops.paged_attention(q, kp, vp, bt, lens, impl=impl)
    assert np.all(np.isfinite(np.asarray(out, np.float32)))


def paged_multi_ref(q, k_pages, v_pages, block_tables, lens):
    """Dense multi-query oracle: query ``t`` attends keys ``< lens + t``
    (speculative verify's per-position causal staircase)."""
    T = q.shape[1]
    cols = [
        paged_ref(q[:, t : t + 1], k_pages, v_pages, block_tables,
                  np.asarray(lens) + t)
        for t in range(T)
    ]
    return jnp.concatenate(cols, axis=1)


PAGED_MQ_SWEEP = [
    # (B, pool_pages, n, page_size, H, Hkv, D, T, dtype)
    (3, 24, 4, 8, 4, 2, 64, 2, jnp.float32),
    (2, 16, 4, 8, 4, 1, 32, 4, jnp.float32),   # MQA, spec_k=3 verify width
    (2, 24, 4, 8, 8, 2, 64, 4, jnp.bfloat16),
]


@pytest.mark.parametrize("B,P,n,ps,H,Hkv,D,T,dtype", PAGED_MQ_SWEEP)
@pytest.mark.parametrize("impl", ["interpret", "jnp"])
def test_paged_attention_multi_query_vs_ref(impl, B, P, n, ps, H, Hkv, D, T,
                                            dtype):
    """Speculative verify pass (T > 1): interpret-mode Pallas and the jnp
    fallback both match the dense staircase oracle, including a row whose
    last query exactly fills the block table."""
    rng = np.random.default_rng(31)
    lens = rng.integers(1, n * ps - T + 2, B).astype(np.int32)
    lens[0] = n * ps - T + 1  # last query covers the final pool token
    q, kp, vp, bt, lens = paged_case(
        29, B, P, n, ps, H, Hkv, D, dtype, lens=lens, T=T
    )
    out = ops.paged_attention(q, kp, vp, bt, lens, impl=impl)
    ref = paged_multi_ref(q, kp, vp, np.asarray(bt), np.asarray(lens))
    assert out.shape == (B, T, H, D)
    assert np.all(np.isfinite(np.asarray(out, np.float32)))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


@pytest.mark.parametrize("impl", ["interpret", "jnp"])
def test_paged_attention_multi_query_first_column_matches_single(impl):
    """Column t=0 of a T-query verify equals the plain T=1 decode step:
    stacking speculative queries cannot change the committed token."""
    B, P, n, ps, H, Hkv, D, T = 2, 16, 4, 8, 4, 2, 64, 3
    lens = np.asarray([ps + 3, 2 * ps], np.int32)
    q, kp, vp, bt, lens = paged_case(
        37, B, P, n, ps, H, Hkv, D, jnp.float32, lens=lens, T=T
    )
    multi = ops.paged_attention(q, kp, vp, bt, lens, impl=impl)
    single = ops.paged_attention(q[:, :1], kp, vp, bt, lens, impl=impl)
    np.testing.assert_allclose(
        np.asarray(multi[:, :1], np.float32),
        np.asarray(single, np.float32),
        rtol=1e-5, atol=1e-5,
    )


def test_paged_attention_matches_contiguous_decode():
    """Paging a contiguous cache (identity block table) reproduces plain
    dense decode attention — the layout is a pure reindexing."""
    from repro.models.layers import decode_attention

    B, S, ps, H, Hkv, D = 2, 64, 16, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(23), 3)
    q = rand(ks[0], (B, 1, H, D), jnp.float32)
    k = rand(ks[1], (B, S, Hkv, D), jnp.float32)
    v = rand(ks[2], (B, S, Hkv, D), jnp.float32)
    n = S // ps
    kp = k.reshape(B * n, ps, Hkv, D)
    vp = v.reshape(B * n, ps, Hkv, D)
    bt = jnp.arange(B * n, dtype=jnp.int32).reshape(B, n)
    L = S - 5  # decode_attention takes one scalar length for the batch
    lens = jnp.full((B,), L, jnp.int32)
    paged = ops.paged_attention(q, kp, vp, bt, lens, impl="interpret")
    dense = decode_attention(q, k, v, jnp.int32(L))
    np.testing.assert_allclose(
        np.asarray(paged), np.asarray(dense), rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

WKV_SWEEP = [
    # (B, S, H, K, V, chunk, dtype)
    (1, 64, 2, 16, 16, 16, jnp.float32),
    (2, 128, 2, 32, 32, 32, jnp.float32),
    (1, 128, 4, 64, 64, 64, jnp.bfloat16),
    (1, 96, 1, 16, 16, 32, jnp.float32),  # S not multiple of chunk → clamps
]


@pytest.mark.parametrize("B,S,H,K,V,chunk,dtype", WKV_SWEEP)
def test_wkv6_kernel_vs_ref(B, S, H, K, V, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    r = rand(ks[0], (B, S, H, K), dtype)
    k = rand(ks[1], (B, S, H, K), dtype)
    v = rand(ks[2], (B, S, H, V), dtype)
    # realistic decays: lw in [-6, -0.02]
    lw = (-jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) * 0.5)).astype(jnp.float32)
    u = rand(ks[4], (H, K), jnp.float32)
    ref, _ = wkv6_ref(r, k, v, lw, u)
    if S % chunk == 0:
        out = ops.wkv6(r, k, v, lw, u, impl="interpret", chunk=chunk)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
        )
    # chunked-jnp path must also match the sequential oracle
    out2 = ops.wkv6(r, k, v, lw, u, impl="jnp", chunk=chunk)
    np.testing.assert_allclose(
        np.asarray(out2, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


def test_wkv6_decode_step_consistency():
    """Sequential single-step decode equals the chunked form, step by step."""
    from repro.models.rwkv import wkv6_chunked, wkv6_step

    B, S, H, K = 1, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = rand(ks[0], (B, S, H, K), jnp.float32)
    k = rand(ks[1], (B, S, H, K), jnp.float32)
    v = rand(ks[2], (B, S, H, K), jnp.float32)
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) * 0.3)
    u = rand(ks[4], (H, K), jnp.float32)
    full, sF = wkv6_chunked(r, k, v, lw, u, chunk=8)
    s = jnp.zeros((B, H, K, K))
    outs = []
    for t in range(S):
        o, s = wkv6_step(r[:, t], k[:, t], v[:, t], lw[:, t], u, s)
        outs.append(o)
    step_out = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(step_out), np.asarray(full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sF), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ssd (mamba2)
# ---------------------------------------------------------------------------

SSD_SWEEP = [
    # (B, S, H, P, N, chunk, dtype)
    (1, 64, 2, 16, 16, 16, jnp.float32),
    (2, 128, 4, 32, 16, 32, jnp.float32),
    (1, 128, 2, 64, 64, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,P,N,chunk,dtype", SSD_SWEEP)
def test_ssd_kernel_vs_ref(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    x = rand(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (B, S, H)) * 0.3)
    la = dt * a / jnp.maximum(dt, 1e-3) * jnp.minimum(dt, 1.0)  # bounded decay
    la = -jnp.abs(la)
    Bm = rand(ks[3], (B, S, N), jnp.float32)
    Cm = rand(ks[4], (B, S, N), jnp.float32)
    D = rand(ks[5], (H,), jnp.float32)
    ref, _ = ssd_ref(x, dt, la, Bm, Cm, D)
    out = ops.ssd(x, dt, la, Bm, Cm, D, impl="interpret", chunk=chunk)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )
    out2 = ops.ssd(x, dt, la, Bm, Cm, D, impl="jnp", chunk=chunk)
    np.testing.assert_allclose(
        np.asarray(out2, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


def test_ssd_decode_step_consistency():
    from repro.models.ssm import ssd_chunked, ssd_step

    B, S, H, P, N = 1, 12, 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    x = rand(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    la = -jnp.abs(jax.random.normal(ks[2], (B, S, H)) * 0.3)
    Bm = rand(ks[3], (B, S, N), jnp.float32)
    Cm = rand(ks[4], (B, S, N), jnp.float32)
    D = rand(ks[5], (H,), jnp.float32)
    full, sF = ssd_chunked(x, dt, la, Bm, Cm, D, chunk=4)
    s = jnp.zeros((B, H, P, N))
    outs = []
    for t in range(S):
        o, s = ssd_step(x[:, t], dt[:, t], la[:, t], Bm[:, t], Cm[:, t], D, s)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(full), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(np.asarray(s), np.asarray(sF), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# property-based: invariances the kernels must satisfy
# ---------------------------------------------------------------------------

from hypothesis import given, settings
from hypothesis import strategies as st


@given(
    st.integers(1, 3), st.sampled_from([32, 64]), st.sampled_from([1, 2, 4]),
    st.sampled_from([16, 32]),
)
@settings(max_examples=8, deadline=None)
def test_attention_softmax_rowsum_property(B, S, H, D):
    """Attention output of constant V must be that constant (softmax sums 1)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(B * 100 + S))
    q = rand(k1, (B, S, H, D), jnp.float32)
    k = rand(k2, (B, S, H, D), jnp.float32)
    v = jnp.ones((B, S, H, D), jnp.float32) * 0.5
    out = ops.flash_attention(q, k, v, causal=True, impl="interpret",
                              block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), 0.5, rtol=1e-5, atol=1e-5)


@given(st.sampled_from([16, 32, 64]), st.sampled_from([8, 16]))
@settings(max_examples=6, deadline=None)
def test_wkv6_zero_decay_accumulates(S, K):
    """With w=1 (lw=0), u=0: o_t = r_t · Σ_{s≤t} k_sᵀ v_s (pure accumulation)."""
    ks = jax.random.split(jax.random.PRNGKey(S + K), 3)
    r = rand(ks[0], (1, S, 1, K), jnp.float32)
    k = rand(ks[1], (1, S, 1, K), jnp.float32)
    v = rand(ks[2], (1, S, 1, K), jnp.float32)
    lw = jnp.zeros((1, S, 1, K))
    u = jnp.zeros((1, K))
    out = ops.wkv6(r, k, v, lw, u, impl="jnp", chunk=16)
    # direct cumulative check: EXCLUSIVE prefix (current token enters via u only)
    kv = jnp.einsum("bshk,bshv->bshkv", k, v)
    S_cum = jnp.cumsum(kv, axis=1) - kv
    ref = jnp.einsum("bshk,bshkv->bshv", r, S_cum)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)
