"""Token-delta streaming contract (PR 5): ordered deltas, first-token-
before-completion, clean topic close, and a cross-process FileConnector
client that survives an engine restart (mirrors test_stream_fastpath's
subprocess pattern, under the multiproc watchdog).
"""
from __future__ import annotations

import json
import os
import pickle
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from _serve_toy import CountingModel, reference_decode
from repro.configs import get_smoke_config
from repro.core import FileConnector, Store
from repro.core.connectors import new_key
from repro.core.streaming import (
    FileLogPublisher,
    FileLogSubscriber,
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)
from repro.serve.client import ServeClient
from repro.serve.engine import ServeEngine, serve_context

CFG = get_smoke_config("smollm-135m")


def make_engine(**kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("page_size", 4)
    kw.setdefault("eos_id", -1)
    return ServeEngine(serve_context(CFG), {}, model=CountingModel(CFG), **kw)


def queue_streams():
    ns = f"ss-{new_key()}"
    return {
        "producer": StreamProducer(
            QueuePublisher(ns), {"requests": Store(f"{ns}-req")}
        ),
        "consumer": StreamConsumer(QueueSubscriber("requests", ns), timeout=30.0),
        "resp_producer": StreamProducer(
            QueuePublisher(ns), {"responses": Store(f"{ns}-resp")}
        ),
        "resp_consumer": StreamConsumer(
            QueueSubscriber("responses", ns), timeout=30.0
        ),
    }


def send(producer, req_id, prompt, max_new):
    producer.send(
        "requests",
        {"prompt": np.asarray(prompt, np.int32)},
        metadata={"req_id": req_id, "max_new_tokens": max_new},
    )
    producer.flush_topic("requests")


class TestDeltaContract:
    def _serve_collect(self, reqs, **run_kw):
        s = queue_streams()
        sent_at = {}
        for rid, (p, mn) in reqs.items():
            sent_at[rid] = time.perf_counter()
            send(s["producer"], rid, p, mn)
        s["producer"].close_topic("requests")
        engine = make_engine()
        client = ServeClient(s["resp_consumer"])
        collector = threading.Thread(target=client.collect, daemon=True)
        collector.start()
        engine.run(s["consumer"], s["resp_producer"], **run_kw)
        collector.join(timeout=30)
        assert not collector.is_alive()
        engine.close()
        return client, sent_at

    def test_deltas_arrive_in_order_and_match_final(self):
        rng = np.random.default_rng(0)
        reqs = {
            f"d{i}": (rng.integers(1, CFG.vocab, 5).astype(np.int32), 6)
            for i in range(4)
        }
        client, _ = self._serve_collect(reqs)
        assert not client.out_of_order
        for rid, (prompt, max_new) in reqs.items():
            rec = client.results[rid]
            ref = reference_decode(CFG, prompt, max_new, max_len=32)
            assert rec.stream_tokens == ref  # every delta, in order
            assert rec.result["tokens"] == ref  # bulk completion agrees

    def test_first_token_precedes_completion(self):
        """Streamed TTFT beats full-completion latency for multi-token
        requests — the whole point of delta streaming."""
        prompt = np.asarray(range(1, 7), np.int32)
        client, sent_at = self._serve_collect({"t": (prompt, 12)})
        rec = client.results["t"]
        assert rec.first_delta_at < rec.done_at
        ttft = client.ttft_s(sent_at)["t"]
        total = client.completion_s(sent_at)["t"]
        assert ttft < total
        # engine-side bookkeeping agrees
        assert rec.result["ttft"] < rec.result["latency"]

    def test_single_token_request_still_streams_a_delta(self):
        prompt = np.asarray([2, 3], np.int32)
        client, _ = self._serve_collect({"one": (prompt, 1)})
        rec = client.results["one"]
        assert len(rec.stream_tokens) == 1
        assert rec.stream_tokens == rec.result["tokens"]

    def test_batched_admission_streams_per_request_deltas(self):
        """A backlog admitted in one batched prefill still streams every
        request's deltas in order and bit-identical to the reference (the
        batching is a device-side detail, invisible on the wire)."""
        rng = np.random.default_rng(5)
        s = queue_streams()
        reqs = {
            f"m{i}": (rng.integers(1, CFG.vocab, 4 + i).astype(np.int32), 5)
            for i in range(4)
        }
        for rid, (p, mn) in reqs.items():
            send(s["producer"], rid, p, mn)
        s["producer"].close_topic("requests")
        engine = make_engine(slots=4)
        client = ServeClient(s["resp_consumer"])
        collector = threading.Thread(target=client.collect, daemon=True)
        collector.start()
        engine.run(s["consumer"], s["resp_producer"])
        collector.join(timeout=30)
        assert not collector.is_alive()
        assert engine.metrics["batched_prefills"] >= 1
        assert not client.out_of_order
        for rid, (prompt, max_new) in reqs.items():
            ref = reference_decode(CFG, prompt, max_new, max_len=32)
            rec = client.results[rid]
            assert rec.stream_tokens == ref, rid
            assert rec.result["tokens"] == ref, rid
        engine.close()

    def test_topic_closes_cleanly(self):
        prompt = np.asarray([1, 2, 3], np.int32)
        client, _ = self._serve_collect({"c": (prompt, 3)})
        assert client.closed  # StopIteration, not a timeout
        with pytest.raises(StopIteration):
            client.consumer.next_with_metadata(timeout=0.1)

    def test_close_responses_false_keeps_topic_open(self):
        """An engine 'restart' mid-topic: run #1 leaves the response topic
        open; run #2 on the same topics finishes and closes it."""
        s = queue_streams()
        rng = np.random.default_rng(1)
        reqs = {
            f"r{i}": (rng.integers(1, CFG.vocab, 4).astype(np.int32), 3)
            for i in range(4)
        }
        for rid, (p, mn) in reqs.items():
            send(s["producer"], rid, p, mn)
        s["producer"].close_topic("requests")
        client = ServeClient(s["resp_consumer"])
        collector = threading.Thread(target=client.collect, daemon=True)
        collector.start()

        engine1 = make_engine()
        engine1.run(
            s["consumer"], s["resp_producer"],
            max_requests=2, close_responses=False,
        )
        assert len(engine1.completed) == 2
        # the stream outlives engine1: completion bulks the collector has
        # not resolved yet must survive its close (handoff form)
        engine1.close(reclaim_responses=False)
        assert not client.closed  # topic still open across the restart

        engine2 = make_engine()
        engine2.run(s["consumer"], s["resp_producer"])
        collector.join(timeout=30)
        assert not collector.is_alive()
        assert client.closed
        served = set(engine1.completed) | set(engine2.completed)
        assert served == set(reqs)
        for rid, (prompt, max_new) in reqs.items():
            assert client.results[rid].stream_tokens == reference_decode(
                CFG, prompt, max_new, max_len=32
            )
        engine2.close()


class TestMetaOnlyEvents:
    """The core streaming primitives the delta protocol rides on."""

    def _pair(self, **consumer_kw):
        ns = f"mo-{new_key()}"
        producer = StreamProducer(QueuePublisher(ns), {"t": Store(f"{ns}-s")})
        consumer = StreamConsumer(QueueSubscriber("t", ns), **consumer_kw)
        return producer, consumer

    def test_send_meta_roundtrip_and_ordering(self):
        from repro.core.proxy import extract

        producer, consumer = self._pair(timeout=5)
        producer.send("t", {"big": 1}, metadata={"kind": "bulk"})
        # send_meta flushes buffered sends first: order == call order
        producer.send_meta("t", {"kind": "delta", "i": 0})
        producer.send_meta("t", {"kind": "delta", "i": 1})
        proxy, meta = consumer.next_with_metadata()
        assert proxy is not None and meta["kind"] == "bulk"
        assert extract(proxy) == {"big": 1}  # consume (one-shot: evicts)
        for i in range(2):
            proxy, meta = consumer.next_with_metadata()
            assert proxy is None  # metadata-only: nothing to resolve
            assert meta == {"kind": "delta", "i": i}

    def test_plain_iteration_skips_meta_only(self):
        from repro.core.proxy import extract

        producer, consumer = self._pair(timeout=5)
        producer.send_meta("t", {"kind": "delta"})
        producer.send("t", "payload")
        producer.flush_topic("t")
        producer.send_meta("t", {"kind": "delta"})
        producer.close_topic("t")
        got = [extract(p) for p in consumer]
        assert got == ["payload"]

    def test_prefetch_consumer_passes_meta_only_through(self):
        producer, consumer = self._pair(timeout=5, prefetch=2)
        producer.send_meta("t", {"kind": "delta", "i": 0})
        producer.send("t", "bulk0")
        producer.flush_topic("t")
        producer.close_topic("t")
        proxy, meta = consumer.next_with_metadata()
        assert proxy is None and meta["i"] == 0
        proxy, meta = consumer.next_with_metadata()
        assert proxy is not None
        with pytest.raises(StopIteration):
            consumer.next_with_metadata()

    def test_per_call_timeout_overrides_constructor(self):
        _, consumer = self._pair(timeout=60)
        t0 = time.perf_counter()
        with pytest.raises(TimeoutError):
            consumer.next_with_metadata(timeout=0.05)
        assert time.perf_counter() - t0 < 5  # not the constructor's 60 s

    def test_client_ignores_unknown_event_kinds(self):
        """Heartbeats / future kinds on the response topic must not kill
        the collector (extract(None) on the 'done' path, KeyErrors)."""
        ns = f"mo-{new_key()}"
        producer = StreamProducer(QueuePublisher(ns), {"r": Store(f"{ns}-s")})
        consumer = StreamConsumer(QueueSubscriber("r", ns), timeout=5)
        client = ServeClient(consumer)
        producer.send_meta("r", {"kind": "heartbeat"})  # no req_id
        producer.send_meta("r", {"req_id": "x", "kind": "progress"})
        producer.send_meta("r", {"req_id": "x", "kind": "done"})  # no bulk
        producer.send_meta(
            "r", {"req_id": "x", "kind": "delta", "token": 7, "index": 0}
        )
        producer.close_topic("r")
        client.collect()
        assert len(client.ignored_events) == 3
        assert client.results["x"].stream_tokens == [7]

    def test_client_duplicate_rejection_spares_live_record(self):
        """An engine 'error' for a req_id that is already streaming is the
        duplicate being refused — the live record keeps collecting and
        completes exactly once."""
        ns = f"mo-{new_key()}"
        store = Store(f"{ns}-s")
        producer = StreamProducer(QueuePublisher(ns), {"r": store})
        consumer = StreamConsumer(QueueSubscriber("r", ns), timeout=5)
        done_calls = []
        client = ServeClient(consumer, on_done=lambda r, rec: done_calls.append(r))
        producer.send_meta(
            "r", {"req_id": "d", "kind": "delta", "token": 1, "index": 0}
        )
        producer.send_meta(  # the engine refusing a duplicate 'd'
            "r", {"req_id": "d", "kind": "error", "error": "already serving"}
        )
        producer.send_meta(
            "r", {"req_id": "d", "kind": "delta", "token": 2, "index": 1}
        )
        producer.send(
            "r", {"req_id": "d", "tokens": [1, 2]},
            metadata={"req_id": "d", "kind": "done"},
        )
        producer.flush_topic("r")
        producer.send_meta(  # late duplicate after completion
            "r", {"req_id": "d", "kind": "error", "error": "already serving"}
        )
        producer.close_topic("r")
        client.collect()
        rec = client.results["d"]
        assert rec.error is None and rec.stream_tokens == [1, 2]
        assert rec.result["tokens"] == [1, 2]
        assert done_calls == ["d"]  # exactly one completion callback
        assert len(client.rejections) == 2

    def test_meta_events_respect_filter(self):
        ns = f"mo-{new_key()}"
        producer = StreamProducer(QueuePublisher(ns), {"t": Store(f"{ns}-s")})
        consumer = StreamConsumer(
            QueueSubscriber("t", ns),
            timeout=5,
            filter_=lambda m: m.get("keep", False),
        )
        producer.send_meta("t", {"keep": False, "i": 0})
        producer.send_meta("t", {"keep": True, "i": 1})
        _, meta = consumer.next_with_metadata()
        assert meta["i"] == 1


# ---------------------------------------------------------------------------
# Cross-process client over FileConnector + FileLog, surviving a restart
# ---------------------------------------------------------------------------

_XP_CLIENT = """
import json, sys
sys.path.insert(0, sys.argv[4])  # tests dir, for _serve_toy
import numpy as np
from _serve_toy import reference_decode
from repro.configs import get_smoke_config
from repro.core import FileConnector, Store
from repro.core.streaming import (
    FileLogPublisher, FileLogSubscriber, StreamConsumer, StreamProducer,
)
from repro.serve.client import ServeClient

chdir, logdir, n = sys.argv[1], sys.argv[2], int(sys.argv[3])
cfg = get_smoke_config("smollm-135m")
store = Store("xp-serve-req", FileConnector(chdir))
producer = StreamProducer(FileLogPublisher(logdir), {"requests": store})
rng = np.random.default_rng(42)
prompts = {}
for i in range(n):
    rid = f"x{i}"
    prompts[rid] = rng.integers(1, cfg.vocab, 5).astype(np.int32)
    producer.send(
        "requests",
        {"prompt": prompts[rid]},
        metadata={"req_id": rid, "max_new_tokens": 4},
    )
    producer.flush_topic("requests")
producer.close_topic("requests")

client = ServeClient(
    StreamConsumer(FileLogSubscriber("responses", logdir), timeout=60.0)
)
client.collect()  # until the (restarted) engine closes the topic
ok = True
for rid, prompt in prompts.items():
    ref = reference_decode(cfg, prompt, 4, max_len=32)
    rec = client.results.get(rid)
    if rec is None or rec.stream_tokens != ref or rec.result["tokens"] != ref:
        ok = False
print(json.dumps({
    "ok": ok and client.closed and not client.out_of_order,
    "n_results": len(client.results),
    "deltas": {r: rec.stream_tokens for r, rec in client.results.items()},
}))
"""


class TestCrossProcessClient:
    @pytest.mark.multiproc(timeout=120)
    def test_fileconnector_client_survives_engine_restart(self, tmp_path):
        """A client in another process sends requests and consumes the
        delta/completion stream over FileConnector+FileLog; the engine is
        torn down after 2 of 4 requests and a fresh engine (resuming the
        request topic from the pickled subscriber offset) serves the rest.
        The client sees one continuous, ordered, complete stream."""
        chdir, logdir = str(tmp_path / "ch"), str(tmp_path / "log")
        n = 4
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
        tests_dir = os.path.dirname(os.path.abspath(__file__))
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.Popen(
            [sys.executable, "-c", _XP_CLIENT, chdir, logdir, str(n), tests_dir],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        try:
            resp_store = Store("xp-serve-resp", FileConnector(chdir))

            def resp_producer():
                return StreamProducer(
                    FileLogPublisher(logdir), {"responses": resp_store}
                )

            sub1 = FileLogSubscriber("requests", logdir)
            consumer1 = StreamConsumer(sub1, timeout=60.0)
            engine1 = make_engine()
            engine1.run(
                consumer1, resp_producer(),
                max_requests=2, close_responses=False,
            )
            assert len(engine1.completed) == 2
            # handoff form: the external client is still consuming — a
            # reclaiming close would evict completion bulks it has not
            # resolved yet and wedge its blocking resolves
            engine1.close(reclaim_responses=False)

            # restart: a new engine resumes the request topic exactly after
            # the last consumed event (the subscriber pickle carries the
            # consumption offset — PR 3 contract)
            sub2 = pickle.loads(pickle.dumps(sub1))
            consumer2 = StreamConsumer(sub2, timeout=60.0)
            engine2 = make_engine()
            engine2.run(consumer2, resp_producer())
            assert len(engine2.completed) == 2
            engine2.close(reclaim_responses=False)

            out, err = proc.communicate(timeout=90)
        except BaseException:
            proc.kill()
            raise
        assert proc.returncode == 0, err.decode()
        report = json.loads(out.decode().strip().splitlines()[-1])
        assert report["ok"], report
        assert report["n_results"] == n


class TestOrphanReclaimFailure:
    def test_reclaim_failure_counted_and_surfaced_by_proxysan(self):
        """Satellite: the engine's best-effort reclaim of an unaddressable
        request's bulk (no ``req_id`` — nobody will ever pull it again)
        used to swallow eviction failures silently.  A failed reclaim must
        now land in ``metrics['reclaim_failures']`` AND hand the orphan to
        ProxySan so the resident payload shows up in the leak report."""
        from repro.core import sanitize as _sanitize

        ns = f"rf-{new_key()}"
        store = Store(f"{ns}-req", sanitize=True)
        producer = StreamProducer(QueuePublisher(ns), {"requests": store})
        consumer = StreamConsumer(QueueSubscriber("requests", ns), timeout=10.0)
        resp_producer = StreamProducer(
            QueuePublisher(ns), {"responses": Store(f"{ns}-resp")}
        )
        # unaddressable: no req_id in the metadata
        producer.send("requests", {"prompt": np.arange(1, 5, dtype=np.int32)},
                      metadata={"note": "no req_id"})
        producer.flush_topic("requests")
        producer.close_topic("requests")

        evict_attempts = []

        def failing_evict(key):
            evict_attempts.append(key)
            raise RuntimeError("injected channel failure")

        orig_evict = store.connector.evict
        store.connector.evict = failing_evict
        engine = make_engine()
        try:
            engine.run(consumer, resp_producer)
            assert engine.metrics["malformed_events"] == 1
            assert engine.metrics["reclaim_failures"] == 1
            assert len(evict_attempts) == 1
            san = _sanitize.active_for(store.name)
            assert san is not None
            leaked = san.leak_report(store=store.name, kinds=("object",))
            assert any(l["key"] == evict_attempts[0] for l in leaked), leaked
        finally:
            store.connector.evict = orig_evict
            # reclaim for real so the orphan does not outlive the test
            store.connector.evict(evict_attempts[0])
            engine.close()

    def test_reclaim_success_keeps_failure_count_zero(self):
        """Control: a healthy channel reclaims the orphan; no failure is
        counted and nothing is handed to ProxySan."""
        s = queue_streams()
        s["producer"].send(
            "requests", {"prompt": np.arange(1, 5, dtype=np.int32)}, metadata={}
        )
        s["producer"].flush_topic("requests")
        s["producer"].close_topic("requests")
        engine = make_engine()
        engine.run(s["consumer"], s["resp_producer"])
        assert engine.metrics["malformed_events"] == 1
        assert engine.metrics["reclaim_failures"] == 0
        engine.close()
