"""Fleet failover chaos matrix: SIGKILL an engine at each lifecycle stage.

The exactly-once contract under test (see ``repro.serve.router``): for
every request, the client receives the exact greedy-decode token stream
once — no gap, no duplicate, one ``on_done`` — no matter when an engine
dies:

- **before admission** (``test_kill_engine_before_admission``): the
  victim holds its lease and publishes load but is parked before its
  serve loop (the fleet ``--hold-key`` chaos hook), so its assigned
  requests have produced nothing when it is killed;
- **mid-decode** (``test_kill_engine_mid_decode``): the headline drill —
  the victim is killed while streaming a long request; the survivor
  replays from the persistent prompt bulk and the router drops the
  bit-identical replayed prefix;
- **after completion-publish, before client read**
  (``test_kill_engine_after_commit_before_client_read``): the victim
  committed ``done-{req_id}`` (put-if-absent) and died before the client
  consumed it; the survivor's twin completion references the same cell
  and the router forwards exactly one terminal event.

Tokens are checked bit-identically against ``reference_decode`` (the
CountingModel is integer-exact), so a replayed/redispatched request that
drifted by even one token fails loudly.
"""
import time

import numpy as np
import pytest

from _serve_toy import reference_decode
from repro.configs import get_smoke_config
from repro.core.connectors import new_key
from repro.core.connectors_net import StoreServer, StoreServerConnector
from repro.core.store import Store
from repro.core.streaming import (
    FileLogPublisher,
    FileLogSubscriber,
    StreamConsumer,
    StreamProducer,
    _load_event,
)
from repro.launch.fleet import EngineProc, Fleet
from repro.serve.client import ServeClient

CFG = get_smoke_config("smollm-135m")


def _wait_until(predicate, timeout: float, what: str) -> None:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return
        time.sleep(0.01)
    raise AssertionError(f"timed out after {timeout}s waiting for {what}")


def _counting(counts: dict):
    """on_done hook that counts completions per req_id."""

    def on_done(rid, rec):
        counts[rid] = counts.get(rid, 0) + 1

    return on_done


def _assert_exactly_once(fleet, prompts, max_new, counts, *, max_len):
    """Every request: bit-identical tokens, gapless stream, one on_done."""
    for rid, prompt in prompts.items():
        rec = fleet.client.results[rid]
        assert rec.error is None, (rid, rec.error)
        want = reference_decode(CFG, prompt, max_new[rid], max_len=max_len)
        assert rec.result["tokens"] == want, rid
        assert rec.stream_tokens == want, rid  # no gap, no duplicate delta
        assert counts.get(rid) == 1, rid  # on_done fired exactly once
    assert not fleet.client.out_of_order
    assert not fleet.client.rejections
    assert fleet.client.closed  # router ran its shutdown ladder to the end


@pytest.mark.multiproc(timeout=300)
class TestFleetChaos:
    def test_kill_engine_mid_decode(self):
        """Headline drill: SIGKILL the engine streaming a 600-token request
        once >= 3 deltas have been forwarded.  The survivor re-resolves the
        same prompt bulk, replays, and the client sees one gapless exact
        stream per request."""
        counts: dict[str, int] = {}
        fleet = Fleet(
            2, slots=2, max_len=1024, page_size=16, ttl=2.0,
            on_done=_counting(counts),
        )
        prompts: dict[str, np.ndarray] = {}
        max_new: dict[str, int] = {}

        def send(rid, prompt, n):
            prompts[rid] = prompt
            max_new[rid] = n
            fleet.send(rid, prompt, n)

        try:
            send("long", np.arange(1, 7, dtype=np.int32), 600)
            for i in range(3):
                send(f"s{i}", np.array([2 + i, 3, 5, 7], np.int32), 8)
            fleet.close_intake()

            def mid_decode():
                snap = fleet.router.snapshot()
                if "long" not in snap:
                    return False
                _, terminal, forwarded = snap["long"]
                assert not terminal, "long finished before the kill window"
                return forwarded >= 3

            _wait_until(mid_decode, 60, "long request mid-decode")
            victim = fleet.router.snapshot()["long"][0]
            fleet.kill_engine(victim)

            fleet.client.collect(deadline=120.0)
            # snapshot metrics NOW: once the survivor exits cleanly after
            # shutdown its lease expires too, and the watch thread counts
            # that as a (benign, post-terminal) second death while the slow
            # reference decodes below run
            m = dict(fleet.router.metrics)
            _assert_exactly_once(fleet, prompts, max_new, counts, max_len=1024)
            assert m["engine_deaths"] == 1
            assert m["failed_requests"] == 0
            assert m["redispatches"] >= 1  # at least the long request moved
            # the survivor's replayed prefix was dropped, not re-delivered
            assert m["dropped_stale_deltas"] >= 3
        finally:
            fleet.stop()

    def test_kill_engine_before_admission(self):
        """The victim is lease-live and load-published but parked *before*
        its serve loop (``hold``), so its assigned requests were never
        admitted.  Killing it must redispatch them untouched."""
        counts: dict[str, int] = {}
        fleet = Fleet(2, ttl=2.0, hold=("e1",), on_done=_counting(counts))
        prompts: dict[str, np.ndarray] = {}
        try:
            for i in range(6):
                p = np.array([1 + i, 2, 3, 4], np.int32)
                prompts[f"a{i}"] = p
                fleet.send(f"a{i}", p, 6)
            fleet.close_intake()
            _wait_until(
                lambda: any(
                    eng == "e1" and not terminal
                    for eng, terminal, _ in fleet.router.snapshot().values()
                ),
                30,
                "a request assigned to the held engine e1",
            )
            fleet.kill_engine("e1")
            fleet.client.collect(deadline=120.0)
            m = dict(fleet.router.metrics)  # before post-shutdown expiries
            _assert_exactly_once(
                fleet, prompts, {r: 6 for r in prompts}, counts, max_len=32
            )
            assert m["engine_deaths"] == 1
            assert m["redispatches"] >= 1
            assert m["failed_requests"] == 0
        finally:
            fleet.stop()

    def test_kill_engine_after_commit_before_client_read(self):
        """The victim commits ``done-c0`` (visible in the response store)
        and dies before the client reads it — both forwarders are paused to
        pin that window open.  The survivor's twin completion references
        the same committed cell; the client must get exactly one done and
        the victim's late event must drop as a duplicate."""
        counts: dict[str, int] = {}
        fleet = Fleet(2, ttl=2.0, on_done=_counting(counts))
        resp = StoreServerConnector(fleet.server.address, namespace="resp")
        try:
            for name in fleet.names:
                fleet.router.pause_forwarder(name)
            prompt = np.array([3, 1, 4, 1, 5], np.int32)
            fleet.send("c0", prompt, 6)
            fleet.close_intake()
            # the completion is durably committed server-side...
            resp.wait_for("done-c0", timeout=60.0)
            victim = fleet.router.snapshot()["c0"][0]
            survivor = next(n for n in fleet.names if n != victim)
            # ...and fully *published*: the done event must be in the
            # victim's response log before the kill, else there is no late
            # event for the duplicate-drop assertion below (commit and
            # event append are two steps; SIGKILL can land between them)
            vsub = FileLogSubscriber(f"responses-{victim}", fleet.logdir)

            def done_event_logged():
                while True:
                    try:
                        raw = vsub.next_event(timeout=0.05)
                    except TimeoutError:
                        return False
                    meta = _load_event(raw).get("metadata", {})
                    if meta.get("req_id") == "c0" and meta.get("kind") == "done":
                        return True

            _wait_until(done_event_logged, 30, "victim's done event logged")
            vsub.close()
            # the client has still read nothing: kill the committer now
            fleet.kill_engine(victim)
            fleet.router.resume_forwarder(survivor)

            fleet.client.collect(deadline=120.0)
            _assert_exactly_once(
                fleet, {"c0": prompt}, {"c0": 6}, counts, max_len=32
            )
            # now let the victim's buffered done event through: it must be
            # dropped as a duplicate of the already-forwarded terminal
            fleet.router.resume_forwarder(victim)
            _wait_until(
                lambda: fleet.router.metrics["duplicate_dones"] >= 1,
                30,
                "victim's late done dropped as duplicate",
            )
            assert fleet.router.metrics["dones_forwarded"] == 1
            assert not fleet.client.rejections
        finally:
            resp.close()
            fleet.stop()


@pytest.mark.multiproc(timeout=300)
class TestFleetClientDeadline:
    def test_dead_engine_surfaces_timeout_with_req_id(self, tmp_path):
        """Satellite bugfix pin: a client collecting against an engine that
        died mid-stream must surface ``TimeoutError`` naming the incomplete
        req_id at its deadline instead of blocking forever."""
        logdir = str(tmp_path)
        prefix = f"dead-{new_key()}"
        server = StoreServer().start()
        proc = None
        try:
            proc = EngineProc(
                "e0", server.address, logdir, prefix,
                toy=True, slots=2, max_len=1024, page_size=16, ttl=60.0,
            )
            proc.wait_ready()
            req_store = Store(
                f"{prefix}-req",
                StoreServerConnector(server.address, namespace="req"),
                register=False,
            )
            producer = StreamProducer(
                FileLogPublisher(logdir), {"requests-e0": req_store}
            )
            killed = []

            def kill_on_first_delta(rid, token, index):
                if not killed:
                    killed.append(rid)
                    proc.kill()  # mid-stream death: no done, no topic close

            client = ServeClient(
                StreamConsumer(
                    FileLogSubscriber("responses-e0", logdir), timeout=60.0
                ),
                on_delta=kill_on_first_delta,
            )
            producer.send(
                "requests-e0",
                {"prompt": np.arange(1, 6, dtype=np.int32)},
                metadata={"req_id": "d0", "max_new_tokens": 600},
            )
            producer.flush_topic("requests-e0")
            t0 = time.monotonic()
            with pytest.raises(TimeoutError) as ei:
                client.collect(1, deadline=5.0)
            assert "d0" in str(ei.value)  # names the incomplete request
            assert killed == ["d0"]  # the stream really started first
            assert time.monotonic() - t0 < 30.0  # deadline, not the 60s wait
        finally:
            if proc is not None:
                proc.stop()
            server.stop()
