"""int8 error-feedback gradient compression (optim/grad_compress.py).

Unit behaviour (quantize/dequant, error carry) runs in-process; the
shard_map integration tests need a 4-device mesh, so they run in a
subprocess with ``--xla_force_host_platform_device_count=4`` (the main
pytest process must keep seeing 1 device — see launch/dryrun.py).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.grad_compress import (
    compress_with_feedback,
    dequantize_int8,
    quantize_int8,
)


def test_quantize_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 64), jnp.float32)
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-7  # half-ulp of the quant grid


def test_error_feedback_carries_residual():
    x = jnp.full((8,), 0.3, jnp.float32)
    err = jnp.zeros((8,), jnp.float32)
    q, s, new_err = compress_with_feedback(x, err)
    recon = dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(recon + new_err), np.asarray(x),
                               rtol=1e-6, atol=1e-6)


_SUBPROCESS_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.optim.grad_compress import compressed_psum, tree_compressed_pmean

    mesh = jax.make_mesh((4,), ("data",))

    # 1) compressed psum tracks the exact mean within the quant grid
    g = jax.random.normal(jax.random.PRNGKey(1), (4, 32), jnp.float32)
    e = jnp.zeros_like(g)

    @functools.partial(jax.shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")), check_vma=False)
    def run(gl, el):
        m, ne = compressed_psum(gl[0], el[0], "data")
        return m[None], ne[None]

    mean, _ = run(g, e)
    exact = g.mean(0)
    for i in range(4):
        np.testing.assert_allclose(np.asarray(mean[i]), np.asarray(exact),
                                   atol=5e-2, rtol=0)

    # 2) error feedback: accumulated compressed mean converges to exact
    steps, shards, dim = 20, 4, 16
    gs = jax.random.normal(jax.random.PRNGKey(2), (steps, shards, dim), jnp.float32)

    @functools.partial(jax.shard_map, mesh=mesh,
                       in_specs=(P(None, "data"), P("data")),
                       out_specs=(P(None, "data"), P("data")), check_vma=False)
    def run_all(g_seq, e0):
        def body(e, g):
            m, ne = compressed_psum(g, e, "data")
            return ne, m
        eT, ms = jax.lax.scan(body, e0[0], g_seq[:, 0])
        return ms[:, None], eT[None]

    ms, _ = run_all(gs, jnp.zeros((shards, dim), jnp.float32))
    acc_comp = np.asarray(ms[:, 0].sum(0))
    acc_exact = np.asarray(gs.mean(1).sum(0))
    np.testing.assert_allclose(acc_comp, acc_exact, atol=6e-2, rtol=0)

    # 3) tree wrapper preserves structure
    tree = {"a": jnp.ones((4, 8)), "b": {"c": jnp.ones((4, 3))}}
    errs = jax.tree.map(jnp.zeros_like, tree)

    @functools.partial(jax.shard_map, mesh=mesh,
                       in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")), check_vma=False)
    def run_tree(t, e):
        tl = jax.tree.map(lambda x: x[0], t)
        el = jax.tree.map(lambda x: x[0], e)
        m, ne = tree_compressed_pmean(tl, el, "data")
        return (jax.tree.map(lambda x: x[None], m),
                jax.tree.map(lambda x: x[None], ne))

    m, ne = run_tree(tree, errs)
    assert jax.tree.structure(m) == jax.tree.structure(tree)
    np.testing.assert_allclose(np.asarray(m["a"][0]), np.ones((8,)), atol=1e-2)
    print("SHARD_MAP_GRAD_COMPRESS_OK")
""")


def test_compressed_psum_shard_map_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_BODY],
        capture_output=True, text=True, timeout=240,
        cwd=os.path.join(os.path.dirname(__file__), ".."), env=env,
    )
    assert "SHARD_MAP_GRAD_COMPRESS_OK" in out.stdout, (
        out.stdout[-2000:] + "\n" + out.stderr[-2000:]
    )
