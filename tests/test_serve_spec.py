"""Speculative decode over owned KV pages (engine ``spec_k > 0``).

The contract under test: greedy speculative decode is **bit-identical to
target-only greedy decode by construction** — the emitted tokens are always
the target model's argmaxes; the draft only decides how many of them one
step yields.  CountingModel makes the comparison exact (integer sums in
f32), so every test here asserts token-for-token equality against
``reference_decode``, including with a draft built to disagree on purpose.

Paging: speculation runs TWO PageTables in lockstep (target + draft pool).
Rejected draft tokens never roll the tables back — pages past the accepted
length simply don't scatter to the device pool — so both pools must still
drain to zero after every run, and admission must backpressure on
whichever pool fills first.
"""
import numpy as np
import pytest

from _serve_toy import CountingModel, reference_decode
from test_serve_engine import CFG, make_streams, send_request, serve
from repro.serve.engine import Request, ServeEngine, serve_context


class DisagreeingDraft(CountingModel):
    """Adversarial draft: always proposes target+1 — never matches, so
    every accepted run is exactly the single corrected token."""

    def _next(self, hist, index):
        return (super()._next(hist, index) + 1) % self.cfg.vocab


def make_spec_engine(
    *, slots=2, max_len=32, page_size=4, eos_id=-1, spec_k=3,
    draft_cls=CountingModel, num_pages=None, draft_num_pages=None, **kw
):
    ctx = serve_context(CFG)
    engine = ServeEngine(
        ctx,
        {},
        slots=slots,
        max_len=max_len,
        page_size=page_size,
        eos_id=eos_id,
        model=CountingModel(CFG),
        spec_k=spec_k,
        draft_model=draft_cls(CFG),
        **kw,
    )
    if num_pages is not None:
        engine.pages.num_pages = num_pages
        engine.pages._free = list(range(num_pages))
    if draft_num_pages is not None:
        engine.draft_pages.num_pages = draft_num_pages
        engine.draft_pages._free = list(range(draft_num_pages))
    return engine


def make_requests(n, *, seed=0, prompt_len=5, max_new=10):
    rng = np.random.default_rng(seed)
    return {
        f"r{i}": (rng.integers(1, CFG.vocab, prompt_len).astype(np.int32),
                  max_new)
        for i in range(n)
    }


def assert_reference(completed, reqs, *, eos_id=-1, max_len=32):
    for rid, (prompt, max_new) in reqs.items():
        ref = reference_decode(CFG, prompt, max_new, eos_id=eos_id,
                               max_len=max_len)
        assert completed[rid]["tokens"] == ref, rid


class TestSpecBitIdentity:
    def test_self_draft_bit_identical(self):
        """Draft == target: near-full acceptance, same exact tokens."""
        engine = make_spec_engine()
        reqs = make_requests(4, max_new=12)
        completed, _ = serve(engine, reqs)
        assert_reference(completed, reqs)
        m = engine.metrics
        assert m["spec_steps"] == m["decode_steps"] > 0
        # a perfect draft accepts k+1 tokens on almost every slot-step
        assert m["spec_accepted_tokens"] / m["spec_slot_steps"] > 2.0
        engine.close()

    def test_adversarial_draft_bit_identical(self):
        """A draft that ALWAYS disagrees still yields identical output —
        just one (corrected) token per slot-step, like plain decode."""
        engine = make_spec_engine(draft_cls=DisagreeingDraft)
        reqs = make_requests(4, seed=1, max_new=9)
        completed, _ = serve(engine, reqs)
        assert_reference(completed, reqs)
        m = engine.metrics
        assert m["spec_accepted_tokens"] == m["spec_slot_steps"]
        engine.close()

    def test_eos_mid_accepted_run(self):
        """An eos inside an accepted multi-token run truncates the stream
        at the eos (inclusive), exactly where the reference stops."""
        prompt = np.arange(1, 6, dtype=np.int32)
        ref = reference_decode(CFG, prompt, 16, eos_id=-1, max_len=32)
        eos = ref[len(ref) // 2]  # a token the generation provably emits
        engine = make_spec_engine(eos_id=eos)
        reqs = {"r0": (prompt, 16)}
        completed, _ = serve(engine, reqs)
        want = reference_decode(CFG, prompt, 16, eos_id=eos, max_len=32)
        assert completed["r0"]["tokens"] == want
        assert completed["r0"]["tokens"][-1] == eos
        engine.close()

    def test_max_len_boundary(self):
        """Requests that run into the max_len horizon clamp speculation
        (k_eff -> 0 near the edge) and stop exactly where plain decode
        stops."""
        engine = make_spec_engine(max_len=16)
        prompt = np.arange(1, 9, dtype=np.int32)  # 8 tokens, 16-cap
        reqs = {"r0": (prompt, 32)}
        completed, _ = serve(engine, reqs)
        ref = reference_decode(CFG, prompt, 32, eos_id=-1, max_len=16)
        assert completed["r0"]["tokens"] == ref
        engine.close()

    def test_single_token_request(self):
        """max_new=1 finishes at admission: no spec step runs at all."""
        engine = make_spec_engine()
        reqs = make_requests(2, seed=2, max_new=1)
        completed, _ = serve(engine, reqs)
        assert_reference(completed, reqs)
        assert engine.metrics["spec_steps"] == 0
        engine.close()

    def test_delta_stream_matches_plain(self):
        """Per-token deltas arrive for every accepted token with contiguous
        indices — a client can't tell speculation from plain decode."""
        engine = make_spec_engine()
        reqs = make_requests(2, seed=3, max_new=8)
        completed, streams = serve(engine, reqs, with_responses=True)
        engine.close()
        streams["resp_producer"].flush_topic("responses")
        seen = {rid: [] for rid in reqs}
        while True:
            try:
                proxy, meta = streams["resp_consumer"].next_with_metadata(
                    timeout=0.5
                )
            except (StopIteration, TimeoutError):
                break
            if meta.get("kind") == "delta":
                assert meta["index"] == len(seen[meta["req_id"]])
                seen[meta["req_id"]].append(meta["token"])
        for rid in reqs:
            assert seen[rid] == completed[rid]["tokens"]


class TestSpecConstruction:
    def test_spec_requires_draft_model(self):
        ctx = serve_context(CFG)
        with pytest.raises(ValueError, match="draft_model"):
            ServeEngine(ctx, {}, model=CountingModel(CFG), spec_k=2)

    def test_spec_requires_paged_layout(self):
        ctx = serve_context(CFG)
        with pytest.raises(ValueError, match="paged"):
            ServeEngine(
                ctx, {}, model=CountingModel(CFG), max_len=30, page_size=4,
                spec_k=2, draft_model=CountingModel(CFG),
            )

    def test_spec_k0_has_no_draft_pool(self):
        ctx = serve_context(CFG)
        engine = ServeEngine(ctx, {}, model=CountingModel(CFG))
        assert engine.draft_pages is None
        engine.close()


class TestSpecPaging:
    def test_both_pools_drain(self):
        """Rejected-draft rollback never leaks: both PageTables return to
        zero pages in use after the run (and the stores empty with them)."""
        engine = make_spec_engine(draft_cls=DisagreeingDraft)
        reqs = make_requests(6, seed=4, max_new=11)
        completed, _ = serve(engine, reqs)
        assert sorted(completed) == sorted(reqs)
        assert engine.pages.pages_in_use() == 0
        assert engine.draft_pages.pages_in_use() == 0
        engine.close()

    def test_draft_pool_backpressure(self):
        """A draft pool too small for every slot stalls admission (FIFO)
        instead of failing an extend mid-generation."""
        engine = make_spec_engine(slots=2, draft_num_pages=4)  # 1 slot's worth
        reqs = make_requests(3, seed=5, prompt_len=5, max_new=10)
        completed, _ = serve(engine, reqs)
        assert_reference(completed, reqs)
        assert engine.metrics["queued_admissions"] > 0
        assert engine.draft_pages.pages_in_use() == 0
        engine.close()

    def test_spec_with_prefix_sharing(self):
        """Shared target-pool prefixes (and their COW) compose with
        speculation; the draft pool never shares."""
        engine = make_spec_engine(slots=4)
        common = np.arange(1, 9, dtype=np.int32)  # two full shared pages
        reqs = {
            f"r{i}": (np.concatenate([common, [10 + i]]).astype(np.int32), 8)
            for i in range(4)
        }
        completed, _ = serve(engine, reqs)
        assert_reference(completed, reqs)
        assert engine.metrics["prefix_shared_pages"] > 0
        assert engine.pages.pages_in_use() == 0
        assert engine.draft_pages.pages_in_use() == 0
        engine.close()


class TestVerifyBatchContract:
    def test_decode_multi_k1_matches_decode_step(self):
        """K == 1 multi-token decode is bit-identical to decode_step."""
        import jax.numpy as jnp

        model = CountingModel(CFG)
        prompt = jnp.asarray(np.arange(1, 6, dtype=np.int32)[None])
        _, cache = model.prefill({}, prompt, 16)
        tok = jnp.asarray([[7]], jnp.int32)
        l1, c1 = model.decode_step({}, cache, tok, jnp.int32(5))
        l2, c2 = model.decode_multi({}, cache, tok, jnp.int32(5))
        assert np.array_equal(np.asarray(l1), np.asarray(l2[:, 0]))
        assert np.array_equal(
            np.asarray(c1["hist"]), np.asarray(c2["hist"])
        )

    def test_verify_batch_per_row_positions(self):
        """Rows verify at their OWN lengths: each row's logits equal the
        same tokens replayed through sequential decode_steps."""
        import jax.numpy as jnp

        model = CountingModel(CFG)
        prompts = np.asarray([[1, 2, 3, 0], [4, 5, 6, 7]], np.int32)
        lens = np.asarray([3, 4], np.int32)
        _, cache = model.prefill_batch(
            {}, jnp.asarray(prompts), jnp.asarray(lens), 16
        )
        toks = np.asarray([[9, 8, 7], [6, 5, 4]], np.int32)
        logits, _ = model.verify_batch(
            {}, cache, jnp.asarray(toks), jnp.asarray(lens)
        )
        for b in range(2):
            row_cache = {"hist": np.asarray(cache["hist"])[:, b : b + 1]}
            c = {"hist": jnp.asarray(row_cache["hist"])}
            for t in range(3):
                lt, c = model.decode_step(
                    {}, c, jnp.asarray([[toks[b, t]]], jnp.int32),
                    jnp.int32(int(lens[b]) + t),
                )
                assert np.array_equal(
                    np.asarray(lt[0]), np.asarray(logits[b, t])
                ), (b, t)
