"""Per-architecture smoke tests: reduced same-family configs, one forward /
train-step on CPU, asserting output shapes + finiteness (assignment §f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import arch_names, get_smoke_config
from repro.dist.sharding import (
    DEFAULT_RULES,
    abstract_params,
    count_params,
    materialize_params,
)
from repro.models.api import build_model, decode_cache_specs, synth_batch
from repro.models.layers import ModelContext

ARCHS = arch_names()


def make_ctx(cfg):
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    return ModelContext(cfg=cfg, mesh=mesh, rules=DEFAULT_RULES)


@pytest.fixture(scope="module")
def cache():
    return {}


def _setup(name):
    cfg = get_smoke_config(name).with_(remat="none")
    ctx = make_ctx(cfg)
    model = build_model(ctx)
    specs = model.param_specs()
    params = materialize_params(specs, jax.random.PRNGKey(0))
    return cfg, ctx, model, params


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_smoke(name):
    cfg, ctx, model, params = _setup(name)
    batch = synth_batch(cfg, batch=2, seq=32)
    with ctx.mesh:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True
        )(params)
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"
    # gradients exist and are finite for every leaf
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32))), (
            f"{name}: non-finite grad at {jax.tree_util.keystr(path)}"
        )
    # loss ~ log(vocab) at init (sanity that logits aren't degenerate)
    assert 0.5 * np.log(cfg.vocab) < float(metrics["ce"]) < 3.0 * np.log(cfg.vocab)


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_smoke(name):
    cfg, ctx, model, params = _setup(name)
    B, S, MAX = 2, 16, 32
    tokens = np.random.default_rng(0).integers(0, cfg.vocab, (B, S)).astype(np.int32)
    with ctx.mesh:
        logits, cache = model.prefill(params, jnp.asarray(tokens), MAX)
        assert logits.shape == (B, cfg.padded_vocab)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        nxt = jnp.argmax(logits[:, : cfg.vocab], -1).astype(jnp.int32)[:, None]
        logits2, cache = model.decode_step(params, cache, nxt, jnp.int32(S))
        assert logits2.shape == (B, cfg.padded_vocab)
        assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_prefill(name):
    """Teacher-forced decode must reproduce the prefill/next-token logits —
    catches cache-indexing and recurrence bugs."""
    if name == "qwen2-vl-72b":
        pytest.skip("mrope decode positions differ from text-only prefill stub")
    cfg, ctx, model, params = _setup(name)
    B, S = 1, 8
    rng = np.random.default_rng(1)
    tokens = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)
    with ctx.mesh:
        # full-sequence logits via prefill of S+1 tokens
        full_logits, _ = model.prefill(params, jnp.asarray(tokens), S + 4)
        # prefill S tokens then teacher-force the last one
        _, cache = model.prefill(params, jnp.asarray(tokens[:, :S]), S + 4)
        step_logits, _ = model.decode_step(
            params, cache, jnp.asarray(tokens[:, S:]), jnp.int32(S)
        )
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=0.15, atol=0.15,
    )


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_param_specs(name):
    """FULL configs build abstract params only (no allocation) with sane
    parameter counts."""
    from repro.configs import get_config

    cfg = get_config(name)
    ctx = make_ctx(cfg)
    model = build_model(ctx)
    specs = model.param_specs()
    n = count_params(specs)
    expected = {
        "deepseek-v3-671b": (600e9, 750e9),
        "granite-moe-1b-a400m": (0.8e9, 1.6e9),
        "whisper-medium": (0.6e9, 0.95e9),  # enc+dec 24L each ≈ 769M + pads
        "qwen2-vl-72b": (60e9, 80e9),
        "rwkv6-7b": (6e9, 9e9),
        "granite-8b": (7e9, 9.5e9),
        "smollm-135m": (0.1e9, 0.2e9),
        "stablelm-1.6b": (1.3e9, 2.0e9),
        "deepseek-7b": (6e9, 8e9),
        "zamba2-1.2b": (0.9e9, 1.8e9),
    }
    lo, hi = expected[name]
    assert lo < n < hi, f"{name}: {n/1e9:.2f}B params outside [{lo/1e9},{hi/1e9}]B"
    abstract = abstract_params(specs)
    assert all(
        hasattr(x, "shape") for x in jax.tree.leaves(abstract)
    )
