"""Unrolled (probe) vs scanned (production) paths must be numerically equal.

The dry-run's roofline probes lower `scan_layers=False` variants in which
every layer loop (``L.scan_stack``), attention chunk loop
(``blockwise_attention(unroll=)``), and SSM/RWKV chunk loop
(``wkv6_chunked``/``ssd_chunked``) is a Python unroll.  The probe
extrapolation is only valid if the unrolled program computes the *same
function*, so this suite pins exact (up to fp tolerance) equivalence on
every family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.dist.sharding import materialize_params
from repro.launch.mesh import make_host_mesh, rules_for
from repro.models import layers as L
from repro.models.api import build_model, synth_batch
from repro.models.layers import ModelContext

ARCHS = [
    "smollm-135m",          # dense GQA
    "granite-moe-1b-a400m", # MoE
    "deepseek-v3-671b",     # MLA + MoE + MTP
    "whisper-medium",       # enc-dec
    "rwkv6-7b",             # WKV6 chunk recurrence
    "zamba2-1.2b",          # Mamba2 SSD + shared attention
]


def _ctx_pair(arch):
    mesh = make_host_mesh()
    rules = rules_for(mesh)
    cfg_scan = get_smoke_config(arch)
    cfg_unroll = cfg_scan.with_(scan_layers=False)
    return ModelContext(cfg_scan, mesh, rules), ModelContext(cfg_unroll, mesh, rules)


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_scan_vs_unroll(arch):
    ctx_s, ctx_u = _ctx_pair(arch)
    model_s, model_u = build_model(ctx_s), build_model(ctx_u)
    params = materialize_params(model_s.param_specs(), jax.random.PRNGKey(0))
    batch = synth_batch(ctx_s.cfg, 2, 256, rng=1)
    with ctx_s.mesh:
        loss_s, _ = jax.jit(model_s.loss)(params, batch)
        loss_u, _ = jax.jit(model_u.loss)(params, batch)
    np.testing.assert_allclose(np.asarray(loss_s), np.asarray(loss_u),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["smollm-135m", "rwkv6-7b", "zamba2-1.2b"])
def test_prefill_scan_vs_unroll(arch):
    ctx_s, ctx_u = _ctx_pair(arch)
    model_s, model_u = build_model(ctx_s), build_model(ctx_u)
    params = materialize_params(model_s.param_specs(), jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 256), 0,
                                ctx_s.cfg.vocab)
    with ctx_s.mesh:
        lg_s, _ = jax.jit(lambda p, t: model_s.prefill(p, t, 256))(params, tokens)
        lg_u, _ = jax.jit(lambda p, t: model_u.prefill(p, t, 256))(params, tokens)
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_u),
                               rtol=2e-2, atol=2e-2)


def test_blockwise_attention_unroll_multichunk():
    """Force multiple q/kv chunks and compare scan vs unroll vs exact."""
    key = jax.random.PRNGKey(0)
    B, S, H, D = 2, 512, 4, 32
    q, k, v = (jax.random.normal(kk, (B, S, H, D), jnp.float32) * 0.3
               for kk in jax.random.split(key, 3))
    o_scan = L.blockwise_attention(q, k, v, causal=True, q_chunk=128,
                                   kv_chunk=128, unroll=False)
    o_unroll = L.blockwise_attention(q, k, v, causal=True, q_chunk=128,
                                     kv_chunk=128, unroll=True)
    np.testing.assert_allclose(np.asarray(o_scan), np.asarray(o_unroll),
                               rtol=1e-5, atol=1e-5)
    # exact reference
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -jnp.inf)
    o_ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(o_unroll), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_attn_chunks_divisor():
    assert L._attn_chunks(1500, 1024) == 750
    assert L._attn_chunks(4096, 1024) == 1024
    assert L._attn_chunks(7, 1024) == 7
    assert L._attn_chunks(32768, 1024) == 1024


def test_causal_skip_equivalence():
    """causal_skip (beyond-paper lever) must not change the function."""
    key = jax.random.PRNGKey(3)
    B, S, H, D = 2, 512, 4, 32
    q, k, v = (jax.random.normal(kk, (B, S, H, D), jnp.float32) * 0.3
               for kk in jax.random.split(key, 3))
    base = L.blockwise_attention(q, k, v, causal=True, q_chunk=128,
                                 kv_chunk=128)
    skip = L.blockwise_attention(q, k, v, causal=True, q_chunk=128,
                                 kv_chunk=128, causal_skip=True)
    skip_unroll = L.blockwise_attention(q, k, v, causal=True, q_chunk=128,
                                        kv_chunk=128, causal_skip=True,
                                        unroll=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(skip),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(base), np.asarray(skip_unroll),
                               rtol=1e-5, atol=1e-5)


def test_flat_dp_rules_resolve():
    """flat_dp profile shards batch over both axes and nothing over model."""
    from repro.dist.sharding import FLAT_DP_RULES, logical_to_spec
    from jax.sharding import PartitionSpec as P

    mesh = make_host_mesh()  # (1,1) same axis names
    spec = logical_to_spec((256, 128), ("batch", None), FLAT_DP_RULES, mesh)
    # on a 1×1 mesh everything degenerates to replication but resolution
    # must not error; real-mesh resolution is covered by the dry-run.
    assert isinstance(spec, P)


def test_attention_core_kernel_dispatch():
    """ctx.use_kernels routes GQA attention through the Pallas wrapper
    (jnp fallback on CPU) and must agree with the blockwise path."""
    from repro.models.layers import ModelContext, _attention_core
    from repro.configs import get_smoke_config

    mesh = make_host_mesh()
    cfg = get_smoke_config("smollm-135m")
    ctx_j = ModelContext(cfg, mesh, rules_for(mesh), use_kernels=False)
    ctx_k = ModelContext(cfg, mesh, rules_for(mesh), use_kernels=True)
    key = jax.random.PRNGKey(0)
    B, S, H, D = 2, 128, 4, 32
    q = jax.random.normal(key, (B, S, H, D), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, 2, D), jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, 2, D), jnp.float32) * 0.3
    o_j = _attention_core(ctx_j, q, k, v, causal=True)
    o_k = _attention_core(ctx_k, q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o_j), np.asarray(o_k),
                               rtol=2e-3, atol=2e-3)
