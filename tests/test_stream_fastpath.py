"""Event-driven streaming & futures fast-path tests (PR 3).

Covers the notification-based ``wait_for``/``wait_for_any`` connector
protocol (in-memory condition variables, file directory watches, the
backoff-poll fallback, cross-process wake-ups), the atomic
``put_if_absent`` future set path, the batched persistent-handle
``FileLogSubscriber`` (offset pickling included), consumer prefetch
ordering/backpressure, ``StoreExecutor.submit_future`` pipelining, and the
in-memory zero-copy parts channel.
"""
from __future__ import annotations

import os
import pickle
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import (
    FileConnector,
    FileLogPublisher,
    FileLogSubscriber,
    InMemoryConnector,
    QueuePublisher,
    QueueSubscriber,
    SharedMemoryConnector,
    Store,
    StoreExecutor,
    StreamConsumer,
    StreamProducer,
    extract,
    framing,
    is_resolved,
    wait_all,
    wait_for,
    wait_for_any,
)
from repro.core.connectors import new_key, put_payload_new
from repro.core.store import _STORE_REGISTRY


@pytest.fixture()
def store():
    with Store(f"sfp-{id(object())}", InMemoryConnector()) as s:
        yield s


class _BytesOnlyConnector:
    """Minimal protocol connector: exercises every duck-typed fallback."""

    def __init__(self):
        self.d = {}

    def put(self, key, data):
        self.d[key] = bytes(data)

    def get(self, key):
        return self.d.get(key)

    def exists(self, key):
        return key in self.d

    def evict(self, key):
        self.d.pop(key, None)

    def close(self):
        pass


# ---------------------------------------------------------------------------
# wait_for / wait_for_any
# ---------------------------------------------------------------------------


class TestWaitFor:
    @pytest.mark.parametrize("conn_factory", [
        InMemoryConnector,
        _BytesOnlyConnector,
    ])
    def test_wake_on_put(self, conn_factory):
        conn = conn_factory()
        key = new_key()
        woke = threading.Event()

        def waiter():
            wait_for(conn, key, timeout=5)
            woke.set()

        th = threading.Thread(target=waiter)
        th.start()
        time.sleep(0.02)
        assert not woke.is_set()
        conn.put(key, b"v")
        th.join(timeout=5)
        assert woke.is_set()

    def test_already_present_returns_immediately(self):
        conn = InMemoryConnector()
        conn.put("k", b"v")
        t0 = time.perf_counter()
        wait_for(conn, "k", timeout=5)
        assert time.perf_counter() - t0 < 0.05

    @pytest.mark.parametrize("conn_factory", [
        InMemoryConnector,
        _BytesOnlyConnector,
    ])
    def test_timeout(self, conn_factory):
        conn = conn_factory()
        t0 = time.perf_counter()
        with pytest.raises(TimeoutError):
            wait_for(conn, new_key(), timeout=0.05)
        # timed out close to the deadline, not after a huge backoff sleep
        assert time.perf_counter() - t0 < 1.0

    def test_file_connector_wake(self, tmp_path):
        conn = FileConnector(str(tmp_path / "ch"))
        key = new_key()
        got = {}

        def waiter():
            wait_for(conn, key, timeout=5)
            got["woke"] = time.perf_counter()

        th = threading.Thread(target=waiter)
        th.start()
        time.sleep(0.02)
        conn.put(key, b"payload")
        t_set = time.perf_counter()
        th.join(timeout=5)
        assert "woke" in got
        # directory watch wakes far faster than the old 10 ms poll ceiling
        assert got["woke"] - t_set < 0.3

    def test_file_connector_timeout(self, tmp_path):
        conn = FileConnector(str(tmp_path / "ch"))
        with pytest.raises(TimeoutError):
            wait_for(conn, new_key(), timeout=0.05)

    def test_file_connector_timeout_under_churn(self, tmp_path):
        """Unrelated-key churn must not starve the deadline (or spin)."""
        conn = FileConnector(str(tmp_path / "ch"))
        stop = threading.Event()

        def churn():
            i = 0
            while not stop.is_set():
                conn.put(f"other-{i % 4}", b"x")
                i += 1
                time.sleep(0.001)

        th = threading.Thread(target=churn)
        th.start()
        try:
            t0 = time.perf_counter()
            with pytest.raises(TimeoutError):
                wait_for(conn, new_key(), timeout=0.2)
            assert time.perf_counter() - t0 < 2.0
        finally:
            stop.set()
            th.join()

    def test_shm_unpublished_segment_is_invisible(self):
        """Commit protocol: a created-but-unwritten segment (zero header)
        must look absent to get/get_view/exists and the segment watch."""
        from multiprocessing import shared_memory

        conn = SharedMemoryConnector()
        key = new_key()
        seg = shared_memory.SharedMemory(
            name=conn._name(key), create=True, size=64
        )
        try:  # header is zero-filled: segment exists but is unpublished
            assert conn.get(key) is None
            assert conn.get_view(key) is None
            assert not conn.exists(key)
            assert not conn._seg_ready(key)
            with pytest.raises(TimeoutError):
                wait_for(conn, key, timeout=0.05)
        finally:
            seg.close()
            seg.unlink()

    def test_shm_failed_exclusive_put_leaves_key_absent(self):
        """A put_parts_new that dies mid-body must not wedge the key
        (half-written O_EXCL segment: retries see 'exists', readers see
        'absent', forever)."""
        conn = SharedMemoryConnector()
        key = new_key()

        class ExplodingPart:  # sized, but not bytes-like: body write raises
            def __len__(self):
                return 8

        with pytest.raises(TypeError):
            conn.put_parts_new(key, [b"ok", ExplodingPart()])
        assert not conn.exists(key)
        assert conn.put_parts_new(key, (b"retry",)) == 5  # key recovered
        assert conn.get(key) == b"retry"
        conn.evict(key)

    def test_shm_roundtrip_after_commit_protocol(self):
        conn = SharedMemoryConnector()
        key = new_key()
        conn.put(key, b"hello")
        assert conn.exists(key)
        assert conn.get(key) == b"hello"
        assert bytes(conn.get_view(key)) == b"hello"
        assert conn.put_parts_new(key, (b"x",)) is None  # still write-once
        conn.evict(key)

    def test_shm_connector_wake(self):
        conn = SharedMemoryConnector()
        key = new_key()
        woke = threading.Event()

        def waiter():
            wait_for(conn, key, timeout=5)
            woke.set()

        th = threading.Thread(target=waiter)
        th.start()
        time.sleep(0.02)
        conn.put(key, b"x")
        th.join(timeout=5)
        assert woke.is_set()
        conn.evict(key)


class TestWaitForAny:
    @pytest.mark.parametrize("conn_factory", [
        InMemoryConnector,
        _BytesOnlyConnector,
    ])
    def test_returns_ready_key(self, conn_factory):
        conn = conn_factory()
        keys = [new_key() for _ in range(4)]
        conn.put(keys[2], b"v")
        assert wait_for_any(conn, keys, timeout=1) == keys[2]

    def test_wakes_on_any_later_put(self):
        conn = InMemoryConnector()
        keys = [new_key() for _ in range(3)]
        result = {}

        def waiter():
            result["key"] = wait_for_any(conn, keys, timeout=5)

        th = threading.Thread(target=waiter)
        th.start()
        time.sleep(0.02)
        conn.put(keys[1], b"v")
        th.join(timeout=5)
        assert result["key"] == keys[1]

    @pytest.mark.parametrize("conn_factory", [
        InMemoryConnector,
        _BytesOnlyConnector,
    ])
    def test_timeout_when_none_ready(self, conn_factory):
        conn = conn_factory()
        t0 = time.perf_counter()
        with pytest.raises(TimeoutError):
            wait_for_any(conn, [new_key(), new_key()], timeout=0.05)
        assert time.perf_counter() - t0 < 1.0

    def test_timeout_zero_with_ready_key_returns(self):
        conn = InMemoryConnector()
        k = new_key()
        conn.put(k, b"v")
        assert wait_for_any(conn, [new_key(), k], timeout=0) == k

    def test_empty_keys_raises(self):
        with pytest.raises(ValueError):
            wait_for_any(InMemoryConnector(), [], timeout=1)

    def test_file_connector_wait_any(self, tmp_path):
        conn = FileConnector(str(tmp_path / "ch"))
        keys = [new_key() for _ in range(3)]
        result = {}

        def waiter():
            result["key"] = wait_for_any(conn, keys, timeout=5)

        th = threading.Thread(target=waiter)
        th.start()
        time.sleep(0.02)
        conn.put(keys[0], b"v")
        th.join(timeout=5)
        assert result["key"] == keys[0]


_XP_PRODUCER = """
import sys, time
from repro.core import FileConnector

directory, key = sys.argv[1], sys.argv[2]
time.sleep(0.2)
FileConnector(directory).put(key, b"from-subprocess")
"""


class TestCrossProcessWait:
    def test_subprocess_producer_wakes_parent(self, tmp_path):
        directory = str(tmp_path / "ch")
        conn = FileConnector(directory)
        key = new_key()
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.Popen(
            [sys.executable, "-c", _XP_PRODUCER, directory, key],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        try:
            wait_for(conn, key, timeout=30)
            assert conn.get(key) == b"from-subprocess"
        finally:
            out, err = proc.communicate(timeout=30)
        assert proc.returncode == 0, err.decode()

    def test_blocking_resolve_across_processes(self, tmp_path):
        directory = str(tmp_path / "ch2")
        key = new_key()
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        script = """
import sys, time
from repro.core import FileConnector, Store

directory, key = sys.argv[1], sys.argv[2]
time.sleep(0.2)
Store("xp-wait-res", FileConnector(directory)).put({"n": 7}, key=key)
"""
        proc = subprocess.Popen(
            [sys.executable, "-c", script, directory, key],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        try:
            with Store("xp-wait-par", FileConnector(directory)) as s:
                assert s.resolve(key, block=True, timeout=30) == {"n": 7}
        finally:
            out, err = proc.communicate(timeout=30)
        assert proc.returncode == 0, err.decode()
        _STORE_REGISTRY.pop("xp-wait-res", None)


# ---------------------------------------------------------------------------
# Atomic put-if-absent / future set_result
# ---------------------------------------------------------------------------


class TestPutIfAbsent:
    @pytest.mark.parametrize("make", [
        lambda tmp: InMemoryConnector(),
        lambda tmp: FileConnector(str(tmp / "pia")),
        lambda tmp: SharedMemoryConnector(),
        lambda tmp: _BytesOnlyConnector(),
    ])
    def test_first_wins(self, tmp_path, make):
        conn = make(tmp_path)
        key = new_key()
        assert put_payload_new(conn, key, (b"first",)) == 5
        assert put_payload_new(conn, key, (b"second",)) is None
        assert bytes(conn.get(key)) == b"first"
        conn.evict(key)

    def test_interned_empty_payload_single_winner(self):
        """Regression: b"" is a singleton — identity-based setdefault
        detection must still let exactly one setter win."""
        conn = InMemoryConnector()
        key = new_key()
        assert conn.put_new(key, b"") is True
        assert conn.put_new(key, b"") is False
        assert conn.get(key) == b""

    def test_store_level(self, store):
        assert store.put_if_absent([1], "k")
        assert not store.put_if_absent([2], "k")
        assert store.get("k") == [1]

    def test_double_set_result_raises_and_preserves(self, store):
        f = store.future()
        f.set_result("winner")
        with pytest.raises(RuntimeError):
            f.set_result("loser")
        assert f.result() == "winner"

    def test_racing_setters_exactly_one_wins(self, store):
        f = store.future()
        errors = []
        barrier = threading.Barrier(4)

        def setter(i):
            barrier.wait()
            try:
                f.set_result(i)
            except RuntimeError as e:
                errors.append(e)

        threads = [threading.Thread(target=setter, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(errors) == 3  # exactly one set succeeded
        assert f.result() in range(4)

    def test_set_exception_propagates(self, store):
        f = store.future()
        f.set_exception(ValueError("boom"))
        with pytest.raises(ValueError, match="boom"):
            f.result()
        with pytest.raises(ValueError, match="boom"):
            extract(f.proxy())


class TestWaitAll:
    def test_multi_key_single_wait(self, store):
        fs = [store.future() for _ in range(5)]

        def setter():
            for i, f in enumerate(reversed(fs)):  # out of order on purpose
                time.sleep(0.01)
                f.set_result(i)

        th = threading.Thread(target=setter)
        th.start()
        wait_all(fs, timeout=5)
        assert all(f.done() for f in fs)
        th.join()

    def test_timeout(self, store):
        fs = [store.future() for _ in range(2)]
        fs[0].set_result(1)
        with pytest.raises(TimeoutError):
            wait_all(fs, timeout=0.05)


# ---------------------------------------------------------------------------
# FileLogSubscriber: batched drain + offset pickling
# ---------------------------------------------------------------------------


class TestFileLogSubscriber:
    def test_batched_drain_many_events(self, tmp_path):
        pub = FileLogPublisher(str(tmp_path))
        events = [f"e{i}".encode() for i in range(200)]
        for e in events:
            pub.send_event("t", e)
        sub = FileLogSubscriber("t", str(tmp_path))
        got = [sub.next_event(timeout=5) for _ in range(200)]
        assert got == events
        sub.close()

    def test_waits_for_appends(self, tmp_path):
        pub = FileLogPublisher(str(tmp_path))
        sub = FileLogSubscriber("t", str(tmp_path))

        def later():
            time.sleep(0.05)
            pub.send_event("t", b"late")

        th = threading.Thread(target=later)
        th.start()
        assert sub.next_event(timeout=5) == b"late"
        th.join()
        sub.close()

    def test_partial_frame_then_completion(self, tmp_path):
        path = os.path.join(str(tmp_path), "t.log")
        body = b"x" * 32
        with open(path, "wb") as f:  # half a frame: header + truncated body
            f.write(len(body).to_bytes(8, "little") + body[:10])
        sub = FileLogSubscriber("t", str(tmp_path))
        with pytest.raises(TimeoutError):
            sub.next_event(timeout=0.05)
        with open(path, "ab") as f:
            f.write(body[10:])
        assert sub.next_event(timeout=5) == body
        sub.close()

    def test_reduce_carries_offset(self, tmp_path):
        """Regression: an unpickled consumer must not re-read the topic."""
        pub = FileLogPublisher(str(tmp_path))
        for i in range(4):
            pub.send_event("t", f"e{i}".encode())
        sub = FileLogSubscriber("t", str(tmp_path))
        assert sub.next_event(timeout=5) == b"e0"
        assert sub.next_event(timeout=5) == b"e1"
        clone = pickle.loads(pickle.dumps(sub))
        assert clone.offset == sub.offset
        assert clone.next_event(timeout=5) == b"e2"  # resumes, no re-read
        assert sub.next_event(timeout=5) == b"e2"  # original unaffected
        sub.close()
        clone.close()

    def test_offset_excludes_buffered_unreturned(self, tmp_path):
        """Pickle mid-buffer: frames drained but not returned are re-read."""
        pub = FileLogPublisher(str(tmp_path))
        for i in range(3):
            pub.send_event("t", f"e{i}".encode())
        sub = FileLogSubscriber("t", str(tmp_path))
        assert sub.next_event(timeout=5) == b"e0"  # drains all 3, returns 1
        clone = pickle.loads(pickle.dumps(sub))
        assert clone.next_event(timeout=5) == b"e1"
        assert clone.next_event(timeout=5) == b"e2"
        sub.close()
        clone.close()


# ---------------------------------------------------------------------------
# Shared-event fanout (in-process broker)
# ---------------------------------------------------------------------------


class TestSharedEventFanout:
    def test_subscribers_share_one_event_object(self, store):
        ns = f"fan-{id(store)}"
        subs = [QueueSubscriber("t", ns) for _ in range(3)]
        prod = StreamProducer(QueuePublisher(ns), {"t": store},
                              evict_on_resolve=False)
        prod.send("t", 42)
        prod.flush()
        raws = [s.next_event(timeout=5) for s in subs]
        assert all(isinstance(r, dict) for r in raws)  # never pickled
        assert raws[0] is raws[1] is raws[2]  # one shared object

    def test_consumers_resolve_from_shared_events(self, store):
        ns = f"fan2-{id(store)}"
        subs = [QueueSubscriber("t", ns) for _ in range(2)]
        prod = StreamProducer(QueuePublisher(ns), {"t": store},
                              evict_on_resolve=False)
        prod.send("t", np.arange(8))
        prod.flush()
        for sub in subs:
            p, _ = StreamConsumer(sub, timeout=5).next_with_metadata()
            np.testing.assert_array_equal(extract(p), np.arange(8))


# ---------------------------------------------------------------------------
# StreamConsumer prefetch
# ---------------------------------------------------------------------------


class TestPrefetch:
    def test_order_preserved_and_items_preresolved(self, store):
        ns = f"pf-{id(store)}"
        sub = QueueSubscriber("t", ns)
        with StreamProducer(QueuePublisher(ns), {"t": store}) as prod:
            for i in range(20):
                prod.send("t", {"i": i})
            prod.close_topic("t")
            got = []
            with StreamConsumer(sub, timeout=5, prefetch=4) as cons:
                time.sleep(0.05)  # let the pipeline run ahead
                for p in cons:
                    assert is_resolved(p)  # resolved before the consumer saw it
                    got.append(extract(p)["i"])
        assert got == list(range(20))

    def test_backpressure_bounds_inflight(self, store):
        """A slow consumer must cap resolutions at prefetch + 1 in flight."""
        resolved = []
        orig = store.resolve

        def counting_resolve(key, **kw):
            out = orig(key, **kw)
            resolved.append(key)
            return out

        store.resolve = counting_resolve
        ns = f"bp-{id(store)}"
        sub = QueueSubscriber("t", ns)
        prod = StreamProducer(QueuePublisher(ns), {"t": store},
                              evict_on_resolve=False)
        for i in range(16):
            prod.send("t", i)
        prod.close_topic("t")
        cons = StreamConsumer(sub, timeout=5, prefetch=3)
        time.sleep(0.3)  # consumer not iterating: pipeline must stall
        # ≤ N queued + 1 being held by the blocked _enqueue
        assert len(resolved) <= 4
        got = [extract(p) for p in cons]
        assert got == list(range(16))
        assert len(resolved) == 16
        cons.close()

    def test_prefetch_with_filter_and_eviction(self, store):
        ns = f"pff-{id(store)}"
        sub = QueueSubscriber("t", ns)
        prod = StreamProducer(QueuePublisher(ns), {"t": store},
                              evict_on_resolve=True)
        for i in range(8):
            prod.send("t", i, metadata={"i": i})
        prod.close_topic("t")
        cons = StreamConsumer(sub, timeout=5, prefetch=2,
                              filter_=lambda m: m["i"] % 2 == 0)
        assert [extract(p) for p in cons] == [0, 2, 4, 6]

    def test_prefetch_error_surfaces(self, store):
        ns = f"pfe-{id(store)}"
        sub = QueueSubscriber("t", ns)
        cons = StreamConsumer(sub, timeout=0.1, prefetch=2)
        with pytest.raises(TimeoutError):
            next(iter(cons))  # no producer: subscriber timeout propagates
        cons.close()

    def test_retry_after_error_reraises_not_hangs(self, store):
        """Terminal pipeline states are sticky: retries must not block."""
        ns = f"pfr-{id(store)}"
        sub = QueueSubscriber("t", ns)
        cons = StreamConsumer(sub, timeout=0.1, prefetch=2)
        for _ in range(3):  # every retry re-raises promptly
            with pytest.raises(TimeoutError):
                cons.next_with_metadata()
        cons.close()

    def test_retry_after_exhaustion_stops_not_hangs(self, store):
        ns = f"pfx-{id(store)}"
        sub = QueueSubscriber("t", ns)
        prod = StreamProducer(QueuePublisher(ns), {"t": store})
        prod.send("t", 1)
        prod.close_topic("t")
        cons = StreamConsumer(sub, timeout=5, prefetch=2)
        assert [extract(p) for p in cons] == [1]
        for _ in range(2):
            with pytest.raises(StopIteration):
                cons.next_with_metadata()
        cons.close()

    def test_metadata_dict_is_private_copy(self, store):
        """In-process shared events: one consumer's mutation must not leak."""
        ns = f"pfm-{id(store)}"
        subs = [QueueSubscriber("t", ns) for _ in range(2)]
        prod = StreamProducer(QueuePublisher(ns), {"t": store},
                              evict_on_resolve=False)
        src_meta = {"tag": "orig"}
        prod.send("t", 1, metadata=src_meta)
        prod.flush()
        _, meta_a = StreamConsumer(subs[0], timeout=5).next_with_metadata()
        meta_a["tag"] = "mutated"
        _, meta_b = StreamConsumer(subs[1], timeout=5).next_with_metadata()
        assert meta_b["tag"] == "orig"
        assert src_meta["tag"] == "orig"  # producer's dict untouched too


# ---------------------------------------------------------------------------
# StoreExecutor.submit_future
# ---------------------------------------------------------------------------


class TestSubmitFuture:
    def test_returns_future_immediately(self, store):
        from concurrent.futures import ThreadPoolExecutor

        gate = threading.Event()

        def slow():
            gate.wait(5)
            return 21

        with StoreExecutor(ThreadPoolExecutor(2), store) as ex:
            fut = gate_fut = ex.submit_future(slow)
            assert not fut.done()  # returned before the task ran
            gate.set()
            assert fut.result(timeout=5) == 21
            assert gate_fut.task.done()

    def test_chained_pipeline_overlaps(self, store):
        from concurrent.futures import ThreadPoolExecutor

        def stage(x):
            return extract(x) + 1 if is_proxy(x) else x + 1

        def is_proxy(x):
            from repro.core import Proxy

            return isinstance(x, Proxy)

        with StoreExecutor(ThreadPoolExecutor(4), store) as ex:
            f1 = ex.submit_future(stage, 0)
            f2 = ex.submit_future(stage, f1.proxy())  # submitted before f1 done
            f3 = ex.submit_future(stage, f2.proxy())
            assert f3.result(timeout=5) == 3

    def test_task_exception_reaches_consumer(self, store):
        from concurrent.futures import ThreadPoolExecutor

        def boom():
            raise KeyError("kaput")

        with StoreExecutor(ThreadPoolExecutor(1), store) as ex:
            fut = ex.submit_future(boom)
            with pytest.raises(KeyError, match="kaput"):
                fut.result(timeout=5)

    def test_unpicklable_result_releases_consumer(self, store):
        """A set_result failure must still publish an error payload —
        consumers blocked on the future can only be woken via the store."""
        from concurrent.futures import ThreadPoolExecutor

        with StoreExecutor(ThreadPoolExecutor(1), store) as ex:
            fut = ex.submit_future(lambda: threading.Lock())  # unpicklable
            with pytest.raises(Exception):
                fut.result(timeout=5)  # releases promptly, no hang

    def test_unpicklable_exception_releases_consumer(self, store):
        from concurrent.futures import ThreadPoolExecutor

        class EvilError(Exception):
            def __reduce__(self):
                raise TypeError("not today")

        def boom():
            raise EvilError()

        with StoreExecutor(ThreadPoolExecutor(1), store) as ex:
            fut = ex.submit_future(boom)
            with pytest.raises(RuntimeError, match="unpicklable"):
                fut.result(timeout=5)

    def test_future_not_pickled_with_task(self, store):
        from concurrent.futures import ThreadPoolExecutor

        with StoreExecutor(ThreadPoolExecutor(1), store) as ex:
            fut = ex.submit_future(lambda: "v")
            fut.result(timeout=5)
            clone = pickle.loads(pickle.dumps(fut))
            assert clone.task is None
            assert clone.result() == "v"


# ---------------------------------------------------------------------------
# In-memory zero-copy parts channel
# ---------------------------------------------------------------------------


class TestInMemoryZeroCopyParts:
    def test_resolve_aliases_producer_buffer(self, store):
        src = np.arange(1024, dtype=np.int64)
        key = store.put(src)
        out = store.resolve(key, fresh=True)
        assert not out.flags.writeable  # read-only channel alias
        assert np.shares_memory(out, src)  # pass-by-reference, no copy

    def test_get_joins_to_exact_bytes(self, store):
        src = np.arange(16, dtype=np.int64)
        key = store.put(src)
        data = store.connector.get(key)
        assert isinstance(data, bytes)
        np.testing.assert_array_equal(framing.decode(data), src)

    def test_get_view_over_parts_entry(self, store):
        key = store.put(np.arange(16))
        view = store.connector.get_view(key)
        assert isinstance(view, memoryview)
        np.testing.assert_array_equal(framing.decode(view), np.arange(16))

    def test_plain_put_keeps_snapshot_semantics(self, store):
        src = np.arange(8, dtype=np.int64)
        store.connector.put("snap", framing.join_parts(framing.encode(src)))
        src[0] = 99
        out = framing.decode(store.connector.get("snap"))
        assert out[0] == 0  # bytes put is a snapshot

    def test_decode_parts_writable_copies(self, store):
        src = np.arange(32, dtype=np.int64)
        key = store.put(src)
        out = store.resolve(key, writable=True)
        assert out.flags.writeable
        assert not np.shares_memory(out, src)
        out[0] = -1
        assert src[0] == 0
