"""Unit + property tests for the transparent lazy proxy and Store (paper §III)."""
import pickle

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    InMemoryConnector,
    Proxy,
    Store,
    extract,
    is_resolved,
    reset,
)


class _Obj:
    def __init__(self):
        self.val = 42

    def double(self):
        return self.val * 2


@pytest.fixture()
def store():
    with Store(f"test-{id(object())}", InMemoryConnector()) as s:
        yield s


class TestProxyTransparency:
    def test_lazy_resolution(self, store):
        calls = []

        def factory():
            calls.append(1)
            return [1, 2, 3]

        p = Proxy(factory)
        assert not is_resolved(p)
        assert calls == []
        assert len(p) == 3  # first op triggers resolution
        assert is_resolved(p)
        assert calls == [1]
        assert p[0] == 1
        assert calls == [1]  # cached

    def test_isinstance_transparency(self, store):
        p = store.proxy({"a": 1})
        assert isinstance(p, dict)
        p2 = store.proxy([1, 2])
        assert isinstance(p2, list)

    def test_operators(self, store):
        p = store.proxy(10)
        assert p + 5 == 15
        assert 5 + p == 15
        assert p * 2 == 20
        assert p - 1 == 9
        assert 21 - p == 11
        assert p / 4 == 2.5
        assert p // 3 == 3
        assert p % 3 == 1
        assert p**2 == 100
        assert -p == -10
        assert abs(store.proxy(-3)) == 3
        assert int(p) == 10
        assert float(p) == 10.0
        assert p < 11 and p > 9 and p <= 10 and p >= 10
        assert hash(p) == hash(10)

    def test_container_protocol(self, store):
        p = store.proxy({"x": 1, "y": 2})
        assert "x" in p
        assert sorted(p) == ["x", "y"]
        assert p["y"] == 2
        p["z"] = 3  # mutates local cached copy
        assert p["z"] == 3

    def test_attribute_forwarding(self, store):
        p = store.proxy(_Obj())
        assert p.val == 42
        assert p.double() == 84
        p.val = 7
        assert p.double() == 14

    def test_numpy_interop(self, store):
        arr = np.arange(12, dtype=np.float32).reshape(3, 4)
        p = store.proxy(arr)
        np.testing.assert_array_equal(np.asarray(p), arr)
        assert p.shape == (3, 4)
        np.testing.assert_allclose(p.sum(), arr.sum())
        # numpy functions accept the proxy directly
        np.testing.assert_allclose(np.sum(p), arr.sum())

    def test_jax_interop(self, store):
        import jax.numpy as jnp

        arr = np.ones((4, 4), np.float32)
        p = store.proxy(arr)
        # consumer code converts via the numpy array protocol (the proxy is
        # transparent to np.asarray) and feeds jax just-in-time
        out = jnp.asarray(np.asarray(p)) + 1
        assert float(out.sum()) == 32.0
        # a proxy of a *jax* array resolves to numpy (store serializer) and
        # is consumable the same way
        pj = store.proxy(jnp.ones((2, 2)))
        assert float(np.asarray(pj).sum()) == 4.0

    def test_reset_and_reresolve(self, store):
        p = store.proxy([1, 2])
        assert len(p) == 2
        reset(p)
        assert not is_resolved(p)
        assert len(p) == 2

    def test_pickle_roundtrip_pass_by_reference(self, store):
        p = store.proxy({"big": list(range(100))})
        _ = p["big"]  # resolve
        data = pickle.dumps(p)
        q = pickle.loads(data)
        assert not is_resolved(q)  # cache dropped: pass-by-reference
        assert q["big"][99] == 99

    def test_missing_target_raises(self, store):
        p = store.proxy("x")
        meta = object.__getattribute__(p, "__proxy_metadata__")
        store.evict(meta["key"])
        with pytest.raises(KeyError):
            extract(p)


class TestStore:
    def test_put_get_evict(self, store):
        k = store.put([1, 2, 3])
        assert store.exists(k)
        assert store.get(k) == [1, 2, 3]
        store.evict(k)
        assert not store.exists(k)
        assert store.get(k, "gone") == "gone"

    def test_metrics(self, store):
        p = store.proxy(np.zeros(1000))
        extract(p)
        m = store.metrics
        assert m.put_count == 1 and m.get_count == 1
        assert m.put_bytes > 1000

    def test_store_pickle_reattach(self, store):
        s2 = pickle.loads(pickle.dumps(store))
        assert s2.name == store.name
        k = s2.put("hello")
        assert store.get(k) == "hello"

    @given(st.one_of(st.integers(), st.text(), st.lists(st.integers(), max_size=20),
                     st.dictionaries(st.text(max_size=5), st.integers(), max_size=8)))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_property(self, obj):
        with Store(f"prop-{id(object())}", InMemoryConnector(), register=False) as s:
            p = s.proxy(obj)
            assert extract(p) == obj
            # transparency: equal and same type
            assert p == obj
            if obj is not None:
                assert isinstance(p, type(obj))


class TestConnectors:
    @pytest.mark.parametrize("conn_kind", ["memory", "file", "shm"])
    def test_connector_contract(self, conn_kind, tmp_path):
        from repro.core import FileConnector, SharedMemoryConnector

        if conn_kind == "memory":
            c = InMemoryConnector()
        elif conn_kind == "file":
            c = FileConnector(str(tmp_path / "store"))
        else:
            c = SharedMemoryConnector()
        try:
            assert c.get("nope") is None
            assert not c.exists("nope")
            c.put("k", b"hello world")
            assert c.exists("k")
            assert c.get("k") == b"hello world"
            c.put("k", b"overwrite")
            assert c.get("k") == b"overwrite"
            c.evict("k")
            assert not c.exists("k")
            c.evict("k")  # idempotent
        finally:
            if conn_kind == "shm":
                c.evict("k")
            c.close()

    @given(st.binary(min_size=0, max_size=4096))
    @settings(max_examples=30, deadline=None)
    def test_file_connector_bytes_property(self, payload):
        import tempfile

        from repro.core import FileConnector

        with tempfile.TemporaryDirectory() as d:
            c = FileConnector(d)
            c.put("k", payload)
            assert c.get("k") == payload
