"""Cross-process store server: the dist/data/serve layers run UNCHANGED.

The PR 9 acceptance bar: a real ``repro.launch.store_server`` process
(spawned per test class), with ``StoreServerConnector`` clients in the
parent and in subprocesses, driving the exact protocols the other layers
already speak — lease heartbeats with SIGKILL chaos, shard dispatch with
a straggler redispatch, and the serve delta/completion stream across an
engine restart.  Zero changes to those layers; the connector is the only
moving part.
"""
import json
import os
import pickle
import signal
import subprocess
import sys
import threading
import time

import pytest

from repro.core import Store
from repro.core.connectors import new_key
from repro.core.connectors_net import StoreServerConnector
from repro.core.sanitize import _conn_id

from _store_server_util import store_server


def _wait_until(predicate, timeout, what):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return
        time.sleep(0.02)
    raise AssertionError(f"timed out waiting for {what}")


def _subprocess_env():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


@pytest.fixture(scope="class")
def server():
    with store_server() as (addr, proc):
        yield addr, proc


# ---------------------------------------------------------------------------
# Channel identity + client robustness
# ---------------------------------------------------------------------------


_XP_PUTTER = """
import sys
from repro.core.connectors_net import StoreServerConnector
addr, ns = sys.argv[1], sys.argv[2]
c = StoreServerConnector(addr, namespace=ns)
c.put("from-subprocess", b"hello-across-processes")
c.close()
"""


class TestCrossClient:
    def test_two_clients_one_channel(self, server):
        addr, _ = server
        ns = new_key()
        a = StoreServerConnector(addr, namespace=ns)
        b = StoreServerConnector(addr, namespace=ns)
        a.put("k", b"from-a")
        assert b.get("k") == b"from-a"
        # ProxySan identity: a server-backed channel is ONE object across
        # clients — both connectors key to the same channel id
        assert _conn_id(a) == _conn_id(b)
        other = StoreServerConnector(addr, namespace=new_key())
        assert _conn_id(other) != _conn_id(a)  # namespaces are distinct channels
        for c in (a, b, other):
            c.close()

    def test_subprocess_put_visible_to_parent(self, server):
        addr, _ = server
        ns = new_key()
        parent = StoreServerConnector(addr, namespace=ns)
        proc = subprocess.run(
            [sys.executable, "-c", _XP_PUTTER, addr, ns],
            env=_subprocess_env(), capture_output=True, timeout=60,
        )
        assert proc.returncode == 0, proc.stderr.decode()
        assert parent.get("from-subprocess") == b"hello-across-processes"
        parent.close()

    def test_client_disconnect_does_not_wedge_server(self, server):
        addr, _ = server
        ns = new_key()
        rude = StoreServerConnector(addr, namespace=ns)
        rude.put("k", b"v")
        del rude  # abandon the pooled sockets without a goodbye
        survivor = StoreServerConnector(addr, namespace=ns)
        assert survivor.get("k") == b"v"
        survivor.close()

    def test_concurrent_wait_and_put_share_one_connector(self, server):
        """A thread parked in a server-side wait must not block another
        thread's put on the SAME connector (the pool contract the serve
        engine's puller/admission threads rely on)."""
        addr, _ = server
        c = StoreServerConnector(addr, namespace=new_key())
        won = []

        def waiter():
            won.append(c.wait_for_any(["a", "b"], timeout=30.0))

        t = threading.Thread(target=waiter, daemon=True)
        t.start()
        time.sleep(0.1)  # let the wait park server-side
        c.put("b", b"x")  # same connector, different pooled socket
        t.join(timeout=30)
        assert not t.is_alive() and won == ["b"]
        c.close()

    def test_error_frames_keep_connection_alive(self, server):
        addr, _ = server
        c = StoreServerConnector(addr, namespace=new_key())
        with pytest.raises(TimeoutError):
            c.wait_for("never", timeout=0.2)
        # the timed-out connection is still pooled and serviceable
        c.put("k", b"v")
        assert c.get("k") == b"v"
        c.close()


# ---------------------------------------------------------------------------
# Lease service over the server, with SIGKILL chaos
# ---------------------------------------------------------------------------


_XP_LEASE_WORKER = """
import sys, time
from repro.core import Store
from repro.core.connectors_net import StoreServerConnector
from repro.dist.lease import LeaseService

addr, ns, name, ttl, beats = (
    sys.argv[1], sys.argv[2], sys.argv[3], float(sys.argv[4]), int(sys.argv[5])
)
svc = LeaseService(
    Store(f"xp-srv-worker-{name}", StoreServerConnector(addr, namespace=ns),
          register=False),
    ttl=ttl,
)
svc.register(name)
print("REGISTERED", flush=True)
for _ in range(beats):
    time.sleep(ttl / 4)
    svc.renew(name)
"""


@pytest.mark.multiproc(timeout=120)
class TestLeaseOverServer:
    def test_heartbeat_sigkill_reregister(self, server):
        from repro.dist.lease import LeaseService

        addr, _ = server
        ns = new_key()
        ttl = 0.8
        monitor = LeaseService(
            Store("xp-srv-monitor", StoreServerConnector(addr, namespace=ns),
                  register=False),
            ttl=ttl,
        )
        # chaos: the worker would beat ~forever; we SIGKILL it mid-beat
        proc = subprocess.Popen(
            [sys.executable, "-c", _XP_LEASE_WORKER, addr, ns, "w0",
             str(ttl), "100000"],
            env=_subprocess_env(), stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        try:
            _wait_until(lambda: monitor.live() == ["w0"], 30, "worker live")
            gen = monitor.lease("w0").generation
            assert gen == 1
            proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=30)
            _wait_until(lambda: monitor.dead() == ["w0"], 30, "worker dead")
            assert monitor.live() == []
        finally:
            if proc.poll() is None:
                proc.kill()
                proc.wait(timeout=30)
        # re-register: a second incarnation claims the next generation
        proc2 = subprocess.Popen(
            [sys.executable, "-c", _XP_LEASE_WORKER, addr, ns, "w0",
             str(ttl), "2"],
            env=_subprocess_env(), stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        try:
            _wait_until(lambda: monitor.is_live("w0"), 30, "worker re-registered")
            assert monitor.lease("w0").generation == gen + 1
        finally:
            out, err = proc2.communicate(timeout=60)
        assert proc2.returncode == 0, err.decode()
        monitor.store.close()


# ---------------------------------------------------------------------------
# DispatchingDataLoader over the server (straggler redispatch intact)
# ---------------------------------------------------------------------------


@pytest.mark.multiproc(timeout=120)
class TestLoaderOverServer:
    def _batch(self, step):
        return {"step": step, "payload": bytes([step % 251]) * 512}

    def test_all_shards_in_order_over_server(self, server):
        from repro.core.proxy import extract
        from repro.data.pipeline import DispatchingDataLoader

        addr, _ = server
        loader = DispatchingDataLoader(
            self._batch,
            num_steps=6,
            store=Store("xp-srv-loader",
                        StoreServerConnector(addr, namespace=new_key()),
                        register=False),
            workers=2,
            prefetch=2,
        )
        got = [extract(p) for p in loader]
        assert [g["step"] for g in got] == list(range(6))
        assert all(g == self._batch(i) for i, g in enumerate(got))
        loader.stop()

    def test_straggler_redispatch_over_server(self, server):
        from repro.core.proxy import extract
        from repro.data.pipeline import DispatchingDataLoader, StragglerPolicy

        addr, _ = server
        release = threading.Event()
        hung = []

        def worker_fn(worker, step):
            if step == 3 and not hung:
                hung.append(worker)
                release.wait(timeout=60)
            return self._batch(step)

        loader = DispatchingDataLoader(
            self._batch,
            num_steps=6,
            store=Store("xp-srv-straggle",
                        StoreServerConnector(addr, namespace=new_key()),
                        register=False),
            workers=["dw0", "dw1"],
            policy=StragglerPolicy(
                warn_factor=2.0, redispatch_factor=4.0, window=8, min_samples=3
            ),
            worker_fn=worker_fn,
            prefetch=2,
            supervise_every=0.01,
            shard_timeout=60.0,
        )
        try:
            got = [extract(p) for p in loader]
            assert [g["step"] for g in got] == list(range(6))
            stragglers = [
                r for r in loader.redispatches
                if r["step"] == 3 and r["reason"] == "straggler"
            ]
            assert stragglers
            assert stragglers[0]["to"] != hung[0]
        finally:
            release.set()
            loader.stop()


# ---------------------------------------------------------------------------
# Serve protocol over the server, across an engine restart
# ---------------------------------------------------------------------------


_XP_SERVE_CLIENT = """
import json, sys
sys.path.insert(0, sys.argv[4])  # tests dir, for _serve_toy
import numpy as np
from _serve_toy import reference_decode
from repro.configs import get_smoke_config
from repro.core import Store
from repro.core.connectors_net import StoreServerConnector
from repro.core.streaming import (
    FileLogPublisher, FileLogSubscriber, StreamConsumer, StreamProducer,
)
from repro.serve.client import ServeClient

addr, logdir, n = sys.argv[1], sys.argv[2], int(sys.argv[3])
cfg = get_smoke_config("smollm-135m")
store = Store("xp-srv-req", StoreServerConnector(addr, namespace="serve-req"))
producer = StreamProducer(FileLogPublisher(logdir), {"requests": store})
rng = np.random.default_rng(42)
prompts = {}
for i in range(n):
    rid = f"x{i}"
    prompts[rid] = rng.integers(1, cfg.vocab, 5).astype(np.int32)
    producer.send(
        "requests",
        {"prompt": prompts[rid]},
        metadata={"req_id": rid, "max_new_tokens": 4},
    )
    producer.flush_topic("requests")
producer.close_topic("requests")

client = ServeClient(
    StreamConsumer(FileLogSubscriber("responses", logdir), timeout=60.0)
)
client.collect()  # until the (restarted) engine closes the topic
ok = True
for rid, prompt in prompts.items():
    ref = reference_decode(cfg, prompt, 4, max_len=32)
    rec = client.results.get(rid)
    if rec is None or rec.stream_tokens != ref or rec.result["tokens"] != ref:
        ok = False
print(json.dumps({
    "ok": ok and client.closed and not client.out_of_order,
    "n_results": len(client.results),
}))
"""


@pytest.mark.multiproc(timeout=180)
class TestServeOverServer:
    def test_serve_stream_survives_engine_restart_over_server(
        self, server, tmp_path
    ):
        """The TestCrossProcessClient scenario with every bulk payload on
        the TCP store instead of FileConnector: requests/responses resolve
        through ``StoreServerConnector`` while the FileLog carries only
        metadata.  Engine 1 serves 2 of 4 requests and is torn down; engine
        2 resumes from the pickled subscriber offset; the external client
        sees one continuous, ordered, complete stream."""
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
        from repro.core.streaming import (
            FileLogPublisher,
            FileLogSubscriber,
            StreamConsumer,
            StreamProducer,
        )
        from test_serve_stream import make_engine

        addr, _ = server
        logdir = str(tmp_path / "log")
        n = 4
        tests_dir = os.path.dirname(os.path.abspath(__file__))
        proc = subprocess.Popen(
            [sys.executable, "-c", _XP_SERVE_CLIENT, addr, logdir, str(n),
             tests_dir],
            env=_subprocess_env(), stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        try:
            resp_store = Store(
                "xp-srv-resp", StoreServerConnector(addr, namespace="serve-resp")
            )

            def resp_producer():
                return StreamProducer(
                    FileLogPublisher(logdir), {"responses": resp_store}
                )

            sub1 = FileLogSubscriber("requests", logdir)
            consumer1 = StreamConsumer(sub1, timeout=60.0)
            engine1 = make_engine()
            engine1.run(
                consumer1, resp_producer(), max_requests=2, close_responses=False
            )
            assert len(engine1.completed) == 2
            engine1.close(reclaim_responses=False)

            sub2 = pickle.loads(pickle.dumps(sub1))
            consumer2 = StreamConsumer(sub2, timeout=60.0)
            engine2 = make_engine()
            engine2.run(consumer2, resp_producer())
            assert len(engine2.completed) == 2
            engine2.close(reclaim_responses=False)

            out, err = proc.communicate(timeout=120)
        except BaseException:
            proc.kill()
            raise
        assert proc.returncode == 0, err.decode()
        report = json.loads(out.decode().strip().splitlines()[-1])
        assert report["ok"], report
        assert report["n_results"] == n


# ---------------------------------------------------------------------------
# Rude client disconnect releases parked wait threads (satellite bugfix)
# ---------------------------------------------------------------------------


class TestRudeDisconnectReleasesWaitThread:
    def _conn_threads(self):
        return sum(
            1
            for t in threading.enumerate()
            if t.name == "store-server-conn" and t.is_alive()
        )

    def test_wait_thread_released_on_peer_close(self):
        """A connection thread parked in a server-side WAIT for a client
        that rudely disconnected used to linger until the wait's own
        timeout (60s here).  The sliced wait probes the peer every
        ``_PEER_TICK``; the thread must be back within seconds of the
        close, far below the wait budget."""
        import socket as socket_mod

        from repro.core.connectors_net import (
            OP_WAIT,
            StoreServer,
            _F64,
            _pack_key,
            send_frame,
        )

        server = StoreServer().start()
        try:
            base = self._conn_threads()
            sock = socket_mod.create_connection((server.host, server.port))
            # park the connection's server thread in a 60s wait on a key
            # that never lands
            send_frame(
                sock, OP_WAIT, (_F64.pack(60.0), _pack_key("ns|never-set"))
            )
            _wait_until(
                lambda: self._conn_threads() == base + 1, 10,
                "wait parked server-side",
            )
            t0 = time.monotonic()
            sock.close()  # rude: no goodbye, the response is never read
            _wait_until(
                lambda: self._conn_threads() == base, 10,
                "parked thread released after peer close",
            )
            # released by the peer probe, not by the 60s wait expiring
            assert time.monotonic() - t0 < 10.0
        finally:
            server.stop()

    def test_patient_client_still_gets_the_push(self):
        """Control: slicing the server-side wait must not break the push
        contract — a connected client parked in wait_for is woken by the
        put, and the sliced wait still honors its own deadline."""
        from repro.core.connectors_net import StoreServer, StoreServerConnector

        server = StoreServer().start()
        try:
            c = StoreServerConnector(server.address, namespace=new_key())
            woken = []

            def waiter():
                c.wait_for("arrives", timeout=30.0)
                woken.append(time.monotonic())

            t = threading.Thread(target=waiter, daemon=True)
            t.start()
            time.sleep(0.6)  # let the wait park (and slice) server-side
            c.put("arrives", b"x")
            t.join(timeout=10)
            assert not t.is_alive() and woken
            with pytest.raises(TimeoutError):
                c.wait_for("never-arrives", timeout=0.4)
            c.close()
        finally:
            server.stop()
