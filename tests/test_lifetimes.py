"""Dedicated lifetime suite (paper §IV-C, Listing 4).

test_core_patterns covers the listing-level basics (one proxy per scope
kind); this suite pins down the contracts the serving and streaming
layers now lean on: multi-entry sweeps, the exception path, add-after-end,
lease extension under load, StaticLifetime's *actual* interpreter-exit
behavior (subprocess), custody handed to ``StreamProducer.send(lifetime=)``
— including the aggregator's merged-payload case — and how lifetimes
interact with Owned proxies under ProxySan (a lifetime sweeping an owned
cell makes the later ``free()`` a double-free, and the sanitizer says so).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time

import pytest

from repro.core import sanitize
from repro.core.connectors import FileConnector, new_key
from repro.core.lifetimes import ContextLifetime, LeaseLifetime, StaticLifetime
from repro.core.ownership import _state, free, owned_proxy
from repro.core.store import Store
from repro.core.streaming import (
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture
def store():
    st = Store(f"lt-{new_key()}", register=False)
    yield st


def proxy_key(p) -> str:
    return object.__getattribute__(p, "__proxy_metadata__")["key"]


class TestContextLifetime:
    def test_exit_evicts_every_entry(self, store):
        """A scope owning many objects — direct keys and proxies mixed —
        sweeps all of them at exit, in one pass."""
        with ContextLifetime() as lt:
            keys = [store.put({"i": i}) for i in range(4)]
            for k in keys:
                lt.add(store, k)
            p = store.proxy("tail", lifetime=lt)
            keys.append(proxy_key(p))
            assert all(store.exists(k) for k in keys)
            assert sorted(lt.keys()) == sorted(keys)
        assert lt.done()
        assert not any(store.exists(k) for k in keys)
        assert list(lt.keys()) == []  # entries handed off, not retained

    def test_exception_path_still_evicts(self, store):
        """Cleanup is exceptional-path-safe — the point of tying lifetime
        to a ``with`` block rather than to manual evict calls."""
        with pytest.raises(RuntimeError, match="boom"):
            with ContextLifetime() as lt:
                p = store.proxy("v", lifetime=lt)
                key = proxy_key(p)
                raise RuntimeError("boom")
        assert not store.exists(key)

    def test_add_proxy_takes_custody(self, store):
        lt = ContextLifetime()
        p = store.proxy("payload")  # minted outside any scope
        lt.add_proxy(p)
        lt.close()
        assert not store.exists(proxy_key(p))

    def test_add_after_end_raises(self, san):
        store = Store(f"lt-end-{new_key()}", sanitize=True, register=False)
        lt = ContextLifetime()
        lt.close()
        with pytest.raises(RuntimeError, match="ended lifetime"):
            lt.add(store, "k")
        with pytest.raises(RuntimeError, match="ended lifetime"):
            store.proxy("v", lifetime=lt)
        # the refused proxy's payload must not be orphaned (a real leak
        # ProxySan found here: put-then-add minted before the raise)
        assert san.leak_report(store=store.name) == []

    def test_close_is_idempotent(self, store):
        lt = ContextLifetime()
        key = store.put("v")
        lt.add(store, key)
        lt.close()
        lt.close()  # second close: no error, no double-evict side effects
        assert lt.done()


class TestLeaseLifetime:
    def test_expiry_evicts(self, store):
        lease = LeaseLifetime(store, expiry=0.1)
        key = store.put("leased")
        lease.add(store, key)
        deadline = time.monotonic() + 5
        while not lease.done() and time.monotonic() < deadline:
            time.sleep(0.02)
        assert lease.done()
        assert not store.exists(key)

    def test_extend_outlives_original_expiry(self, store):
        lease = LeaseLifetime(store, expiry=0.15)
        key = store.put("renewed")
        lease.add(store, key)
        lease.extend(0.4)
        time.sleep(0.25)  # past the original expiry, inside the extension
        assert not lease.done()
        assert store.exists(key)
        lease.close()

    def test_remaining_counts_down(self, store):
        lease = LeaseLifetime(store, expiry=30.0)
        r0 = lease.remaining()
        assert 0 < r0 <= 30.0
        lease.extend(10.0)
        assert lease.remaining() > r0  # extension visible immediately
        lease.close()
        assert lease.done()

    def test_extend_after_expiry_raises(self, store):
        lease = LeaseLifetime(store, expiry=0.05)
        deadline = time.monotonic() + 5
        while not lease.done() and time.monotonic() < deadline:
            time.sleep(0.02)
        with pytest.raises(RuntimeError, match="expired lease"):
            lease.extend(1.0)


STATIC_CHILD = textwrap.dedent(
    """
    import sys

    sys.path.insert(0, sys.argv[2])
    from repro.core.connectors import FileConnector
    from repro.core.lifetimes import StaticLifetime
    from repro.core.store import Store

    store = Store("static-child", FileConnector(sys.argv[1]))
    lt = StaticLifetime()
    key = store.put({"pinned": True})
    lt.add(store, key)
    assert store.exists(key)  # alive for the whole program...
    print(key)
    # ...and reclaimed by the atexit hook after this line
    """
)


class TestStaticLifetime:
    @pytest.mark.multiproc(timeout=60)
    def test_atexit_reclaims_in_real_interpreter_exit(self, tmp_path):
        """The registered atexit hook actually runs: a child process pins a
        payload for its whole life; after a *normal* exit the file-backed
        cell is gone."""
        child = tmp_path / "static_child.py"
        child.write_text(STATIC_CHILD)
        chan = tmp_path / "chan"
        r = subprocess.run(
            [sys.executable, str(child), str(chan), SRC],
            capture_output=True, text=True, timeout=60,
        )
        assert r.returncode == 0, r.stderr
        key = r.stdout.strip().splitlines()[-1]
        assert key
        conn = FileConnector(str(chan))
        assert not conn.exists(key)  # swept by atexit, not leaked

    def test_manual_close_before_exit(self, store):
        lt = StaticLifetime()
        key = store.put("pinned")
        lt.add(store, key)
        assert store.exists(key)
        lt.close()  # test hygiene: don't wait for interpreter exit
        assert not store.exists(key)


class TestOwnedProxyInteraction:
    def test_lifetime_sweep_of_owned_cell_makes_free_a_double_free(self, san):
        """A lifetime and an owner are two custodians for one cell — a
        custody conflict.  The sweep wins the race here, and ProxySan
        flags the owner's later ``free()`` for what it now is."""
        store = Store(f"lt-own-{new_key()}", sanitize=True, register=False)
        o = owned_proxy(store, {"shared-custody": 1})
        lt = ContextLifetime()
        lt.add(store, _state(o).key)
        lt.close()  # the sweep evicts the owned cell
        assert not store.exists(_state(o).key)
        with sanitize.expecting() as exp:
            free(o)
        assert exp.categories() == {"double_free"}

    def test_free_then_sweep_is_benign(self, san):
        """The reverse order is fine: the owner freed its cell, and the
        lifetime's later sweep of the same key is a no-op evict — counted,
        never flagged."""
        store = Store(f"lt-own2-{new_key()}", sanitize=True, register=False)
        o = owned_proxy(store, {"freed-first": 1})
        lt = ContextLifetime()
        lt.add(store, _state(o).key)
        free(o)
        before = len(san.violations)
        lt.close()
        assert len(san.violations) == before

    def test_sweep_counter_under_sanitizer(self, san):
        store = Store(f"lt-cnt-{new_key()}", sanitize=True, register=False)
        base = san.counters.get("lifetime_sweeps", 0)
        lt = ContextLifetime()
        lt.add(store, store.put("a"))
        lt.close()
        empty = ContextLifetime()
        empty.close()  # nothing owned: not a sweep
        assert san.counters.get("lifetime_sweeps", 0) == base + 1


class TestStreamCustody:
    """``StreamProducer.send(lifetime=)``: the producer attaches the minted
    key at flush time, so payloads the consumer never resolves are
    reclaimed by scope end — the serve engine's per-request pattern."""

    def _pair(self, store, **producer_kw):
        ns = f"ltc-{new_key()}"
        producer = StreamProducer(QueuePublisher(ns), {"t": store}, **producer_kw)
        consumer = StreamConsumer(QueueSubscriber("t", ns), timeout=5)
        return producer, consumer

    def test_unresolved_payload_reclaimed_at_scope_end(self, store):
        producer, consumer = self._pair(store)
        lt = ContextLifetime()
        producer.send("t", {"bulk": list(range(16))}, lifetime=lt)
        producer.flush_topic("t")
        proxy, _ = consumer.next_with_metadata()
        key = proxy_key(proxy)
        assert store.exists(key)  # consumer saw the event, never resolved
        lt.close()
        assert not store.exists(key)

    def test_lifetime_is_optional(self, store):
        producer, consumer = self._pair(store)
        producer.send("t", {"free-floating": True})
        producer.flush_topic("t")
        proxy, _ = consumer.next_with_metadata()
        assert store.exists(proxy_key(proxy))  # unowned: survives (by design)
        store.evict(proxy_key(proxy))

    def test_aggregated_batch_owned_by_every_constituent_lifetime(self, store):
        """The aggregator merges N sends into one payload; that payload
        belongs to every lifetime that covered a constituent — closing any
        one of them may evict it (documented sharp edge)."""
        producer, consumer = self._pair(
            store, batch_size=8, aggregator=lambda objs: {"merged": objs}
        )
        lt_a, lt_b = ContextLifetime(), ContextLifetime()
        producer.send("t", {"from": "a"}, lifetime=lt_a)
        producer.send("t", {"from": "b"}, lifetime=lt_b)
        producer.flush_topic("t")
        proxy, _ = consumer.next_with_metadata()
        key = proxy_key(proxy)
        assert store.exists(key)
        lt_a.close()  # either custodian suffices
        assert not store.exists(key)
        lt_b.close()  # the other's sweep is a harmless no-op
