"""Multi-host chaos suite (PR 4): kill a heartbeating worker subprocess
mid-run and prove the whole fault path fires —

    lease expiry → elastic_plan → MeshPlan → Trainer.remesh → training
    resumes → a checkpoint written *before* the mesh change restores
    bit-identically *after* it, through resharded per-chunk leaves.

Worker subprocesses are real interpreters heartbeating over a
``FileConnector`` (the cross-process mediated channel); the parent runs the
monitor, the ``ElasticMeshDriver`` watch thread, and the trainer.  On this
1-device box the mesh factory maps every plan onto a 1-device mesh *with
the plan's axis character* (pod axis present ⇔ multi-pod plan), so the
remesh really swaps rules profiles, re-jits, and re-device_puts — the same
code path a 512-chip deployment takes, scaled down.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import FileConnector, Store
from repro.data.pipeline import SyntheticCorpus
from repro.dist.fault import MeshPlan
from repro.dist.lease import LeaseService
from repro.launch.mesh import ElasticMeshDriver, rules_for
from repro.models.layers import ModelContext
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def _subprocess_env():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _wait_until(predicate, timeout, what):
    deadline = time.time() + timeout
    while not predicate():
        assert time.time() < deadline, f"timed out waiting for {what}"
        time.sleep(0.05)


# A chip host: registers its lease and heartbeats forever (until SIGKILL).
# An expiry (e.g. a long stall) re-registers — the lease protocol's
# recovery path; a fencing loss is fatal (another incarnation owns the name).
_CHAOS_WORKER = """
import sys, time
from repro.core import FileConnector, Store
from repro.dist.lease import LeaseService, LeaseExpired, LeaseLost

directory, name, ttl = sys.argv[1], sys.argv[2], float(sys.argv[3])
svc = LeaseService(
    Store(f"chaos-w-{name}", FileConnector(directory), register=False), ttl=ttl
)
svc.register(name)
while True:
    time.sleep(ttl / 5)
    try:
        svc.renew(name)
    except LeaseExpired:
        svc.register(name)
    except LeaseLost:
        sys.exit(3)
"""


def _smoke_mesh(plan: MeshPlan):
    """Map any MeshPlan onto this box's 1 device, keeping the plan's axis
    character so rules_for still switches pod/multipod resolution."""
    if plan.pods > 1:
        return jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))


TTL = 2.0  # generous: a CPU-share-throttled box must not flap healthy leases


@pytest.mark.multiproc(timeout=480)
class TestChaos:
    def test_worker_death_remesh_and_resharded_restore(self, tmp_path):
        lease_dir = str(tmp_path / "leases")
        monitor = LeaseService(
            Store("chaos-mon", FileConnector(lease_dir), register=False), ttl=TTL
        )
        procs = {
            name: subprocess.Popen(
                [sys.executable, "-c", _CHAOS_WORKER, lease_dir, name, str(TTL)],
                env=_subprocess_env(),
                stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            )
            for name in ("hostA", "hostB")
        }
        driver = None
        try:
            _wait_until(
                lambda: monitor.live() == ["hostA", "hostB"], 30, "both hosts live"
            )

            cfg = get_smoke_config("smollm-135m")
            mesh0 = _smoke_mesh(MeshPlan(2, 16, 16))
            ctx = ModelContext(cfg, mesh0, rules_for(mesh0))
            tc = TrainerConfig(
                opt=AdamWConfig(lr=1e-3, warmup_steps=2),
                ckpt_every=100,  # only the end-of-train saves matter here
                ckpt_dir=str(tmp_path / "ckpt"),
                log_every=10**6,
            )
            trainer = Trainer(ctx, tc)
            trainer.init_state()
            # 2 live hosts × 256 chips → the full 2-pod 512-chip plan
            driver = ElasticMeshDriver(
                monitor, trainer, cfg,
                chips_per_worker=256, model_parallel=16, chips_per_pod=256,
                mesh_factory=_smoke_mesh,
            )
            assert driver.plan == MeshPlan(2, 16, 16)
            assert "pod" in trainer.ctx.mesh.shape
            driver.start(poll=0.25)

            corpus = SyntheticCorpus(cfg, 2, 32)
            batches = [corpus.next_batch(i) for i in range(12)]
            # phase 1: train on the full mesh; train() checkpoints step 6
            trainer.train(batches[:6], 6, log=lambda m: None)
            assert trainer.step_num == 6
            pre = jax.tree.map(lambda x: np.array(x, copy=True), trainer.state)

            # chaos: SIGKILL a heartbeating host mid-run
            procs["hostB"].kill()
            procs["hostB"].wait(timeout=30)
            _wait_until(lambda: "hostB" in monitor.dead(), 30, "lease expiry")
            _wait_until(
                lambda: trainer._pending_remesh is not None, 30, "remesh request"
            )

            # phase 2: training resumes; the remesh applies at the boundary
            trainer.train(batches[6:], 12, log=lambda m: None)
            assert trainer.step_num == 12
        finally:
            if driver is not None:
                driver.stop()
            for p in procs.values():
                if p.poll() is None:
                    p.kill()
                p.communicate(timeout=30)

        # the degraded plan dropped the dead pod, model parallelism pinned
        assert driver.plan == MeshPlan(1, 16, 16)
        replans = [e for e in driver.events if e["kind"] == "replan"]
        assert replans and replans[-1]["to"] == "data:16xmodel:16"
        assert trainer.remeshes
        assert trainer.remeshes[-1]["mesh_axes"] == ("data", "model")
        assert "pod" not in trainer.ctx.mesh.shape

        # the step-6 checkpoint (written on the 2-pod mesh) restores
        # bit-identically under the post-change mesh, via resharded leaves
        restored, step = trainer.ckpt.restore(
            trainer._abstract_state(), step=6,
            shardings=trainer.bundle.state_shardings,
        )
        assert step == 6
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)
            ),
            pre, restored,
        )
        with open(os.path.join(str(tmp_path / "ckpt"), "manifest-6.json")) as f:
            manifest = json.load(f)
        leaves = manifest["leaves"].values()
        assert all("keys" in m for m in leaves)  # per-shard slices, no
        assert any(len(m["keys"]) > 1 for m in leaves)  # whole-leaf blobs
