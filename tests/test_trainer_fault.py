"""Fault-tolerance tests: checkpoint/restart, elastic re-mesh, stragglers,
heartbeats — the large-scale-runnability substrate."""
from __future__ import annotations

import os

import jax
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.store import Store
from repro.data.pipeline import StreamingDataLoader, SyntheticCorpus
from repro.dist.fault import HeartbeatMonitor, StragglerPolicy, elastic_plan
from repro.dist.sharding import materialize_params
from repro.launch.mesh import make_host_mesh, rules_for
from repro.models.api import build_model
from repro.models.layers import ModelContext
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def ctx():
    cfg = get_smoke_config("smollm-135m")
    mesh = make_host_mesh()
    return ModelContext(cfg, mesh, rules_for(mesh))


def make_trainer(ctx, tmp, **kw):
    tc = TrainerConfig(
        opt=AdamWConfig(lr=1e-3, warmup_steps=2),
        ckpt_every=kw.pop("ckpt_every", 3),
        ckpt_dir=str(tmp),
        log_every=1000,
        **kw,
    )
    return Trainer(ctx, tc)


def data(ctx, n):
    corpus = SyntheticCorpus(ctx.cfg, 2, 32)
    return [corpus.next_batch(i) for i in range(n)]


class TestCheckpoint:
    def test_save_restore_roundtrip(self, ctx, tmp_path):
        model = build_model(ctx)
        params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save(params, step=7)
        restored, step = mgr.restore(params)
        assert step == 7
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            params, restored,
        )
        mgr.close()

    def test_async_save_overlaps_and_retention(self, ctx, tmp_path):
        model = build_model(ctx)
        params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            fut = mgr.save_async(params, step=s)
            assert fut is not None
        mgr.wait()
        mgr.wait()  # idempotent
        steps = sorted(
            int(f.split("-")[1].split(".")[0])
            for f in os.listdir(tmp_path) if f.startswith("manifest-")
        )
        assert steps == [3, 4]  # keep-last-2 enforced by ownership frees
        mgr.close()

    def test_elastic_restore_across_meshes(self, ctx, tmp_path):
        """Checkpoint written under one mesh restores under another."""
        model = build_model(ctx)
        params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path), keep=1)
        mgr.save(params, step=1)

        mesh2 = jax.make_mesh((1,), ("model",))
        from repro.dist.sharding import DEFAULT_RULES, sharding_tree

        sh = sharding_tree(model.param_specs(), DEFAULT_RULES, mesh2)
        restored, step = mgr.restore(params, shardings=sh)
        assert step == 1
        leaf = jax.tree.leaves(restored)[0]
        assert leaf.sharding.mesh.axis_names == ("model",)
        mgr.close()


class TestTrainerFaults:
    def test_crash_restart_resumes_from_checkpoint(self, ctx, tmp_path):
        trainer = make_trainer(ctx, tmp_path, ckpt_every=2, max_failures=2)
        trainer.init_state()
        crashed = []

        def fail_once(step):
            if step == 4 and not crashed:
                crashed.append(step)
                raise RuntimeError("injected node failure")

        hist = trainer.train(data(ctx, 12), 6, fail_hook=fail_once, log=lambda m: None)
        assert crashed == [4]
        assert trainer.step_num == 6
        assert trainer.failures == 1
        assert [h["step"] for h in hist][-1] == 6

    def test_failure_budget_exhaustion_raises(self, ctx, tmp_path):
        trainer = make_trainer(ctx, tmp_path, max_failures=1)
        trainer.init_state()

        def always_fail(step):
            raise RuntimeError("persistent failure")

        with pytest.raises(RuntimeError):
            trainer.train(data(ctx, 8), 4, fail_hook=always_fail, log=lambda m: None)

    def test_remesh_preserves_state(self, ctx, tmp_path):
        trainer = make_trainer(ctx, tmp_path)
        trainer.init_state()
        trainer.train(data(ctx, 3), 2, log=lambda m: None)
        before = jax.tree.map(np.asarray, trainer.state["params"])
        new_mesh = jax.make_mesh((1, 1), ("data", "model"))
        trainer.remesh(ModelContext(ctx.cfg, new_mesh, rules_for(new_mesh)))
        after = jax.tree.map(np.asarray, trainer.state["params"])
        jax.tree.map(np.testing.assert_array_equal, before, after)
        trainer.train(data(ctx, 6)[2:], 4, log=lambda m: None)  # still trains
        assert trainer.step_num == 4


class TestFaultPrimitives:
    def test_heartbeat_lease_lifecycle(self):
        store = Store("hb-test")
        mon = HeartbeatMonitor(store, ttl=0.3)
        mon.register("w0")
        mon.register("w1")
        assert set(mon.live_workers()) == {"w0", "w1"}
        import time

        for _ in range(3):  # w0 keeps beating; w1 goes silent
            time.sleep(0.15)
            mon.heartbeat("w0")
        time.sleep(0.25)
        assert "w1" in mon.dead_workers()
        with pytest.raises(TimeoutError):
            mon.heartbeat("w1")  # dead workers must re-register
        store.close()

    def test_elastic_plan_shrinks_after_loss(self):
        full = elastic_plan(512, model_parallel=16, chips_per_pod=256)
        assert (full.pods, full.data, full.model) == (2, 16, 16)
        degraded = elastic_plan(512 - 96, model_parallel=16, chips_per_pod=256)
        assert degraded.model == 16
        assert degraded.chips <= 512 - 96
        tiny = elastic_plan(48, model_parallel=16)
        assert tiny.data == 2  # 48//16=3 → pow2 floor
        with pytest.raises(ValueError):
            elastic_plan(8, model_parallel=16)

    def test_straggler_policy_decisions(self):
        pol = StragglerPolicy(warn_factor=2.0, redispatch_factor=4.0)
        for _ in range(6):
            assert pol.observe(1.0) is None
        assert pol.observe(2.5) == "warn"
        assert pol.observe(5.0) == "redispatch"
        assert pol.observe(1.1) is None


class TestPipeline:
    def test_loader_yields_proxies_in_order(self, ctx):
        corpus = SyntheticCorpus(ctx.cfg, 2, 16)
        loader = StreamingDataLoader(corpus.next_batch, num_steps=5, prefetch=2)
        from repro.core.proxy import Proxy, extract

        steps = []
        for p in loader:
            assert isinstance(p, Proxy)
            steps.append(extract(p)["tokens"].shape)
        assert len(steps) == 5
        assert all(s == (2, 16) for s in steps)
