"""Fault-tolerance tests: checkpoint/restart, elastic re-mesh, stragglers,
heartbeats, shard redispatch — the large-scale-runnability substrate."""
from __future__ import annotations

import json
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.proxy import extract
from repro.core.store import Store
from repro.data.pipeline import (
    DispatchingDataLoader,
    StreamingDataLoader,
    SyntheticCorpus,
)
from repro.dist.fault import HeartbeatMonitor, StragglerPolicy, elastic_plan
from repro.dist.sharding import materialize_params
from repro.launch.mesh import make_host_mesh, rules_for
from repro.models.api import build_model
from repro.models.layers import ModelContext
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def ctx():
    cfg = get_smoke_config("smollm-135m")
    mesh = make_host_mesh()
    return ModelContext(cfg, mesh, rules_for(mesh))


def make_trainer(ctx, tmp, **kw):
    tc = TrainerConfig(
        opt=AdamWConfig(lr=1e-3, warmup_steps=2),
        ckpt_every=kw.pop("ckpt_every", 3),
        ckpt_dir=str(tmp),
        log_every=1000,
        **kw,
    )
    return Trainer(ctx, tc)


def data(ctx, n):
    corpus = SyntheticCorpus(ctx.cfg, 2, 32)
    return [corpus.next_batch(i) for i in range(n)]


class TestCheckpoint:
    def test_save_restore_roundtrip(self, ctx, tmp_path):
        model = build_model(ctx)
        params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save(params, step=7)
        restored, step = mgr.restore(params)
        assert step == 7
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            params, restored,
        )
        mgr.close()

    def test_async_save_overlaps_and_retention(self, ctx, tmp_path):
        model = build_model(ctx)
        params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            fut = mgr.save_async(params, step=s)
            assert fut is not None
        mgr.wait()
        mgr.wait()  # idempotent
        steps = sorted(
            int(f.split("-")[1].split(".")[0])
            for f in os.listdir(tmp_path) if f.startswith("manifest-")
        )
        assert steps == [3, 4]  # keep-last-2 enforced by ownership frees
        mgr.close()

    def test_elastic_restore_across_meshes(self, ctx, tmp_path):
        """Checkpoint written under one mesh restores under another."""
        model = build_model(ctx)
        params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path), keep=1)
        mgr.save(params, step=1)

        mesh2 = jax.make_mesh((1,), ("model",))
        from repro.dist.sharding import DEFAULT_RULES, sharding_tree

        sh = sharding_tree(model.param_specs(), DEFAULT_RULES, mesh2)
        restored, step = mgr.restore(params, shardings=sh)
        assert step == 1
        leaf = jax.tree.leaves(restored)[0]
        assert leaf.sharding.mesh.axis_names == ("model",)
        mgr.close()


class TestTrainerFaults:
    def test_crash_restart_resumes_from_checkpoint(self, ctx, tmp_path):
        trainer = make_trainer(ctx, tmp_path, ckpt_every=2, max_failures=2)
        trainer.init_state()
        crashed = []

        def fail_once(step):
            if step == 4 and not crashed:
                crashed.append(step)
                raise RuntimeError("injected node failure")

        hist = trainer.train(data(ctx, 12), 6, fail_hook=fail_once, log=lambda m: None)
        assert crashed == [4]
        assert trainer.step_num == 6
        assert trainer.failures == 1
        assert [h["step"] for h in hist][-1] == 6

    def test_failure_budget_exhaustion_raises(self, ctx, tmp_path):
        trainer = make_trainer(ctx, tmp_path, max_failures=1)
        trainer.init_state()

        def always_fail(step):
            raise RuntimeError("persistent failure")

        with pytest.raises(RuntimeError):
            trainer.train(data(ctx, 8), 4, fail_hook=always_fail, log=lambda m: None)

    def test_remesh_preserves_state(self, ctx, tmp_path):
        trainer = make_trainer(ctx, tmp_path)
        trainer.init_state()
        trainer.train(data(ctx, 3), 2, log=lambda m: None)
        before = jax.tree.map(np.asarray, trainer.state["params"])
        new_mesh = jax.make_mesh((1, 1), ("data", "model"))
        trainer.remesh(ModelContext(ctx.cfg, new_mesh, rules_for(new_mesh)))
        after = jax.tree.map(np.asarray, trainer.state["params"])
        jax.tree.map(np.testing.assert_array_equal, before, after)
        trainer.train(data(ctx, 6)[2:], 4, log=lambda m: None)  # still trains
        assert trainer.step_num == 4


class TestFaultPrimitives:
    def test_heartbeat_lease_lifecycle(self):
        store = Store("hb-test")
        mon = HeartbeatMonitor(store, ttl=0.3)
        mon.register("w0")
        mon.register("w1")
        assert set(mon.live_workers()) == {"w0", "w1"}
        import time

        for _ in range(3):  # w0 keeps beating; w1 goes silent
            time.sleep(0.15)
            mon.heartbeat("w0")
        time.sleep(0.25)
        assert "w1" in mon.dead_workers()
        with pytest.raises(TimeoutError):
            mon.heartbeat("w1")  # dead workers must re-register
        store.close()

    def test_elastic_plan_shrinks_after_loss(self):
        full = elastic_plan(512, model_parallel=16, chips_per_pod=256)
        assert (full.pods, full.data, full.model) == (2, 16, 16)
        degraded = elastic_plan(512 - 96, model_parallel=16, chips_per_pod=256)
        assert degraded.model == 16
        assert degraded.chips <= 512 - 96
        tiny = elastic_plan(48, model_parallel=16)
        assert tiny.data == 2  # 48//16=3 → pow2 floor
        with pytest.raises(ValueError):
            elastic_plan(8, model_parallel=16)

    def test_straggler_policy_decisions(self):
        pol = StragglerPolicy(warn_factor=2.0, redispatch_factor=4.0)
        for _ in range(6):
            assert pol.observe(1.0) is None
        assert pol.observe(2.5) == "warn"
        assert pol.observe(5.0) == "redispatch"
        assert pol.observe(1.1) is None


class TestReshardedCheckpoint:
    """PR 4: leaves saved as axis-0 chunks; restores read per-shard slices."""

    def test_manifest_is_chunked(self, ctx, tmp_path):
        model = build_model(ctx)
        params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path), keep=1, leaf_shards=4)
        mgr.save(params, step=1)
        with open(mgr._manifest_path(1)) as f:
            manifest = json.load(f)
        metas = list(manifest["leaves"].values())
        assert all("keys" in m and "bounds" in m for m in metas)
        multi = [m for m in metas if len(m["keys"]) > 1]
        assert multi  # every axis-0-divisible leaf really is sharded
        for m in metas:
            assert len(m["bounds"]) == len(m["keys"]) + 1
            if m["shape"]:
                assert m["bounds"][-1] == m["shape"][0]
        mgr.close()

    def test_partial_fetch_reads_only_overlapping_chunks(self, tmp_path):
        arr = np.arange(32, dtype=np.float32).reshape(8, 4)
        mgr = CheckpointManager(str(tmp_path), keep=1, leaf_shards=4)
        mgr.save({"w": arr}, step=1)
        meta = json.load(open(mgr._manifest_path(1)))["leaves"]["['w']"]
        assert meta["bounds"] == [0, 2, 4, 6, 8]
        mgr.close()

        cold = CheckpointManager(str(tmp_path), keep=1)  # fresh store, no cache
        before = cold._store.metrics.get_count
        rows = cold._fetch_rows(meta, 2, 4, "w")
        np.testing.assert_array_equal(rows, arr[2:4])
        # rows [2,4) live in exactly one chunk: exactly one channel read
        assert cold._store.metrics.get_count - before == 1
        cold.close()

        cold2 = CheckpointManager(str(tmp_path), keep=1)
        before = cold2._store.metrics.get_count
        rows = cold2._fetch_rows(meta, 3, 7, "w")
        np.testing.assert_array_equal(rows, arr[3:7])
        assert cold2._store.metrics.get_count - before == 3  # 3 overlapping chunks
        cold2.close()

    def test_sharded_restore_via_callback_matches(self, ctx, tmp_path):
        """Restore with shardings goes through make_array_from_callback on
        per-chunk reads and still reproduces every leaf bit-identically."""
        model = build_model(ctx)
        params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path), keep=1, leaf_shards=4)
        mgr.save(params, step=1)

        from repro.dist.sharding import sharding_tree

        sh = sharding_tree(model.param_specs(), ctx.rules, ctx.mesh)
        restored, step = mgr.restore(params, shardings=sh)
        assert step == 1
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            params, restored,
        )
        mgr.close()

    def test_zero_length_leaf_roundtrips(self, tmp_path):
        arr = np.zeros((0, 4), np.float32)
        mgr = CheckpointManager(str(tmp_path), keep=1, leaf_shards=4)
        mgr.save({"empty": arr}, step=1)
        restored, _ = mgr.restore({"empty": arr})
        assert np.asarray(restored["empty"]).shape == (0, 4)
        mgr.close()

    def test_legacy_whole_leaf_manifest_restores(self, tmp_path):
        """Pre-PR4 manifests (one `key` per leaf) still restore."""
        arr = np.arange(12, dtype=np.float32).reshape(3, 4)
        mgr = CheckpointManager(str(tmp_path), keep=1)
        mgr._store.put(arr, key="legacy-leaf")
        manifest = {
            "step": 9, "time": 0.0,
            "leaves": {"['w']": {"key": "legacy-leaf", "shape": [3, 4],
                                 "dtype": "float32"}},
        }
        with open(mgr._manifest_path(9), "w") as f:
            json.dump(manifest, f)
        restored, step = mgr.restore({"w": arr})
        assert step == 9
        np.testing.assert_array_equal(np.asarray(restored["w"]), arr)
        mgr.close()


class TestDispatchingLoader:
    """PR 4: the `redispatch` grade acts — shards are re-issued to live
    workers, committed exactly once through put_if_absent."""

    def _corpus(self, ctx):
        return SyntheticCorpus(ctx.cfg, 2, 16)

    def test_all_shards_delivered_in_order(self, ctx):
        corpus = self._corpus(ctx)
        loader = DispatchingDataLoader(
            corpus.next_batch, num_steps=6, workers=2, prefetch=2
        )
        got = [extract(p)["tokens"] for p in loader]
        assert len(got) == 6
        for i, toks in enumerate(got):
            np.testing.assert_array_equal(toks, corpus.next_batch(i)["tokens"])
        loader.stop()

    def test_straggler_shard_redispatched_to_other_worker(self, ctx):
        corpus = self._corpus(ctx)
        release = threading.Event()
        hung = []

        def worker_fn(worker, step):
            if step == 5 and not hung:  # first issue of shard 5 wedges
                hung.append(worker)
                release.wait(timeout=60)
            return corpus.next_batch(step)

        policy = StragglerPolicy(
            warn_factor=2.0, redispatch_factor=4.0, window=8, min_samples=3
        )
        loader = DispatchingDataLoader(
            corpus.next_batch, num_steps=8, workers=["dw0", "dw1"],
            policy=policy, worker_fn=worker_fn, prefetch=2,
            supervise_every=0.01, shard_timeout=60.0,
        )
        try:
            got = [extract(p)["tokens"] for p in loader]
            assert len(got) == 8
            np.testing.assert_array_equal(got[5], corpus.next_batch(5)["tokens"])
            stragglers = [
                r for r in loader.redispatches
                if r["step"] == 5 and r["reason"] == "straggler"
            ]
            assert stragglers
            assert stragglers[0]["to"] != hung[0]  # re-issued to the OTHER worker
        finally:
            release.set()
            loader.stop()

    def test_worker_error_shard_redispatched(self, ctx):
        """A worker exception must not strand its shard: the step is
        re-issued immediately and the error is recorded, not swallowed."""
        corpus = self._corpus(ctx)
        blew = []

        def worker_fn(worker, step):
            if step == 2 and not blew:
                blew.append(worker)
                raise RuntimeError("boom")
            return corpus.next_batch(step)

        policy = StragglerPolicy(min_samples=10**6)  # isolate the error path
        loader = DispatchingDataLoader(
            corpus.next_batch, num_steps=5, workers=2, policy=policy,
            worker_fn=worker_fn, prefetch=2, supervise_every=0.01,
            shard_timeout=60.0,
        )
        try:
            got = [extract(p) for p in loader]
            assert len(got) == 5
            np.testing.assert_array_equal(
                got[2]["tokens"], corpus.next_batch(2)["tokens"]
            )
            assert loader.errors and loader.errors[0]["step"] == 2
            assert any(
                r["reason"] == "worker-error" and r["step"] == 2
                for r in loader.redispatches
            )
        finally:
            loader.stop()

    def test_dead_worker_shards_redispatched(self, ctx):
        corpus = self._corpus(ctx)

        class FakeMonitor:
            def __init__(self):
                self.alive = {"dw0", "dw1"}

            def live_workers(self):
                return sorted(self.alive)

        mon = FakeMonitor()
        stall = threading.Event()

        def worker_fn(worker, step):
            if worker == "dw0":
                stall.wait(timeout=60)  # dw0 never finishes anything
            return corpus.next_batch(step)

        # min_samples high: only the death path may trigger re-issues
        policy = StragglerPolicy(min_samples=10**6)
        loader = DispatchingDataLoader(
            corpus.next_batch, num_steps=6, workers=["dw0", "dw1"],
            policy=policy, monitor=mon, worker_fn=worker_fn, prefetch=2,
            supervise_every=0.01, shard_timeout=60.0,
        )
        try:
            loader.start()
            time.sleep(0.1)  # let dw0 pick up a shard, then "kill" it
            mon.alive.discard("dw0")
            got = [extract(p) for p in loader]
            assert len(got) == 6
            dead = [r for r in loader.redispatches if r["reason"] == "dead-worker"]
            assert dead and all(r["from"] == "dw0" and r["to"] == "dw1" for r in dead)
        finally:
            stall.set()
            loader.stop()


class TestPipeline:
    def test_loader_yields_proxies_in_order(self, ctx):
        corpus = SyntheticCorpus(ctx.cfg, 2, 16)
        loader = StreamingDataLoader(corpus.next_batch, num_steps=5, prefetch=2)
        from repro.core.proxy import Proxy, extract

        steps = []
        for p in loader:
            assert isinstance(p, Proxy)
            steps.append(extract(p)["tokens"].shape)
        assert len(steps) == 5
        assert all(s == (2, 16) for s in steps)
