"""Shared helper: spawn a real store-server process for cross-process tests."""
import contextlib
import os
import subprocess
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@contextlib.contextmanager
def store_server(*args):
    """Spawn ``python -m repro.launch.store_server`` and yield ``"host:port"``.

    The child prints ``PSRV READY <host> <port>`` once bound; we block on
    that line so the address is connectable the moment the context opens.
    Terminates (then kills) the child on exit.
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.store_server", *args],
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
        text=True,
        env=env,
    )
    try:
        line = proc.stdout.readline().strip()
        fields = line.split()
        if len(fields) != 4 or fields[:2] != ["PSRV", "READY"]:
            err = proc.stderr.read() if proc.poll() is not None else ""
            raise RuntimeError(f"store server failed to start: {line!r}\n{err}")
        yield f"{fields[2]}:{fields[3]}", proc
    finally:
        if proc.poll() is None:
            proc.terminate()
            try:
                proc.wait(timeout=5)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait(timeout=5)
