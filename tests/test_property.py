"""Property-based tests (hypothesis) for the system's invariants."""
from __future__ import annotations

import pickle

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (
    InMemoryConnector,
    OwnershipError,
    Store,
    borrow,
    clone,
    free,
    mut_borrow,
    owned_proxy,
    release,
)
from repro.core.proxy import Proxy, extract, is_resolved
from repro.core.streaming import (
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)

SETTINGS = settings(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.function_scoped_fixture],
)

# objects a store must round-trip faithfully
objects = st.one_of(
    st.integers(),
    st.floats(allow_nan=False),
    st.text(max_size=64),
    st.lists(st.integers(), max_size=16),
    st.dictionaries(st.text(max_size=8), st.integers(), max_size=8),
    st.binary(max_size=256),
)


@pytest.fixture
def store():
    with Store(f"prop-{np.random.randint(1e9)}") as s:
        yield s


class TestProxyRoundTrip:
    @SETTINGS
    @given(obj=objects)
    def test_proxy_equals_target(self, store, obj):
        """∀ obj: extract(store.proxy(obj)) == obj (pass-by-value fidelity)."""
        p = store.proxy(obj)
        assert extract(p) == obj

    @SETTINGS
    @given(obj=objects)
    def test_proxy_type_transparency(self, store, obj):
        """isinstance(p, type(t)) is true for a proxy p and target t (§III)."""
        p = store.proxy(obj)
        assert isinstance(p, type(obj))

    @SETTINGS
    @given(obj=objects)
    def test_pickled_proxy_still_resolves(self, store, obj):
        """Proxies are self-contained across (de)serialization (§III)."""
        p = store.proxy(obj)
        p2 = pickle.loads(pickle.dumps(p))
        assert extract(p2) == obj

    @SETTINGS
    @given(arr=st.lists(st.floats(allow_nan=False, width=32), min_size=1, max_size=64))
    def test_numpy_fidelity(self, store, arr):
        a = np.asarray(arr, np.float32)
        p = store.proxy(a)
        np.testing.assert_array_equal(extract(p), a)


class TestFutureInvariants:
    @SETTINGS
    @given(obj=objects)
    def test_set_once_then_every_proxy_resolves(self, store, obj):
        fut = store.future()
        proxies = [fut.proxy() for _ in range(3)]
        assert not fut.done()
        fut.set_result(obj)
        assert fut.done()
        for p in proxies:
            assert extract(p) == obj

    @SETTINGS
    @given(obj=objects)
    def test_double_set_always_raises(self, store, obj):
        fut = store.future()
        fut.set_result(obj)
        with pytest.raises(RuntimeError):
            fut.set_result(obj)


class TestStreamOrdering:
    @SETTINGS
    @given(items=st.lists(objects, min_size=1, max_size=12))
    def test_fifo_and_exactly_once(self, store, items):
        """Stream delivers every item exactly once, in order."""
        ns = f"prop-{np.random.randint(1e9)}"
        producer = StreamProducer(QueuePublisher(ns), {"t": store})
        consumer = StreamConsumer(QueueSubscriber("t", ns), timeout=5.0)
        for it in items:
            producer.send("t", it)
            producer.flush_topic("t")
        producer.close_topic("t")
        got = [extract(p) for p in consumer]
        assert got == list(items)


class TestOwnershipInvariants:
    @SETTINGS
    @given(obj=objects, n_refs=st.integers(0, 4))
    def test_borrow_rules(self, store, obj, n_refs):
        """Any number of Refs XOR exactly one RefMut; free only when clear."""
        owner = owned_proxy(store, obj)
        refs = [borrow(owner) for _ in range(n_refs)]
        if n_refs:
            with pytest.raises(OwnershipError):
                mut_borrow(owner)  # Ref(s) outstanding → no RefMut
            with pytest.raises(OwnershipError):
                free(owner)  # cannot free with live borrows
        for r in refs:
            release(r)
        m = mut_borrow(owner)
        with pytest.raises(OwnershipError):
            borrow(owner)  # RefMut outstanding → no Ref
        release(m)
        key = owner.__factory__.key
        free(owner)
        assert not store.exists(key)  # free ⇒ target evicted

    @SETTINGS
    @given(obj=objects)
    def test_clone_is_deep_and_independent(self, store, obj):
        a = owned_proxy(store, obj)
        b = clone(a)
        free(a)
        assert extract(b) == obj  # clone survives original's death
        free(b)


class TestElasticPlanInvariants:
    """The re-plan properties the remesh driver relies on (PR 4)."""

    @SETTINGS
    @given(
        chips=st.integers(1, 4096),
        mp=st.sampled_from([1, 2, 4, 8, 16]),
        cpp=st.sampled_from([64, 128, 256]),
    )
    def test_plan_invariants(self, chips, mp, cpp):
        from repro.dist.fault import elastic_plan

        try:
            plan = elastic_plan(chips, model_parallel=mp, chips_per_pod=cpp)
        except ValueError:
            # only legitimate failure: the surviving chips can't host even
            # one model-parallel group
            assert min(chips, cpp) < mp
            return
        assert plan.model == mp  # model parallelism pinned, always
        assert plan.data >= 1
        assert plan.data & (plan.data - 1) == 0  # power of two, always
        assert plan.chips <= chips  # never oversubscribes the survivors
        assert plan.data * plan.model <= cpp  # a DP group never spans pods

    @SETTINGS
    @given(
        chips=st.integers(16, 4096),
        extra=st.integers(0, 1024),
        mp=st.sampled_from([1, 2, 4, 8]),
    )
    def test_plan_monotone_in_available_chips(self, chips, extra, mp):
        """More surviving chips can never produce a smaller mesh."""
        from repro.dist.fault import elastic_plan

        try:
            a = elastic_plan(chips, model_parallel=mp, chips_per_pod=256)
        except ValueError:
            return
        b = elastic_plan(chips + extra, model_parallel=mp, chips_per_pod=256)
        assert b.chips >= a.chips
        assert b.model == a.model  # pinned on both sides of the loss


class TestPageTableInvariants:
    """Serving KV-page allocator properties (PR 5): no double assignment,
    conservation, total reclamation, monotone extends, reservation safety."""

    def _pt(self, num_pages=12, page_size=4):
        from repro.serve.kvcache import PageTable

        store = Store(f"ptp-{np.random.randint(1e9)}")
        return (
            PageTable(
                num_pages=num_pages, page_size=page_size, store=store,
                page_bytes=8,
            ),
            store,
        )

    @SETTINGS
    @given(ops=st.lists(st.integers(0, 10**6), max_size=40))
    def test_allocator_invariants_hold_under_any_op_sequence(self, ops):
        """allocate/extend/free in any order: pages_in_use + pages_free ==
        num_pages, no page owned twice, reservations never negative."""
        pt, store = self._pt()
        live: dict[str, int] = {}
        next_id = 0
        for code in ops:
            kind, arg = code % 3, code // 3
            if kind == 0:
                tokens = arg % 20 + 1
                sid = f"s{next_id}"
                next_id += 1
                try:
                    pt.allocate(sid, tokens, reserve_tokens=tokens + arg % 9)
                except MemoryError:
                    assert pt.pages_needed(tokens) > pt.pages_available() or (
                        pt.pages_needed(tokens + arg % 9) > pt.pages_available()
                    )
                else:
                    live[sid] = tokens
            elif kind == 1 and live:
                sid = sorted(live)[arg % len(live)]
                before = pt.pages_of(sid)
                new_total = live[sid] + arg % 11
                try:
                    pt.extend(sid, new_total)
                except MemoryError:
                    pass
                else:
                    after = pt.pages_of(sid)
                    assert after[: len(before)] == before  # extend is monotone
                    assert len(after) == max(
                        len(before), pt.pages_needed(new_total)
                    )
                    live[sid] = max(live[sid], new_total)
            elif kind == 2 and live:
                sid = sorted(live)[arg % len(live)]
                pt.free_sequence(sid)
                del live[sid]
            # invariants after every single operation
            assert pt.pages_in_use() + pt.pages_free() == pt.num_pages
            owned = [p for s in live for p in pt.pages_of(s)]
            assert len(owned) == len(set(owned))  # never double-assigned
            assert pt.pages_in_use() == len(owned)
            assert 0 <= pt.pages_reserved() <= pt.pages_free()
        for sid in list(live):
            pt.free_sequence(sid)
        # free always returns every page, and the store holds no cells
        assert pt.pages_free() == pt.num_pages
        assert pt.pages_in_use() == 0
        assert pt.pages_reserved() == 0
        for sid in [f"s{i}" for i in range(next_id)]:
            for p in range(pt.num_pages):
                assert not store.exists(pt.page_key(sid, p))
        store.close()

    @SETTINGS
    @given(
        prompt=st.integers(1, 16),
        growth=st.integers(0, 32),
        n_rivals=st.integers(0, 6),
    )
    def test_reservation_makes_extend_infallible(self, prompt, growth, n_rivals):
        """A sequence allocated with reserve_tokens=T can always extend to
        T, no matter what is admitted after it."""
        pt, store = self._pt(num_pages=16, page_size=4)
        total = prompt + growth
        if pt.pages_needed(total) > pt.num_pages:
            store.close()
            return
        pt.allocate("hero", prompt, reserve_tokens=total)
        for i in range(n_rivals):  # rivals soak up whatever is left
            try:
                pt.allocate(f"rival{i}", 8, reserve_tokens=16)
            except MemoryError:
                break
        for t in range(prompt, total + 1):  # token-by-token, like decode
            pt.extend("hero", t)  # MemoryError here = property violated
        assert len(pt.pages_of("hero")) == pt.pages_needed(total)
        for sid in list(pt.live_sequences()):
            pt.free_sequence(sid)
        assert pt.pages_free() == pt.num_pages
        store.close()

    @SETTINGS
    @given(tokens=st.integers(1, 64))
    def test_free_releases_store_memory(self, tokens):
        pt, store = self._pt(num_pages=16, page_size=4)
        if pt.pages_needed(tokens) > pt.num_pages:
            store.close()
            return
        pages = pt.allocate("m", tokens)
        for p in pages:
            assert store.exists(pt.page_key("m", p))
        pt.free_sequence("m")
        for p in pages:
            assert not store.exists(pt.page_key("m", p))
        store.close()


class TestPrefixSharingInvariants:
    """Refcounted shared KV pages (paged-decode PR): conservation under
    arbitrary allocate/share/extend/free interleavings, copy-on-write
    isolation, no double-free, and exact availability accounting."""

    def _pt(self, num_pages=16, page_size=4):
        from repro.serve.kvcache import PageTable

        store = Store(f"psp-{np.random.randint(1e9)}")
        return (
            PageTable(
                num_pages=num_pages, page_size=page_size, store=store,
                page_bytes=8,
            ),
            store,
        )

    def _check_refcounts(self, pt, live):
        """Every page referenced by any live sequence has a refcount equal
        to the number of live sequences referencing it — creators and
        borrowers indistinguishable to the count, orphans included."""
        refs: dict[int, int] = {}
        for sid in live:
            for p in pt.pages_of(sid):
                refs[p] = refs.get(p, 0) + 1
        for p, n in refs.items():
            assert pt.page_refcount(p) == n, (p, n)
        # conservation: the union of referenced pages IS the in-use set
        assert pt.pages_in_use() == len(refs)
        assert pt.pages_in_use() + pt.pages_free() == pt.num_pages
        assert 0 <= pt.pages_reserved() <= pt.pages_free()
        # orphans are exactly the in-use pages whose creator is dead
        assert pt.orphan_pages() <= set(refs)

    @SETTINGS
    @given(ops=st.lists(st.integers(0, 10**6), max_size=40))
    def test_sharing_interleavings_conserve_refcounts(self, ops):
        pt, store = self._pt()
        live: dict[str, int] = {}
        next_id = 0
        for code in ops:
            kind, arg = code % 4, code // 4
            if kind == 0:  # plain allocate
                tokens = arg % 20 + 1
                sid = f"s{next_id}"
                next_id += 1
                try:
                    pt.allocate(sid, tokens, reserve_tokens=tokens + arg % 9)
                except MemoryError:
                    pass
                else:
                    live[sid] = tokens
            elif kind == 1 and live:  # allocate sharing a live prefix
                parent = sorted(live)[arg % len(live)]
                ptok = arg % (live[parent] + 1)
                tokens = max(1, ptok + arg % 8)
                sid = f"s{next_id}"
                next_id += 1
                try:
                    pt.allocate(
                        sid, tokens, reserve_tokens=tokens + arg % 5,
                        prefix_of=parent, prefix_tokens=ptok,
                    )
                except MemoryError:
                    pass
                else:
                    live[sid] = tokens
            elif kind == 2 and live:  # extend (may cross a COW boundary)
                sid = sorted(live)[arg % len(live)]
                new_total = live[sid] + arg % 11
                try:
                    pt.extend(sid, new_total)
                except MemoryError:
                    pass
                else:
                    live[sid] = max(live[sid], new_total)
            elif kind == 3 and live:  # free (parents may die first)
                sid = sorted(live)[arg % len(live)]
                pt.free_sequence(sid)
                del live[sid]
            self._check_refcounts(pt, live)
        for sid in list(live):
            pt.free_sequence(sid)
        assert pt.pages_free() == pt.num_pages
        assert pt.orphan_pages() == set()
        assert sorted(pt._free) == list(range(pt.num_pages))
        store.close()

    @SETTINGS
    @given(
        ptok=st.integers(1, 16),
        child_extra=st.integers(0, 10),
        grow=st.integers(0, 12),
    )
    def test_cow_never_mutates_parent_and_extend_never_fails(
        self, ptok, child_extra, grow
    ):
        """A sharer crossing its prefix boundary copies, never mutates:
        the parent's page list, cells, and refcounts are untouched, and
        the sharer's reservation priced the COW page in, so token-by-token
        extension to the reserved total never raises."""
        pt, store = self._pt(num_pages=32, page_size=4)
        pt.allocate("par", 16, reserve_tokens=20)
        before = list(pt.pages_of("par"))
        child_tokens = max(1, ptok + child_extra)
        reach = child_tokens + grow
        pt.allocate(
            "ch", child_tokens, reserve_tokens=reach,
            prefix_of="par", prefix_tokens=ptok,
        )
        assert pt.pages_of("par") == before
        for t in range(child_tokens, reach + 1):
            pt.extend("ch", t)  # MemoryError here = reservation violated
        assert pt.pages_of("par") == before
        for p in before:
            assert store.exists(pt.page_key("par", p))  # cells intact
        # once the child outgrew the prefix, any partially-shared boundary
        # page was copied: overlap is confined to *full* shared pages
        eff = min(ptok, child_tokens)
        overlap = set(before) & set(pt.pages_of("ch"))
        if reach > eff:
            assert overlap == set(before[: eff // 4])
        # the child's refcounts on shared pages drop to 1 after its free
        pt.free_sequence("ch")
        assert all(pt.page_refcount(p) == 1 for p in before)
        pt.free_sequence("par")
        assert pt.pages_free() == pt.num_pages
        store.close()

    @SETTINGS
    @given(
        n_children=st.integers(1, 4),
        parent_first=st.booleans(),
        ptok=st.integers(4, 12),
    )
    def test_no_double_free_any_teardown_order(
        self, n_children, parent_first, ptok
    ):
        """Shared pages survive their creator (orphaned, not freed), are
        returned exactly once when the last borrower exits, and freeing a
        dead sequence raises instead of corrupting the free list."""
        pt, store = self._pt(num_pages=32, page_size=4)
        pt.allocate("par", 16, reserve_tokens=16)
        shared = set(pt.pages_of("par")[: ptok // 4])
        for i in range(n_children):
            pt.allocate(
                f"ch{i}", ptok, reserve_tokens=ptok + 4,
                prefix_of="par", prefix_tokens=ptok,
            )
        order = (["par"] + [f"ch{i}" for i in range(n_children)]) if (
            parent_first
        ) else ([f"ch{i}" for i in range(n_children)] + ["par"])
        for k, sid in enumerate(order):
            pt.free_sequence(sid)
            if parent_first and k == 0 and shared:
                # creator died with borrows out: cells orphaned, not freed
                assert shared <= pt.orphan_pages() | {
                    p for c in range(n_children) for p in pt.pages_of(f"ch{c}")
                }
        assert pt.orphan_pages() == set()
        assert pt.pages_free() == pt.num_pages
        assert sorted(pt._free) == list(range(pt.num_pages))
        with pytest.raises(KeyError):
            pt.free_sequence("par")  # double-free is an error, not a leak
        assert pt.pages_free() == pt.num_pages
        store.close()

    @SETTINGS
    @given(ptok=st.integers(0, 16), extra=st.integers(1, 8))
    def test_available_accounting_exact_with_shared_pages(self, ptok, extra):
        """pages_available reflects sharing exactly: a child consumes only
        its fresh pages (plus the priced-in COW page for a partial
        boundary), never re-counts borrowed ones."""
        pt, store = self._pt(num_pages=32, page_size=4)
        pt.allocate("par", 16, reserve_tokens=16)
        avail = pt.pages_available()
        total_before = pt.pages_allocated_total
        tokens = ptok + extra
        pt.allocate(
            "ch", tokens, reserve_tokens=tokens,
            prefix_of="par", prefix_tokens=ptok,
        )
        # tokens > ptok always here, so a partial boundary page COWs at
        # allocate and lands in the fresh count; either way the identity
        # is: fresh pages drawn == pages needed − pages borrowed
        n_borrowed = len(pt.borrowed_pages("ch"))
        fresh_now = pt.pages_allocated_total - total_before
        assert fresh_now == pt.pages_needed(tokens) - n_borrowed
        # availability dropped by exactly the fresh pages (reserve==tokens,
        # so no growth reservation is held back on top)
        assert pt.pages_available() == avail - fresh_now
        pt.free_sequence("ch")
        pt.free_sequence("par")
        assert pt.pages_available() == pt.num_pages
        store.close()


    @SETTINGS
    @given(
        k=st.integers(1, 4),
        accepts=st.lists(st.integers(1, 5), min_size=1, max_size=12),
        share=st.booleans(),
    )
    def test_spec_overextend_rollback_never_leaks(self, k, accepts, share):
        """Speculative decode extends a sequence up to k tokens past its
        accepted length every step and 'rolls back' rejected drafts by
        simply not advancing — the page list never shrinks, extends stay
        inside the admission reservation (never raise), nothing leaks,
        and teardown frees every page exactly once."""
        pt, store = self._pt(num_pages=64, page_size=4)
        prompt = 8
        max_new = sum(min(a, k + 1) for a in accepts)
        total = prompt + max_new
        pt.allocate("seq", prompt, reserve_tokens=total)
        if share:  # a prefix-sharing peer must not perturb any of this
            pt.allocate("peer", prompt, reserve_tokens=prompt + 4,
                        prefix_of="seq", prefix_tokens=prompt)
        pos = prompt
        for a in accepts:
            remaining = total - pos
            if remaining <= 0:
                break
            k_eff = max(0, min(k, remaining - 1))
            before = list(pt.pages_of("seq"))
            pt.extend("seq", pos + k_eff + 1)  # the speculative over-extend
            after = pt.pages_of("seq")
            assert after[: len(before)] == before  # never rolls pages back
            pos += min(a, k_eff + 1)  # accepted prefix only; tail rejected
            assert pt.pages_in_use() + pt.pages_free() == pt.num_pages
        for sid in list(pt.live_sequences()):
            pt.free_sequence(sid)
        assert pt.pages_free() == pt.num_pages
        assert pt.orphan_pages() == set()
        assert sorted(pt._free) == list(range(pt.num_pages))
        with pytest.raises(KeyError):
            pt.free_sequence("seq")  # double-free is an error, not a leak
        for p in range(pt.num_pages):
            assert not store.exists(pt.page_key("seq", p))
        store.close()


class TestSpecAcceptanceInvariants:
    """Greedy speculative acceptance (serve/engine.py): the verify math —
    match the padded draft row against the target argmax row and accept
    ``cumprod(match).sum() + 1`` — emits exactly the target-only greedy
    stream for ANY draft, and every step accepts LCP + 1 tokens."""

    @staticmethod
    def _accept(drafts, outs, k, k_eff):
        # mirror of _spec_verify_body: tokens row is [last, d_1..d_k_eff,
        # -1 padding]; argmaxes are ≥ 0, so padding can never match
        padded = list(drafts[:k_eff]) + [-1] * (k - k_eff)
        match = np.cumprod([int(o == d) for o, d in zip(outs[:k], padded)])
        return min(int(match.sum()) + 1, k_eff + 1)

    @SETTINGS
    @given(
        drafts=st.lists(st.integers(0, 9), min_size=0, max_size=6),
        outs=st.lists(st.integers(0, 9), min_size=7, max_size=7),
        k=st.integers(1, 6),
    )
    def test_accepted_length_is_lcp_plus_one(self, drafts, outs, k):
        k_eff = min(len(drafts), k)
        acc = self._accept(drafts, outs, k, k_eff)
        lcp = 0
        while lcp < k_eff and outs[lcp] == drafts[lcp]:
            lcp += 1
        assert acc == lcp + 1
        assert 1 <= acc <= k_eff + 1

    @SETTINGS
    @given(
        target=st.lists(st.integers(0, 9), min_size=1, max_size=24),
        draft=st.lists(st.integers(0, 9), min_size=1, max_size=24),
        garbage=st.lists(st.integers(0, 9), min_size=1, max_size=8),
        k=st.integers(1, 4),
    )
    def test_spec_stream_equals_target_greedy(self, target, draft, garbage, k):
        """Run the engine's step loop shape over arbitrary (draft, target)
        disagreement patterns: whatever the draft proposes — and whatever
        garbage the target row carries *past* the first mismatch — the
        emitted stream is bit-identical to target-only greedy decode."""

        def draft_at(i):
            return draft[i % len(draft)]

        emitted, pos, per_step = [], 0, []
        while pos < len(target):
            k_eff = min(k, len(target) - pos - 1)
            ds = [draft_at(pos + j) for j in range(k_eff)]
            outs, poisoned = [], False
            for j in range(k_eff + 1):
                # target argmaxes are trustworthy only while the verified
                # prefix matched; after the first mismatch the row is junk
                outs.append(garbage[(pos + j) % len(garbage)] if poisoned
                            else target[pos + j])
                if j < k_eff and outs[j] != ds[j]:
                    poisoned = True
            acc = self._accept(ds, outs, k, k_eff)
            emitted.extend(outs[:acc])
            per_step.append(acc)
            pos += acc
        assert emitted == target  # bit-identical to target-only greedy
        assert sum(per_step) == len(target)
        assert all(1 <= a <= k + 1 for a in per_step)


class TestShardingRules:
    @SETTINGS
    @given(
        dim=st.integers(1, 4096),
        axis=st.sampled_from(["embed", "heads", "mlp", "vocab", "batch", None]),
    )
    def test_spec_always_valid(self, dim, axis):
        """logical_to_spec never produces an indivisible sharding."""
        import jax
        from jax.sharding import PartitionSpec

        from repro.dist.sharding import DEFAULT_RULES, logical_to_spec

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = logical_to_spec((dim,), (axis,), DEFAULT_RULES, mesh)
        assert isinstance(spec, PartitionSpec)
        for entry, d in zip(spec, (dim,)):
            if entry is not None:
                names = entry if isinstance(entry, tuple) else (entry,)
                size = int(np.prod([mesh.shape[n] for n in names]))
                assert d % size == 0
