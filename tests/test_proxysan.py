"""ProxySan suite: every violation category produced by driving the real
Store/ownership lifecycle, the leak report, ``expecting()`` scoping,
per-store opt-in, serve request-proxy reclamation, and a cross-process
smoke (the scripts/check.sh target) whose leak report must come back
clean under ``REPRO_PROXYSAN=1``.

State discipline: the module-level sanitizer is a process singleton, so
every test goes through the shared ``san`` fixture (conftest), which
snapshots the tracking tables and restores them on the way out — nothing
a test mints (or the violations it provokes on purpose) can bleed into
the conftest session gate or into other tests.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import sanitize
from repro.core.connectors import FileConnector, InMemoryConnector, new_key
from repro.core.ownership import (
    _state,
    borrow,
    free,
    owned_proxy,
    release,
    release_by_token,
)
from repro.core.sanitize import Sanitizer
from repro.core.store import Store

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def tracked_store(name_prefix: str, connector=None, **kw) -> Store:
    return Store(
        f"{name_prefix}-{new_key()}", connector,
        sanitize=True, register=False, **kw,
    )


class TestViolationCategories:
    def test_use_after_evict_via_stale_shared_cache(self, san):
        """Two Store views of one channel: view A caches a resolve, view B
        frees the key.  A's next cached read hands out a freed payload —
        the exact bug class the paper's ownership rules exist to prevent."""
        conn = InMemoryConnector(f"uae-{new_key()}")
        a = tracked_store("uae-a", conn)
        b = tracked_store("uae-b", conn)
        key = a.put({"v": 1})
        assert a.get(key) == {"v": 1}  # cache fill
        assert a.get(key) == {"v": 1}  # legitimate hit
        with sanitize.expecting() as exp:
            b.evict(key)
            assert a.get(key) == {"v": 1}  # stale cache: value for a dead key
        assert exp.categories() == {"use_after_evict"}

    def test_freed_key_keyerror_is_counted_not_flagged(self, san):
        store = tracked_store("uaf-loud")
        o = owned_proxy(store, [1, 2, 3])
        key = _state(o).key
        free(o)
        before = len(san.violations)
        with pytest.raises(KeyError):
            store.resolve(key)
        # the loud failure is the *correct* outcome — counted, never flagged
        assert san.counters.get("resolve_after_free_raised", 0) >= 1
        assert len(san.violations) == before

    def test_double_free_flagged(self, san):
        store = tracked_store("df")
        o = owned_proxy(store, {"x": 1})
        free(o)
        with sanitize.expecting() as exp:
            free(o)  # forgiving API: a no-op — but exactly what ProxySan flags
        assert exp.categories() == {"double_free"}

    def test_refcount_underflow_on_unissued_token(self, san):
        store = tracked_store("rc")
        o = owned_proxy(store, {"x": 1})
        with sanitize.expecting() as exp:
            release_by_token(_state(o), "token-never-issued")
        assert exp.categories() == {"refcount_underflow"}
        free(o)

    def test_redundant_release_is_benign(self, san):
        store = tracked_store("rr")
        o = owned_proxy(store, {"x": 1})
        r = borrow(o)
        token = object.__getattribute__(r, "__proxy_metadata__")["token"]
        release(r)
        before = len(san.violations)
        release_by_token(_state(o), token)  # idempotent re-release
        assert san.counters.get("redundant_releases", 0) >= 1
        assert len(san.violations) == before
        free(o)

    def test_stale_cache_read_after_foreign_re_put(self, san):
        """A re-put through another Store view invalidates nothing in this
        process — the cached read silently serves the old value unless the
        reader asks for ``fresh=True`` (ProxyLint's mutable-key-fresh rule,
        observed at runtime)."""
        conn = InMemoryConnector(f"stale-{new_key()}")
        a = tracked_store("stale-a", conn)
        b = tracked_store("stale-b", conn)
        a.put({"gen": 1}, key="cell")
        assert a.get("cell") == {"gen": 1}  # fill
        b.put({"gen": 2}, key="cell")  # re-put behind a's cache
        with sanitize.expecting() as exp:
            assert a.get("cell") == {"gen": 1}  # stale!
        assert exp.categories() == {"stale_cache_read"}
        # the sanctioned read is clean and sees the new value
        before = len(san.violations)
        assert a.get("cell", fresh=True) == {"gen": 2}
        assert len(san.violations) == before
        a.evict("cell")


class TestLeakReport:
    def test_owned_cell_leak_named_with_mint_stack(self, san):
        store = tracked_store("leak")
        o = owned_proxy(store, np.arange(8))
        key = _state(o).key
        leaks = san.leak_report(store=store.name, kinds=("owned",))
        assert [l["key"] for l in leaks] == [key]
        assert leaks[0]["kind"] == "owned"
        assert "test_proxysan" in leaks[0]["minted_at"]  # provenance
        free(o)
        assert san.leak_report(store=store.name, kinds=("owned",)) == []

    def test_object_payload_leak_cleared_by_evict(self, san):
        store = tracked_store("obj-leak")
        key = store.put({"bulk": list(range(10))})
        leaks = san.leak_report(store=store.name, kinds=("object",))
        assert [l["key"] for l in leaks] == [key]
        store.evict(key)
        assert san.leak_report(store=store.name) == []

    def test_foreign_eviction_not_reported(self, san):
        """Residency is checked at report time: a key another process (here:
        a direct connector evict the sanitizer never saw) freed is gone."""
        conn = InMemoryConnector(f"foreign-{new_key()}")
        store = tracked_store("foreign", conn)
        key = store.put([1])
        conn.evict(key)  # behind the sanitizer's back
        assert san.leak_report(store=store.name) == []

    def test_assert_clean_on_isolated_instance(self):
        """Unit-level: a private Sanitizer instance, no global state."""
        s = Sanitizer()
        conn = InMemoryConnector(f"iso-{new_key()}")
        conn.put("k", b"x")
        s.on_put("iso", conn, "k")
        with pytest.raises(AssertionError, match="never freed"):
            s.assert_clean()
        s.on_evict("iso", conn, "k")
        conn.evict("k")
        s.assert_clean()


class TestWiring:
    def test_env_enabled_parsing(self, monkeypatch):
        for val, expect in (
            ("1", True), ("true", True), ("YES", True), ("on", True),
            ("0", False), ("no", False), ("", False),
        ):
            monkeypatch.setenv("REPRO_PROXYSAN", val)
            assert sanitize.env_enabled() is expect, val
        monkeypatch.delenv("REPRO_PROXYSAN")
        assert sanitize.env_enabled() is False

    def test_per_store_opt_in_tracks_only_that_store(self, san):
        san.enabled = False  # isolate the opt-in path (fixture restores)
        opted = tracked_store("opted")
        plain = Store(f"plain-{new_key()}", register=False)
        assert opted._san is san
        assert plain._san is None
        assert sanitize.current() is san
        assert sanitize.active_for(opted.name) is san
        assert sanitize.active_for(plain.name) is None

    def test_explicit_opt_out_wins_over_global_enable(self, san):
        """``Store(sanitize=False)`` — the durable-store escape hatch
        (checkpoint chunks): untracked even while the env switch is on,
        including the out-of-Store ownership hooks via ``active_for``."""
        san.enabled = True
        durable = Store(f"durable-{new_key()}", sanitize=False, register=False)
        assert durable._san is None
        assert sanitize.active_for(durable.name) is None
        key = durable.put(b"artifact")  # resident at exit — by design
        assert san.leak_report(store=durable.name) == []
        assert durable.get(key) == b"artifact"
        durable.evict(key)
        # re-opting in (a later Store view of the same name) flips it back
        san.track_store(durable.name)
        assert sanitize.active_for(durable.name) is san

    def test_expecting_routes_away_from_the_violation_list(self, san):
        store = tracked_store("exp")
        o = owned_proxy(store, [1])
        free(o)
        before = len(san.violations)
        with sanitize.expecting() as exp:
            free(o)
        assert len(san.violations) == before
        assert len(exp.records) == 1
        assert exp.records[0].category == "double_free"

    def test_counters_track_lifecycle_events(self, san):
        store = tracked_store("cnt")
        base = dict(san.counters)
        key = store.put([1, 2])
        store.get(key)
        store.get(key)
        store.evict(key)
        o = owned_proxy(store, [3])
        free(o)

        def grew(name):
            return san.counters.get(name, 0) - base.get(name, 0)

        assert grew("puts") >= 2  # the plain put + the owned mint
        assert grew("resolves") >= 2
        assert grew("evict_evict") >= 1
        assert grew("own_mints") >= 1
        assert grew("evict_owned-free") >= 1


class TestServeRequestProxies:
    def test_engine_close_reclaims_request_payloads(self, san):
        """The PR's serve-leak acceptance: run a serve whose responses no
        client ever resolves, then show every request-minted payload
        (prompt bulk, completion bulk, KV page cells) is reclaimed by
        ``engine.close()`` — the per-request ContextLifetime at work."""
        from _serve_toy import CountingModel
        from repro.configs import get_smoke_config
        from repro.core.streaming import (
            QueuePublisher,
            QueueSubscriber,
            StreamConsumer,
            StreamProducer,
        )
        from repro.serve.engine import ServeEngine, serve_context

        san.enabled = True  # track every store the serve stack creates
        cfg = get_smoke_config("smollm-135m")
        ns = f"sanserve-{new_key()}"
        req_store = Store(f"{ns}-req")
        resp_store = Store(f"{ns}-resp")
        producer = StreamProducer(QueuePublisher(ns), {"requests": req_store})
        consumer = StreamConsumer(QueueSubscriber("requests", ns), timeout=30)
        resp_producer = StreamProducer(
            QueuePublisher(ns), {"responses": resp_store}
        )
        engine = ServeEngine(
            serve_context(cfg), {}, slots=2, max_len=32, page_size=4,
            eos_id=-1, model=CountingModel(cfg),
        )
        rng = np.random.default_rng(0)
        for i in range(3):
            producer.send(
                "requests",
                {"prompt": rng.integers(1, cfg.vocab, 4).astype(np.int32)},
                metadata={"req_id": f"r{i}", "max_new_tokens": 3},
            )
            producer.flush_topic("requests")
        producer.close_topic("requests")
        completed = engine.run(consumer, resp_producer)
        assert sorted(completed) == ["r0", "r1", "r2"]
        kv_name = engine.kv_store.name
        # responses were never consumed: before close, the completion bulks
        # are resident by design (the client may still resolve them)
        assert san.leak_report(store=resp_store.name) != []
        engine.close()
        for name in (req_store.name, resp_store.name, kv_name):
            assert san.leak_report(store=name) == [], name


CHILD = textwrap.dedent(
    """
    import sys

    sys.path.insert(0, sys.argv[2])
    from repro.core import sanitize
    from repro.core.connectors import FileConnector
    from repro.core.ownership import free, owned_proxy
    from repro.core.store import Store

    assert sanitize.current() is not None, "REPRO_PROXYSAN did not enable"
    store = Store("sansmoke", FileConnector(sys.argv[1]))
    req = store.resolve("req", block=True, timeout=30, evict_on_resolve=True)
    store.put([x * 2 for x in req], key="resp")
    scratch = owned_proxy(store, {"scratch": req})
    free(scratch)
    store.wait_for("ack", timeout=30)  # parent evicted "resp" before this
    sanitize.current().assert_clean(store="sansmoke")
    print("CHILD-CLEAN")
    """
)


class TestCrossProcessSmoke:
    @pytest.mark.multiproc(timeout=120)
    def test_proxysan_smoke_clean_report(self, san, tmp_path):
        """The check.sh smoke: a producer/consumer pair over a FileConnector,
        both sides sanitized, both leak reports clean.  The child runs with
        ``REPRO_PROXYSAN=1`` (the env path) and its atexit report must say
        clean; the parent's keys that the *child* freed must not be reported
        (residency is checked at report time)."""
        workdir = tmp_path / "chan"
        child = tmp_path / "child.py"
        child.write_text(CHILD)
        store = Store(
            "sansmoke-parent", FileConnector(str(workdir)),
            sanitize=True, register=False,
        )
        store.put([1, 2, 3], key="req")
        env = {**os.environ, "REPRO_PROXYSAN": "1", "PYTHONPATH": SRC}
        proc = subprocess.Popen(
            [sys.executable, str(child), str(workdir), SRC],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        )
        try:
            store.wait_for("resp", timeout=60)
            assert store.resolve("resp", fresh=True) == [2, 4, 6]
            store.evict("resp")
            store.put(True, key="ack")
            out, err = proc.communicate(timeout=60)
        finally:
            proc.kill()
        assert proc.returncode == 0, err
        assert "CHILD-CLEAN" in out
        assert "[proxysan] clean" in err  # the child's atexit report
        store.evict("ack")
        # "req" was freed by the child; the parent minted it but must not
        # report it — only truly-resident payloads count
        assert san.leak_report(store=store.name) == []
