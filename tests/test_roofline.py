"""Roofline analyzer unit tests: HLO collective parsing + term math."""
from __future__ import annotations

import pytest

from repro.analysis.roofline import (
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    analyze,
    parse_collectives,
)

HLO_SAMPLE = """
HloModule jit_step

fused_computation {
  ROOT %x = f32[8,128]{1,0} add(%a, %b)
}

ENTRY %main {
  %ag = f32[576,96]{1,0} all-gather(%p0), channel_id=9, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
  %ar = bf16[1024,256]{1,0} all-reduce(%f1), channel_id=10, replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[64,64]{1,0} reduce-scatter(%f2), channel_id=11, replica_groups=[2,8]<=[16], dimensions={0}
  %cp = f32[32]{0} collective-permute(%f3), channel_id=12, source_target_pairs={{0,1},{1,0}}
  %ags = (f32[4,4]{1,0}, f32[16,4]{1,0}) all-gather-start(%f4), channel_id=13, replica_groups=[4,4]<=[16], dimensions={0}
  %agd = f32[16,4]{1,0} all-gather-done(%ags)
}
"""


class TestCollectiveParser:
    def test_counts_and_kinds(self):
        st = parse_collectives(HLO_SAMPLE, n_devices=256)
        assert st.ops["all-gather"]["count"] == 2  # plain + -start
        assert st.ops["all-reduce"]["count"] == 1
        assert st.ops["reduce-scatter"]["count"] == 1
        assert st.ops["collective-permute"]["count"] == 1
        # -done must NOT be double counted
        total = sum(v["count"] for v in st.ops.values())
        assert total == 5

    def test_wire_bytes_ring_model(self):
        st = parse_collectives(HLO_SAMPLE, n_devices=256)
        # all-gather: result 576*96*4 B, groups of 16 → wire = 15/16 × result
        ag = 576 * 96 * 4
        assert st.ops["all-gather"]["wire_bytes"] == pytest.approx(
            ag * 15 / 16 + (16 * 4 * 4) * 3 / 4
        )
        # all-reduce: result 1024*256*2 B, group 4 → 2×(3/4)
        ar = 1024 * 256 * 2
        assert st.ops["all-reduce"]["wire_bytes"] == pytest.approx(ar * 2 * 3 / 4)
        # reduce-scatter: result 64*64*4, group 8 → operand=8×result, wire=7×result
        rs = 64 * 64 * 4
        assert st.ops["reduce-scatter"]["wire_bytes"] == pytest.approx(rs * 7)

    def test_group_size_fallback(self):
        txt = "%ar = f32[16]{0} all-reduce(%x), to_apply=%add\n"
        st = parse_collectives(txt, n_devices=8)
        assert st.total_wire_bytes == pytest.approx(16 * 4 * 2 * 7 / 8)

    def test_dcn_attribution(self):
        # group of 16 when pods hold 4 devices → crosses DCN
        st = parse_collectives(HLO_SAMPLE, n_devices=16, pod_group=4)
        assert st.dcn_wire_bytes > 0
        st2 = parse_collectives(HLO_SAMPLE, n_devices=16, pod_group=64)
        assert st2.dcn_wire_bytes == 0

    def test_ignores_non_collective_lines(self):
        txt = "%f = f32[1024,1024]{1,0} fusion(%a), calls=%fused\n"
        st = parse_collectives(txt, n_devices=8)
        assert st.total_wire_bytes == 0


class TestTerms:
    def test_analysis_terms_and_dominance(self):
        rep = analyze(
            arch="x", shape="train_4k", mesh_desc="16x16", chips=256,
            cost={"flops": PEAK_FLOPS, "bytes accessed": HBM_BW / 2},
            hlo_text="%ar = f32[1024]{0} all-reduce(%x), replica_groups=[1,256]<=[256]\n",
            model_flops=PEAK_FLOPS * 256 * 0.5,
        )
        assert rep.t_compute == pytest.approx(1.0)
        assert rep.t_memory == pytest.approx(0.5)
        assert rep.t_collective == pytest.approx(
            1024 * 4 * 2 * 255 / 256 / LINK_BW
        )
        assert rep.dominant == "compute"
        assert rep.mfu_bound == pytest.approx(0.5)
        assert rep.useful_ratio == pytest.approx(0.5)

    def test_zero_cost_degenerates_gracefully(self):
        rep = analyze(
            arch="x", shape="s", mesh_desc="1", chips=1,
            cost={}, hlo_text="", model_flops=0.0,
        )
        assert rep.step_time == 0.0
        assert rep.mfu_bound == 0.0


class TestNNLS:
    """_nnls: the probe-fit solver must match brute-force NNLS on random
    small systems and never return negative coefficients."""

    def test_nonnegative_and_exact_on_consistent_systems(self):
        import numpy as np
        from repro.launch.dryrun import _nnls

        rng = np.random.default_rng(0)
        for _ in range(50):
            n, m = rng.integers(2, 6), rng.integers(4, 10)
            A = rng.uniform(0, 4, (m, n))
            beta_true = rng.uniform(0, 10, n)
            # random sparsity — some coefficients exactly zero
            beta_true[rng.random(n) < 0.3] = 0.0
            y = A @ beta_true
            beta = _nnls(A, y)
            assert (beta >= 0).all()
            # consistent nonneg system: reconstruction must match
            np.testing.assert_allclose(A @ beta, y, rtol=1e-6, atol=1e-6)

    def test_clamps_negative_tendency(self):
        import numpy as np
        from repro.launch.dryrun import _nnls

        # y decreasing in the second column would pull OLS negative
        A = np.array([[1.0, 1.0], [1.0, 2.0], [1.0, 3.0]])
        y = np.array([3.0, 2.0, 1.0])
        beta = _nnls(A, y)
        assert (beta >= 0).all()
