"""Suite bootstrap: src/ on sys.path, hypothesis fallback, multiproc guard,
and the ProxySan plugin.

The sys.path insert duplicates pyproject's ``pythonpath`` on purpose: this
conftest imports ``repro`` itself (for the hypothesis stub) and must not
depend on ini-option processing order.

``@pytest.mark.multiproc`` tests spawn subprocesses (lease workers, chaos
victims) and could wedge the tier-1 gate if a child never writes the key
the parent is blocked on.  A SIGALRM watchdog turns any such hang into a
prompt failure: default 120 s per test, raised per-test via
``pytest.mark.multiproc(timeout=...)``; the ``REPRO_MULTIPROC_TIMEOUT``
env var, when set, is a hard *cap* over both (scripts/check.sh sets it so
the gate's worst-case hang is bounded regardless of per-test budgets).

ProxySan plugin (``REPRO_PROXYSAN=1``): the whole suite runs under the
runtime sanitizer — every test fails on any *new* lifecycle violation
(use-after-evict, double-free, refcount underflow, stale cache read) it
caused, and the session exits non-zero if any Owned cell is still
resident after the last test.  ``scripts/check.sh`` sets the env var for
the tier-1 step; tests that exercise the failure paths on purpose scope
them with ``sanitize.expecting()``.  (Object-payload leak reports stay
per-scope — see test_proxysan.py — because many tests legitimately leave
payloads in stores they then drop whole.)
"""
import os
import signal
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro._compat import hypothesis_stub

    hypothesis_stub.install()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multiproc(timeout=120): spawns subprocesses; a SIGALRM watchdog "
        "fails the test after `timeout` seconds instead of wedging the gate",
    )


# -- ProxySan plugin ---------------------------------------------------------

from repro.core import sanitize as _sanitize  # noqa: E402


@pytest.fixture
def san():
    """The process sanitizer, state-snapshotted and restored around the
    test: nothing a test mints (or the violations it provokes on purpose)
    can bleed into the session gate or into other tests."""
    s = _sanitize._get()
    snap = (
        s.enabled,
        (set(s._opted), set(s._opted_out)),
        len(s.violations),
        set(s._live),
        set(s._freed),
        set(s._put_seq),
        set(s._fill_seq),
        set(s._borrows),
        dict(s.counters),
    )
    yield s
    with s._lock:
        s.enabled = snap[0]
        s._opted.clear()
        s._opted.update(snap[1][0])
        s._opted_out.clear()
        s._opted_out.update(snap[1][1])
        del s.violations[snap[2]:]
        for attr, keep in (
            ("_live", snap[3]),
            ("_freed", snap[4]),
            ("_put_seq", snap[5]),
            ("_fill_seq", snap[6]),
            ("_borrows", snap[7]),
        ):
            table = getattr(s, attr)
            for k in [k for k in table if k not in keep]:
                table.pop(k, None)
        s.counters.clear()
        s.counters.update(snap[8])


@pytest.fixture(autouse=True)
def _proxysan_guard():
    """Fail any test that caused a new sanitizer violation."""
    san = _sanitize.current()
    if san is None:
        yield
        return
    before = len(san.violations)
    yield
    new = san.violations[before:]
    assert not new, (
        f"ProxySan recorded {len(new)} violation(s) during this test:\n"
        + "\n".join(v.render() for v in new)
        + "\n(intentional misuse? scope it with sanitize.expecting())"
    )


def pytest_sessionfinish(session, exitstatus):
    """Sanitizer-clean gate: no violations, no leaked Owned cells."""
    san = _sanitize.current()
    if san is None:
        return
    import gc

    gc.collect()  # drop cycles so owner __del__ frees run before the report
    problems = [v.render() for v in san.violations]
    problems += [
        f"[proxysan:leak] owned cell {l['key']!r} in store {l['store']!r} "
        f"never freed\n  minted at:\n{l['minted_at']}"
        for l in san.leak_report(kinds=("owned",))
    ]
    if problems:
        tr = session.config.pluginmanager.get_plugin("terminalreporter")
        write = tr.write_line if tr is not None else print
        write("")
        write(f"ProxySan session gate: {len(problems)} problem(s)")
        for p in problems:
            write(p)
        # wrap_session returns session.exitstatus *after* this hook runs
        session.exitstatus = max(int(exitstatus) or 0, 1)
        session.testsfailed += 1


@pytest.hookimpl(wrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("multiproc")
    if marker is None or not hasattr(signal, "SIGALRM"):
        return (yield)
    timeout = int(marker.kwargs.get("timeout", 120))
    cap = os.environ.get("REPRO_MULTIPROC_TIMEOUT")
    if cap is not None:
        timeout = min(timeout, int(cap))  # env is a hard cap, not a default

    def on_alarm(signum, frame):
        raise TimeoutError(
            f"multiproc test exceeded its {timeout}s watchdog "
            f"(a subprocess is likely wedged): {item.nodeid}"
        )

    old_handler = signal.signal(signal.SIGALRM, on_alarm)
    signal.alarm(timeout)
    try:
        return (yield)
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old_handler)
