"""Suite bootstrap: src/ on sys.path + hypothesis fallback.

The sys.path insert duplicates pyproject's ``pythonpath`` on purpose: this
conftest imports ``repro`` itself (for the hypothesis stub) and must not
depend on ini-option processing order.
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro._compat import hypothesis_stub

    hypothesis_stub.install()
