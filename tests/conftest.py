"""Suite bootstrap: src/ on sys.path, hypothesis fallback, multiproc guard.

The sys.path insert duplicates pyproject's ``pythonpath`` on purpose: this
conftest imports ``repro`` itself (for the hypothesis stub) and must not
depend on ini-option processing order.

``@pytest.mark.multiproc`` tests spawn subprocesses (lease workers, chaos
victims) and could wedge the tier-1 gate if a child never writes the key
the parent is blocked on.  A SIGALRM watchdog turns any such hang into a
prompt failure: default 120 s per test, raised per-test via
``pytest.mark.multiproc(timeout=...)``; the ``REPRO_MULTIPROC_TIMEOUT``
env var, when set, is a hard *cap* over both (scripts/check.sh sets it so
the gate's worst-case hang is bounded regardless of per-test budgets).
"""
import os
import signal
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro._compat import hypothesis_stub

    hypothesis_stub.install()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multiproc(timeout=120): spawns subprocesses; a SIGALRM watchdog "
        "fails the test after `timeout` seconds instead of wedging the gate",
    )


@pytest.hookimpl(wrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("multiproc")
    if marker is None or not hasattr(signal, "SIGALRM"):
        return (yield)
    timeout = int(marker.kwargs.get("timeout", 120))
    cap = os.environ.get("REPRO_MULTIPROC_TIMEOUT")
    if cap is not None:
        timeout = min(timeout, int(cap))  # env is a hard cap, not a default

    def on_alarm(signum, frame):
        raise TimeoutError(
            f"multiproc test exceeded its {timeout}s watchdog "
            f"(a subprocess is likely wedged): {item.nodeid}"
        )

    old_handler = signal.signal(signal.SIGALRM, on_alarm)
    signal.alarm(timeout)
    try:
        return (yield)
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old_handler)
