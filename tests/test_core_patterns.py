"""Tests for the three paper patterns: futures (§IV-A), streaming (§IV-B),
ownership + lifetimes (§IV-C)."""
import pickle
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core import (
    ContextLifetime,
    FileConnector,
    FileLogPublisher,
    FileLogSubscriber,
    InMemoryConnector,
    LeaseLifetime,
    OwnershipError,
    Proxy,
    ProxyPolicy,
    QueuePublisher,
    QueueSubscriber,
    StaticLifetime,
    Store,
    StoreExecutor,
    StreamConsumer,
    StreamProducer,
    borrow,
    clone,
    extract,
    free,
    into_owned,
    is_resolved,
    mut_borrow,
    owned_proxy,
    release,
    update,
    wait_all,
)
from repro.core.ownership import is_valid, num_borrows


@pytest.fixture()
def store():
    with Store(f"pat-{id(object())}", InMemoryConnector()) as s:
        yield s


# ---------------------------------------------------------------------------
# ProxyFutures
# ---------------------------------------------------------------------------


class TestProxyFutures:
    def test_explicit_set_result(self, store):
        f = store.future()
        assert not f.done()
        f.set_result({"v": 1})
        assert f.done()
        assert f.result() == {"v": 1}

    def test_double_set_raises(self, store):
        f = store.future()
        f.set_result(1)
        with pytest.raises(RuntimeError):
            f.set_result(2)

    def test_proxy_created_before_target_exists(self, store):
        """The core §IV-A property: proxy minted before set_result."""
        f = store.future()
        p = f.proxy()
        assert not is_resolved(p)

        def producer():
            time.sleep(0.05)
            f.set_result("value")

        t = threading.Thread(target=producer)
        t.start()
        assert p == "value"  # blocks just-in-time
        t.join()

    def test_consumer_runs_before_producer(self, store):
        """Listing 1 shape: consumer task dispatched before producer finishes."""
        f = store.future()
        p = f.proxy()
        results = []

        def consumer(data):
            # implicit: code takes 'data' directly, proxy injected seamlessly
            results.append(data * 2)

        with ThreadPoolExecutor(2) as ex:
            c = ex.submit(consumer, p)
            time.sleep(0.02)
            ex.submit(lambda: f.set_result(21)).result()
            c.result(timeout=5)
        assert results == [42]

    def test_timeout(self, store):
        f = store.future(timeout=0.05)
        p = f.proxy()
        with pytest.raises(TimeoutError):
            extract(p)

    def test_pickle_future_and_proxy(self, store):
        f = store.future()
        f2 = pickle.loads(pickle.dumps(f))
        p = pickle.loads(pickle.dumps(f.proxy()))
        f2.set_result([1, 2])
        assert p == [1, 2]

    def test_wait_all(self, store):
        fs = [store.future() for _ in range(4)]

        def setter():
            for i, f in enumerate(fs):
                time.sleep(0.01)
                f.set_result(i)

        t = threading.Thread(target=setter)
        t.start()
        wait_all(fs, timeout=5)
        assert all(f.done() for f in fs)
        t.join()

    def test_cross_process_future_via_file_connector(self, tmp_path):
        # file-backed channel: producer/consumer need not coexist (mediated)
        with Store("xp-fut", FileConnector(str(tmp_path / "s"))) as s:
            f = s.future()
            p = f.proxy()
            f.set_result(np.arange(5))
            # simulate a different process: fresh objects from pickles
            p2 = pickle.loads(pickle.dumps(p))
            np.testing.assert_array_equal(extract(p2), np.arange(5))
            s.evict(f.key)  # reclaim the settled payload (ProxySan-clean)


# ---------------------------------------------------------------------------
# ProxyStream
# ---------------------------------------------------------------------------


class TestProxyStream:
    def test_basic_stream(self, store):
        ns = f"ns-{id(store)}"
        sub = QueueSubscriber("t", ns)
        with StreamProducer(QueuePublisher(ns), {"t": store}) as prod:
            for i in range(5):
                prod.send("t", {"i": i}, metadata={"idx": i})
            prod.close_topic("t")
            items = []
            with StreamConsumer(sub, timeout=5) as cons:
                for p in cons:
                    assert isinstance(p, Proxy)
                    items.append(extract(p)["i"])
        assert items == list(range(5))

    def test_metadata_without_bulk_resolution(self, store):
        """Dispatcher consumes metadata only; bulk stays in the store."""
        ns = f"ns2-{id(store)}"
        sub = QueueSubscriber("t", ns)
        prod = StreamProducer(QueuePublisher(ns), {"t": store}, evict_on_resolve=False)
        big = np.zeros(100_000)
        prod.send("t", big, metadata={"shape": big.shape})
        prod.flush()
        cons = StreamConsumer(sub, timeout=5)
        proxy, meta = cons.next_with_metadata()
        assert meta["shape"] == (100_000,)
        assert not is_resolved(proxy)  # no bulk transfer happened
        gets_before = store.metrics.get_count
        assert store.metrics.get_count == gets_before  # still none
        np.testing.assert_array_equal(extract(proxy), big)

    def test_evict_on_resolve_single_consumption(self, store):
        ns = f"ns3-{id(store)}"
        sub = QueueSubscriber("t", ns)
        prod = StreamProducer(QueuePublisher(ns), {"t": store}, evict_on_resolve=True)
        prod.send("t", "payload")
        prod.flush()
        cons = StreamConsumer(sub, timeout=5)
        p, _ = cons.next_with_metadata()
        key = object.__getattribute__(p, "__proxy_metadata__")["key"]
        assert store.exists(key)
        assert p == "payload"
        assert not store.exists(key)  # evicted after resolve

    def test_filtering_producer_and_consumer(self, store):
        ns = f"ns4-{id(store)}"
        sub = QueueSubscriber("t", ns)
        prod = StreamProducer(
            QueuePublisher(ns), {"t": store}, filter_=lambda o, m: o % 2 == 0
        )
        for i in range(6):
            prod.send("t", i, metadata={"i": i})
        prod.flush()
        prod.close_topic("t")
        cons = StreamConsumer(sub, filter_=lambda m: m["i"] >= 2, timeout=5)
        assert [extract(p) for p in cons] == [2, 4]

    def test_batching_and_aggregation(self, store):
        ns = f"ns5-{id(store)}"
        sub = QueueSubscriber("t", ns)
        prod = StreamProducer(
            QueuePublisher(ns),
            {"t": store},
            batch_size=3,
            aggregator=lambda objs: sum(objs),
        )
        for i in range(6):
            prod.send("t", i)
        prod.close_topic("t")
        cons = StreamConsumer(sub, timeout=5)
        assert [extract(p) for p in cons] == [0 + 1 + 2, 3 + 4 + 5]

    def test_multi_consumer_fanout(self, store):
        ns = f"ns6-{id(store)}"
        subs = [QueueSubscriber("t", ns) for _ in range(2)]
        prod = StreamProducer(
            QueuePublisher(ns), {"t": store}, evict_on_resolve=False
        )
        prod.send("t", 7)
        prod.flush()
        for sub in subs:
            p, _ = StreamConsumer(sub, timeout=5).next_with_metadata()
            assert extract(p) == 7

    def test_file_log_broker_cross_process_shape(self, tmp_path, store):
        pub = FileLogPublisher(str(tmp_path / "broker"))
        prod = StreamProducer(pub, {"t": store})
        for i in range(3):
            prod.send("t", i * 10)
        prod.close_topic("t")
        sub = FileLogSubscriber("t", str(tmp_path / "broker"))
        cons = StreamConsumer(sub, timeout=5)
        assert [extract(p) for p in cons] == [0, 10, 20]

    def test_topic_store_mapping(self, store):
        other = Store(f"other-{id(store)}", InMemoryConnector())
        ns = f"ns7-{id(store)}"
        suba, subb = QueueSubscriber("a", ns), QueueSubscriber("b", ns)
        prod = StreamProducer(QueuePublisher(ns), {"a": store, "b": other})
        prod.send("a", 1)
        prod.send("b", 2)
        prod.flush()
        pa, _ = StreamConsumer(suba, timeout=5).next_with_metadata()
        pb, _ = StreamConsumer(subb, timeout=5).next_with_metadata()
        assert extract(pa) == 1 and extract(pb) == 2
        assert store.metrics.put_count == 1 and other.metrics.put_count == 1
        other.close()


# ---------------------------------------------------------------------------
# Ownership
# ---------------------------------------------------------------------------


class TestOwnership:
    def test_owned_proxy_free_evicts(self, store):
        o = owned_proxy(store, [1, 2, 3])
        key = object.__getattribute__(o, "__proxy_metadata__")["key"]
        assert store.exists(key)
        assert o[0] == 1
        free(o)
        assert not store.exists(key)
        assert not is_valid(o)

    def test_many_immutable_borrows(self, store):
        o = owned_proxy(store, {"v": 1})
        refs = [borrow(o) for _ in range(5)]
        assert num_borrows(o) == (5, False)
        for r in refs:
            assert r["v"] == 1
            release(r)
        assert num_borrows(o) == (0, False)
        free(o)

    def test_mut_borrow_exclusive(self, store):
        o = owned_proxy(store, [0])
        m = mut_borrow(o)
        with pytest.raises(OwnershipError):
            borrow(o)
        with pytest.raises(OwnershipError):
            mut_borrow(o)
        release(m)
        r = borrow(o)
        with pytest.raises(OwnershipError):
            mut_borrow(o)  # immutable borrow outstanding
        release(r)
        free(o)

    def test_free_with_outstanding_borrow_raises(self, store):
        o = owned_proxy(store, "x")
        r = borrow(o)
        with pytest.raises(OwnershipError):
            free(o)
        release(r)
        free(o)

    def test_mutation_via_refmut_update(self, store):
        o = owned_proxy(store, {"n": 1})
        m = mut_borrow(o)
        m["n"] = 99  # mutate local copy
        update(m)  # write back to global store
        release(m)
        from repro.core import reset

        reset(o)
        assert o["n"] == 99
        free(o)

    def test_update_through_ref_raises(self, store):
        o = owned_proxy(store, [1])
        r = borrow(o)
        _ = r[0]
        with pytest.raises(OwnershipError):
            update(r)
        release(r)
        free(o)

    def test_clone_independent(self, store):
        o = owned_proxy(store, [1, 2])
        c = clone(o)
        free(o)
        assert c == [1, 2]  # clone survives original free
        free(c)

    def test_move_semantics_via_pickle(self, store):
        o = owned_proxy(store, "data")
        blob = pickle.dumps(o)  # ownership moves
        o2 = pickle.loads(blob)
        assert extract(o2) == "data"
        with pytest.raises(OwnershipError):
            borrow(o)  # moved-from owner unusable
        free(o2)

    def test_cannot_move_with_borrows(self, store):
        o = owned_proxy(store, "data")
        r = borrow(o)
        with pytest.raises(OwnershipError):
            pickle.dumps(o)
        release(r)
        free(o)

    def test_into_owned(self, store):
        p = store.proxy([5])
        o = into_owned(p)
        assert o == [5]
        free(o)

    def test_borrow_after_free_raises(self, store):
        o = owned_proxy(store, 1)
        free(o)
        with pytest.raises(OwnershipError):
            borrow(o)

    def test_use_after_free_keyerror(self, store):
        o = owned_proxy(store, [1])
        r = borrow(o)
        release(r)
        free(o)
        with pytest.raises(KeyError):
            extract(r)  # dangling reference: loud failure, not UB


class TestStoreExecutor:
    def test_borrow_released_on_task_completion(self, store):
        o = owned_proxy(store, np.arange(10))
        r = borrow(o)
        with StoreExecutor(ThreadPoolExecutor(2), store) as ex:
            fut = ex.submit(lambda a: int(np.asarray(a).sum()), r)
            assert fut.result() == 45
            for _ in range(100):
                if num_borrows(o) == (0, False):
                    break
                time.sleep(0.01)
        assert num_borrows(o) == (0, False)  # auto-released by callback
        del r
        free(o)

    def test_auto_proxy_large_args_and_results(self, store):
        policy = ProxyPolicy(min_bytes=100)
        big = list(range(1000))

        def fn(x):
            assert isinstance(x, Proxy)  # auto-proxied on the way in
            return list(x) + [1]  # big result → proxied on the way out

        with StoreExecutor(ThreadPoolExecutor(1), store, policy=policy) as ex:
            out = ex.submit(fn, big).result()
            assert isinstance(out, Proxy)
            assert len(out) == 1001

    def test_small_args_not_proxied(self, store):
        def fn(x):
            assert not isinstance(x, Proxy)
            return x + 1

        with StoreExecutor(ThreadPoolExecutor(1), store) as ex:
            assert ex.submit(fn, 1).result() == 2


# ---------------------------------------------------------------------------
# Lifetimes
# ---------------------------------------------------------------------------


class TestLifetimes:
    def test_context_lifetime(self, store):
        with ContextLifetime() as lt:
            p = store.proxy("v", lifetime=lt)
            key = object.__getattribute__(p, "__proxy_metadata__")["key"]
            assert store.exists(key)
        assert lt.done()
        assert not store.exists(key)

    def test_lease_lifetime_expiry_and_extend(self, store):
        lease = LeaseLifetime(store, expiry=0.15)
        p = store.proxy("v", lifetime=lease)
        key = object.__getattribute__(p, "__proxy_metadata__")["key"]
        lease.extend(0.15)
        time.sleep(0.2)
        assert not lease.done()  # extension kept it alive
        assert store.exists(key)
        time.sleep(0.25)
        assert lease.done()
        assert not store.exists(key)
        with pytest.raises(RuntimeError):
            lease.extend(1)

    def test_static_lifetime_persists(self, store):
        lt = StaticLifetime()
        p = store.proxy("v", lifetime=lt)
        key = object.__getattribute__(p, "__proxy_metadata__")["key"]
        assert store.exists(key)  # still alive; cleaned at interpreter exit
        lt.close()  # manual close for test hygiene
        assert not store.exists(key)

    def test_lifetime_after_close_raises(self, store):
        lt = ContextLifetime()
        lt.close()
        with pytest.raises(RuntimeError):
            store.proxy("v", lifetime=lt)
