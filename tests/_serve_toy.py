"""Re-export shim: the toy CountingModel moved to ``repro.serve.toy`` so
fleet engine subprocesses and benchmarks can import it without the tests
package on their path.  Existing tests keep importing from here."""
from repro.serve.toy import CountingModel, reference_decode

__all__ = ["CountingModel", "reference_decode"]
