"""Minimal hypothesis stand-in (this container cannot install packages).

Installed into ``sys.modules`` by tests/conftest.py ONLY when the real
hypothesis is absent.  Implements just the surface this suite uses —
``given`` / ``settings`` / ``HealthCheck`` and a handful of strategies —
with deterministic pseudo-random example generation (seeded per test
qualname) and a minimal first example per strategy.  No shrinking, no
example database, no stateful testing: if real hypothesis is available it
always wins.
"""
from __future__ import annotations

import functools
import inspect
import random
import string
import struct
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 25


class HealthCheck:
    function_scoped_fixture = "function_scoped_fixture"
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


class settings:
    """Decorator/config object; only ``max_examples`` is honoured."""

    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
                 suppress_health_check=(), derandomize=False, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_settings = self
        return fn


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example_at(self, rng: random.Random, i: int):
        return self._draw(rng, i)


def given(*arg_strategies, **kw_strategies):
    """Run the test body over generated examples.

    Positional strategies map to the RIGHTMOST parameters of the test (the
    hypothesis rule); keyword strategies map by name.  Remaining parameters
    (self, pytest fixtures) stay in the visible signature so pytest injects
    them normally.
    """

    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        names = [p.name for p in params]
        strat: dict[str, SearchStrategy] = {}
        if arg_strategies:
            strat.update(zip(names[len(names) - len(arg_strategies):],
                             arg_strategies))
        strat.update(kw_strategies)
        remaining = [p for p in params if p.name not in strat]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            st = (getattr(wrapper, "_stub_settings", None)
                  or getattr(fn, "_stub_settings", None))
            n = st.max_examples if st is not None else DEFAULT_MAX_EXAMPLES
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                drawn = {k: s.example_at(rng, i) for k, s in strat.items()}
                fn(*args, **{**kwargs, **drawn})

        wrapper.__signature__ = sig.replace(parameters=remaining)
        wrapper.is_hypothesis_test = True
        return wrapper

    return deco


# ---------------------------------------------------------------------------
# Strategies (the subset the suite imports)
# ---------------------------------------------------------------------------


def integers(min_value=None, max_value=None) -> SearchStrategy:
    def draw(rng, i):
        if min_value is not None and max_value is not None:
            if i == 0:
                return min_value
            if i == 1:
                return max_value
            return rng.randint(min_value, max_value)
        edges = (0, 1, -1, 127, -128, 2**31 - 1, -(2**31), 10**18)
        if i < len(edges):
            return edges[i]
        return rng.randint(-(2**63), 2**63)

    return SearchStrategy(draw)


def floats(min_value=None, max_value=None, allow_nan=None, allow_infinity=None,
           width=64, **_ignored) -> SearchStrategy:
    edges = (0.0, -0.0, 1.0, -1.5, 0.5, 1e-6, -1e6, 3.140625)

    def draw(rng, i):
        if i < len(edges):
            v = edges[i]
        else:
            kind = rng.randrange(3)
            if kind == 0:
                v = rng.gauss(0.0, 1.0)
            elif kind == 1:
                v = rng.uniform(-1e6, 1e6)
            else:
                v = rng.uniform(-1.0, 1.0) * 10.0 ** rng.randint(-20, 20)
        if width == 32:
            v = struct.unpack("f", struct.pack("f", v))[0]
        if min_value is not None:
            v = max(v, min_value)
        if max_value is not None:
            v = min(v, max_value)
        return v

    return SearchStrategy(draw)


_TEXT_ALPHABET = string.ascii_letters + string.digits + " _-./:äöü☃µ"


def text(alphabet=None, min_size=0, max_size=None) -> SearchStrategy:
    chars = alphabet or _TEXT_ALPHABET

    def draw(rng, i):
        if i == 0:
            return "a" * min_size
        hi = max_size if max_size is not None else min_size + 16
        n = rng.randint(min_size, max(min_size, hi))
        return "".join(rng.choice(chars) for _ in range(n))

    return SearchStrategy(draw)


def binary(min_size=0, max_size=None) -> SearchStrategy:
    def draw(rng, i):
        if i == 0:
            return b"\x00" * min_size
        hi = max_size if max_size is not None else min_size + 64
        n = rng.randint(min_size, max(min_size, hi))
        return bytes(rng.randrange(256) for _ in range(n))

    return SearchStrategy(draw)


def lists(elements: SearchStrategy, min_size=0, max_size=None) -> SearchStrategy:
    def draw(rng, i):
        hi = max_size if max_size is not None else min_size + 8
        n = min_size if i == 0 else rng.randint(min_size, max(min_size, hi))
        return [elements.example_at(rng, max(i, 1)) for _ in range(n)]

    return SearchStrategy(draw)


def dictionaries(keys: SearchStrategy, values: SearchStrategy, min_size=0,
                 max_size=None) -> SearchStrategy:
    def draw(rng, i):
        hi = max_size if max_size is not None else min_size + 8
        n = min_size if i == 0 else rng.randint(min_size, max(min_size, hi))
        return {
            keys.example_at(rng, max(i, 1)): values.example_at(rng, max(i, 1))
            for _ in range(n)
        }

    return SearchStrategy(draw)


def one_of(*strategies) -> SearchStrategy:
    if len(strategies) == 1 and isinstance(strategies[0], (list, tuple)):
        strategies = tuple(strategies[0])

    def draw(rng, i):
        # first pass: each branch's minimal example, then random branches
        if i < len(strategies):
            return strategies[i].example_at(rng, 0)
        return rng.choice(strategies).example_at(rng, i)

    return SearchStrategy(draw)


def sampled_from(seq) -> SearchStrategy:
    seq = list(seq)

    def draw(rng, i):
        if i < len(seq):
            return seq[i]
        return rng.choice(seq)

    return SearchStrategy(draw)


def booleans() -> SearchStrategy:
    return sampled_from([False, True])


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng, i: value)


def none() -> SearchStrategy:
    return just(None)


_STRATEGY_NAMES = (
    "integers", "floats", "text", "binary", "lists", "dictionaries",
    "one_of", "sampled_from", "booleans", "just", "none",
)


def install() -> None:
    """Register this stub as ``hypothesis`` / ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = HealthCheck
    mod.__stub__ = True
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in _STRATEGY_NAMES:
        setattr(st_mod, name, globals()[name])
    st_mod.__stub__ = True
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
