"""Version/dependency compatibility shims.

This container pins its environment (no installs), so API gaps are bridged
here instead of in requirements: ``jaxshims`` adapts the ``shard_map``
API rename, ``hypothesis_stub`` stands in for the absent hypothesis
package (installed by tests/conftest.py only when the real one is missing).
"""
