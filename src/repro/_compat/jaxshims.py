"""``shard_map`` across jax versions.

jax ≥ 0.5 exposes ``jax.shard_map(..., check_vma=...)``; 0.4.x only has
``jax.experimental.shard_map.shard_map(..., check_rep=...)``.  This module
exports a :func:`shard_map` accepting either keyword and (via import side
effect) installs it as ``jax.shard_map`` when absent, so subprocess test
bodies and user code written against the new spelling run on both.
"""
from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):
    _native = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _native

# pick the kwarg the native function actually accepts (jax.shard_map existed
# before the check_rep → check_vma rename, so presence alone is no signal)
_check_kw = (
    "check_vma"
    if "check_vma" in inspect.signature(_native).parameters
    else "check_rep"
)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, check_rep=None,
              **kw):
    check = check_vma if check_vma is not None else check_rep
    if check is not None:
        kw[_check_kw] = check
    return _native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


if not hasattr(jax, "shard_map"):
    jax.shard_map = shard_map


def ensure_pallas_compat() -> None:
    """Alias ``pltpu.CompilerParams`` (current spelling) on jax 0.4.x, which
    only ships ``TPUCompilerParams``.  Called by repro.kernels before any
    kernel module loads; idempotent."""
    from jax.experimental.pallas import tpu as pltpu

    if not hasattr(pltpu, "CompilerParams"):
        pltpu.CompilerParams = pltpu.TPUCompilerParams
