"""ProxyFutures — distributed futures over mediated channels (paper §IV-A).

A :class:`ProxyFuture` is created for an eventual value ``x``; any number of
proxies can be minted from it *before* ``x`` exists.  A consumer resolving
such a proxy blocks (in the store, on the connector's notification-based
``wait_for`` — engine-agnostic) until the producer calls :meth:`set_result`.
Both the future and its proxies are picklable and self-contained, so they
cross process/engine boundaries freely — the key property distinguishing
them from ``concurrent.futures`` / Dask / Ray futures (paper §VII).
"""
from __future__ import annotations

import time
from typing import Generic, TypeVar

from repro.core.connectors import wait_for_any
from repro.core.proxy import Proxy
from repro.core.store import Store, StoreFactory

T = TypeVar("T")


class _FutureError:
    """Channel payload standing in for a result when the producer raised.

    Travels through the store like any value; the consuming side
    (``result()`` or a future-minted proxy) re-raises the original
    exception instead of handing the wrapper to user code.
    """

    def __init__(self, exc: BaseException):
        self.exc = exc


class _FutureResultFactory(StoreFactory):
    """StoreFactory that unwraps producer errors on resolution."""

    def __call__(self):
        out = super().__call__()
        if isinstance(out, _FutureError):
            raise out.exc
        return out


class ProxyFuture(Generic[T]):
    """Future whose result is communicated through a Store."""

    def __init__(self, store: Store, key: str, *, timeout: float | None = None):
        self.store = store
        self.key = key
        self.timeout = timeout
        # Optional engine-side handle (StoreExecutor.submit_future); local
        # only — never pickled, the channel is the source of truth.
        self.task = None

    # -- producer side ---------------------------------------------------------
    def set_result(self, obj: T) -> None:
        # One atomic put-if-absent round trip (connector-arbitrated), not a
        # done()-then-put pair that races a concurrent setter.
        if not self.store.put_if_absent(obj, self.key):
            raise RuntimeError(f"future {self.key!r} already set")

    def set_exception(self, exc: BaseException) -> None:
        """Propagate a producer-side failure through the channel."""
        if not self.store.put_if_absent(_FutureError(exc), self.key):
            raise RuntimeError(f"future {self.key!r} already set")

    # -- consumer side (explicit) ------------------------------------------------
    def done(self) -> bool:
        return self.store.exists(self.key)

    def result(self, timeout: float | None = None) -> T:
        out = self.store.resolve(
            self.key, block=True, timeout=timeout or self.timeout
        )
        if isinstance(out, _FutureError):
            raise out.exc
        return out

    # -- consumer side (implicit: the paper's contribution) ------------------------
    def proxy(self) -> Proxy[T]:
        """Mint a transparent proxy that blocks just-in-time on first use."""
        factory = _FutureResultFactory(
            self.key,
            self.store.name,
            self.store.connector,
            block=True,
            timeout=self.timeout,
            deserializer=self.store._carried_deserializer(),
            serializer=self.store._carried_serializer(),
        )
        return Proxy(factory, metadata={"key": self.key, "store": self.store.name,
                                        "future": True})

    def cancel(self) -> None:
        self.store.evict(self.key)

    def __reduce__(self):
        return (_rebuild_future, (self.store, self.key, self.timeout))

    def __repr__(self):
        state = "done" if self.done() else "pending"
        return f"ProxyFuture(key={self.key!r}, {state})"


def _rebuild_future(store, key, timeout):
    return ProxyFuture(store, key, timeout=timeout)


def wait_all(futures: list[ProxyFuture], timeout: float | None = None) -> None:
    """Block until every future is set (barrier over the mediated channel).

    Futures are grouped by connector and each group drains through
    ``wait_for_any`` — one multi-key notification wait per connector (a
    single condition sleep / directory watch covers all pending keys), not
    N sequential single-key polls.
    """
    deadline = None if timeout is None else time.monotonic() + timeout
    groups: dict[int, tuple] = {}
    for f in futures:
        conn = f.store.connector
        groups.setdefault(id(conn), (conn, set()))[1].add(f.key)
    for conn, pending in groups.values():
        while pending:
            remaining = (
                None if deadline is None else max(0.0, deadline - time.monotonic())
            )
            ready = wait_for_any(
                conn, list(pending), remaining if timeout is not None else None
            )
            pending.discard(ready)
