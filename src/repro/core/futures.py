"""ProxyFutures — distributed futures over mediated channels (paper §IV-A).

A :class:`ProxyFuture` is created for an eventual value ``x``; any number of
proxies can be minted from it *before* ``x`` exists.  A consumer resolving
such a proxy blocks (in the store, with backoff polling — engine-agnostic)
until the producer calls :meth:`set_result`.  Both the future and its
proxies are picklable and self-contained, so they cross process/engine
boundaries freely — the key property distinguishing them from
``concurrent.futures`` / Dask / Ray futures (paper §VII).
"""
from __future__ import annotations

import time
from typing import Generic, TypeVar

from repro.core.connectors import wait_for_key
from repro.core.proxy import Proxy
from repro.core.store import Store, StoreFactory

T = TypeVar("T")


class ProxyFuture(Generic[T]):
    """Future whose result is communicated through a Store."""

    def __init__(self, store: Store, key: str, *, timeout: float | None = None):
        self.store = store
        self.key = key
        self.timeout = timeout

    # -- producer side ---------------------------------------------------------
    def set_result(self, obj: T) -> None:
        if self.done():
            raise RuntimeError(f"future {self.key!r} already set")
        self.store.put(obj, key=self.key)

    # -- consumer side (explicit) ------------------------------------------------
    def done(self) -> bool:
        return self.store.exists(self.key)

    def result(self, timeout: float | None = None) -> T:
        return self.store.resolve(
            self.key, block=True, timeout=timeout or self.timeout
        )

    # -- consumer side (implicit: the paper's contribution) ------------------------
    def proxy(self) -> Proxy[T]:
        """Mint a transparent proxy that blocks just-in-time on first use."""
        factory = StoreFactory(
            self.key,
            self.store.name,
            self.store.connector,
            block=True,
            timeout=self.timeout,
            deserializer=self.store._carried_deserializer(),
            serializer=self.store._carried_serializer(),
        )
        return Proxy(factory, metadata={"key": self.key, "store": self.store.name,
                                        "future": True})

    def cancel(self) -> None:
        self.store.evict(self.key)

    def __reduce__(self):
        return (_rebuild_future, (self.store, self.key, self.timeout))

    def __repr__(self):
        state = "done" if self.done() else "pending"
        return f"ProxyFuture(key={self.key!r}, {state})"


def _rebuild_future(store, key, timeout):
    return ProxyFuture(store, key, timeout=timeout)


def wait_all(futures: list[ProxyFuture], timeout: float | None = None) -> None:
    """Block until every future is set (barrier over the mediated channel)."""
    deadline = None if timeout is None else time.monotonic() + timeout
    for f in futures:
        remaining = None if deadline is None else max(0.0, deadline - time.monotonic())
        wait_for_key(f.store.connector, f.key, timeout=remaining if timeout else None)
