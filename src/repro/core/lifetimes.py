"""Lifetimes — scoped cleanup of proxied objects (paper §IV-C, Listing 4).

A :class:`Lifetime` is attached to proxies/keys at creation time and evicts
all associated objects when it ends.  Three concrete types, as in the paper:

- :class:`ContextLifetime` — ends when the ``with`` block exits.
- :class:`LeaseLifetime`   — ends when a (extendable) time lease expires.
- :class:`StaticLifetime`  — ends at interpreter exit.
"""
from __future__ import annotations

import atexit
import threading
import time
from typing import Iterable

from repro.core import sanitize as _sanitize
from repro.core.proxy import Proxy
from repro.core.store import Store


class Lifetime:
    """Base lifetime: a named scope owning a set of (store, key) pairs."""

    def __init__(self) -> None:
        self._entries: list[tuple[Store, str]] = []
        self._done = False
        self._lock = threading.Lock()

    def add(self, store: Store, key: str) -> None:
        with self._lock:
            if self._done:
                raise RuntimeError("cannot associate object with ended lifetime")
            self._entries.append((store, key))

    def add_proxy(self, proxy: Proxy) -> None:
        meta = object.__getattribute__(proxy, "__proxy_metadata__")
        store = Store.get_or_reattach(
            meta["store"], object.__getattribute__(proxy, "__factory__").connector
        )
        self.add(store, meta["key"])

    def done(self) -> bool:
        return self._done

    def close(self) -> None:
        with self._lock:
            if self._done:
                return
            self._done = True
            entries, self._entries = self._entries, []
        for store, key in entries:
            store.evict(key)
        if entries:
            # Under ProxySan a closed scope is a leak-check boundary: the
            # evicts above clear our entries from the live set, so anything
            # this scope was *supposed* to cover but didn't shows up in
            # leak_report() with its mint stack.
            san = _sanitize.current()
            if san:
                san.counters["lifetime_sweeps"] = (
                    san.counters.get("lifetime_sweeps", 0) + 1
                )

    def keys(self) -> Iterable[str]:
        return [k for _, k in self._entries]


class ContextLifetime(Lifetime):
    """Maps proxy lifetimes to a discrete code block."""

    def __enter__(self) -> "ContextLifetime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class LeaseLifetime(Lifetime):
    """Time-leased lifetime: evicts objects when the lease expires.

    Decentralized (no shared state): cleanup runs from a local timer thread,
    mirroring the lease mechanism of Gray & Cheriton the paper cites.
    """

    def __init__(self, store: Store | None = None, *, expiry: float = 10.0):
        super().__init__()
        self._default_store = store
        self._expires_at = time.monotonic() + expiry
        self._timer_lock = threading.Lock()
        self._timer: threading.Timer | None = None
        self._arm()

    def _arm(self) -> None:
        with self._timer_lock:
            if self._timer is not None:
                self._timer.cancel()
            delay = max(0.0, self._expires_at - time.monotonic())
            self._timer = threading.Timer(delay, self._maybe_expire)
            self._timer.daemon = True
            self._timer.start()

    def _maybe_expire(self) -> None:
        if time.monotonic() >= self._expires_at:
            self.close()
        else:  # lease was extended since this timer was armed
            self._arm()

    def extend(self, seconds: float) -> None:
        if self._done:
            raise RuntimeError("cannot extend an expired lease")
        self._expires_at += seconds
        self._arm()

    def remaining(self) -> float:
        return max(0.0, self._expires_at - time.monotonic())

    def close(self) -> None:
        with self._timer_lock:
            if self._timer is not None:
                self._timer.cancel()
        super().close()


class StaticLifetime(Lifetime):
    """Objects persist for the remainder of the program."""

    def __init__(self) -> None:
        super().__init__()
        atexit.register(self.close)
