"""ProxySan: opt-in runtime sanitizer for proxy lifecycle events (§IV-B/C).

The ownership and lifetime patterns make use-after-free and leaks
*impossible by construction* — when the rules are followed.  ProxySan
checks that they are: it instruments Store, ownership, and stream
lifecycle events with provenance-stamped records (mint, resolve, evict,
free, borrow, move) and reports, with creation stacks:

- **use_after_evict** — a resolve that *returned a value* for a key that
  was already freed/evicted (only possible through a stale in-process
  cache; a resolve that raises ``KeyError`` is the loud, correct failure
  and is counted, not flagged).
- **double_free** — ownership ``free()`` (or an ``OwnedProxy`` drop)
  evicting a key that some other path already freed.
- **refcount_underflow** — releasing a borrow token that was never
  issued for that cell (idempotent re-release of a known token is
  benign and only counted).
- **stale_cache_read** — a resolve-cache hit served after the key was
  re-put (overwritten) behind the cache's back.
- **leak** — via :meth:`Sanitizer.leak_report`: every Owned cell or
  plain proxy payload still resident in its connector, with the stack
  that minted it.

Enable globally with ``REPRO_PROXYSAN=1`` (an atexit report prints to
stderr) or per store with ``Store(name, sanitize=True)``; a store whose
residency is intentional — checkpoint chunks are durable artifacts, not
leaks — opts out with ``Store(name, sanitize=False)``, which wins over
the env switch.  The test suite runs under ProxySan when the env var is
set — ``scripts/check.sh`` sets it for the tier-1 pytest step and for
the multiproc smoke.

Tests that *intentionally* misuse the lifecycle (double-free tests,
use-after-free tests) scope the expected reports with::

    with sanitize.expecting() as exp:
        free(owner); free(owner)
    assert exp.categories() == {"double_free"}

``expecting`` is process-global (not thread-local) by design: the tests
that use it drive the misuse from a single thread.
"""
from __future__ import annotations

import atexit
import os
import sys
import threading
from collections import OrderedDict
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Iterable

_TRUTHY = ("1", "true", "yes", "on")

# Bounds: the sanitizer must be able to ride along under a full test
# suite without growing without limit.
_MAX_FREED = 50_000
_MAX_VIOLATIONS = 200
_STACK_DEPTH = 8


def env_enabled() -> bool:
    return os.environ.get("REPRO_PROXYSAN", "").strip().lower() in _TRUTHY


def _stack(skip: int = 2) -> tuple:
    """Cheap provenance: raw (filename, lineno, func) frames, no formatting."""
    frames = []
    try:
        f = sys._getframe(skip)
    except ValueError:  # pragma: no cover - shallow stack
        return ()
    while f is not None and len(frames) < _STACK_DEPTH:
        code = f.f_code
        frames.append((code.co_filename, f.f_lineno, code.co_name))
        f = f.f_back
    return tuple(frames)


def format_stack(stack: Iterable) -> str:
    return "\n".join(f"    {fn}:{ln} in {name}" for fn, ln, name in stack)


def _conn_id(connector: Any) -> str:
    """Stable identity for a mediated channel, shared across Store views.

    Delegates to :func:`repro.core.connectors.channel_identity` (imported
    lazily — sanitize must stay importable before connectors): a
    server-backed channel is ONE object across every client socket, a
    tiered MultiConnector is one object across its stack, so lifecycle
    events recorded through different Store/connector instances land on
    the same record.
    """
    from repro.core.connectors import channel_identity

    return channel_identity(connector)


@dataclass
class MintRecord:
    store: str
    key: str
    kind: str  # "object" | "owned"
    stack: tuple
    connector: Any = field(repr=False, default=None)


@dataclass
class Violation:
    category: str
    store: str
    key: str
    message: str
    stack: tuple = ()
    minted_at: tuple = ()
    freed_at: tuple = ()

    def render(self) -> str:
        out = [f"[proxysan:{self.category}] {self.message} (store={self.store!r}, key={self.key!r})"]
        if self.stack:
            out.append("  at:\n" + format_stack(self.stack))
        if self.minted_at:
            out.append("  minted at:\n" + format_stack(self.minted_at))
        if self.freed_at:
            out.append("  freed at:\n" + format_stack(self.freed_at))
        return "\n".join(out)

    def __str__(self) -> str:  # pragma: no cover - repr convenience
        return self.render()


class _Expectation:
    """Records routed away from the violation list inside ``expecting()``."""

    def __init__(self):
        self.records: list[Violation] = []

    def categories(self) -> set:
        return {v.category for v in self.records}


class Sanitizer:
    """Event recorder + checker.  All hooks are cheap no-ops when a store
    is not tracked; mutation is guarded by one reentrant lock."""

    def __init__(self):
        self._lock = threading.RLock()
        self.enabled = False  # global (every store)
        self._opted: set[str] = set()  # per-store opt-ins
        self._opted_out: set[str] = set()  # per-store opt-OUTs (win over enabled)
        # (conn_id, key) -> MintRecord for payloads we saw minted
        self._live: "OrderedDict[tuple, MintRecord]" = OrderedDict()
        # (conn_id, key) -> (stack, via) for payloads we saw freed
        self._freed: "OrderedDict[tuple, tuple]" = OrderedDict()
        # staleness: per-key write and cache-fill sequence numbers
        self._put_seq: dict[tuple, int] = {}
        self._fill_seq: dict[tuple, int] = {}
        # borrow tokens: (conn_id, key) -> {token: "out" | "released"}
        self._borrows: dict[tuple, dict] = {}
        self.violations: list[Violation] = []
        self.counters: dict[str, int] = {}
        self._expect: list[_Expectation] = []

    # -- wiring ---------------------------------------------------------------
    def track_store(self, name: str) -> None:
        with self._lock:
            self._opted.add(name)
            self._opted_out.discard(name)

    def untrack_store(self, name: str) -> None:
        """Explicit opt-out: wins over global enable, and also silences
        the out-of-Store hooks (ownership, lifetimes) for this store —
        otherwise an opted-out durable store's owned manifests would
        still surface as gating leaks through ``active_for``."""
        with self._lock:
            self._opted_out.add(name)
            self._opted.discard(name)

    def tracked(self, store_name: str) -> bool:
        if store_name in self._opted_out:
            return False
        return self.enabled or store_name in self._opted

    def _count(self, what: str, n: int = 1) -> None:
        self.counters[what] = self.counters.get(what, 0) + n

    def _violate(self, v: Violation) -> None:
        with self._lock:
            self._count("violations_total")
            if self._expect:
                self._expect[-1].records.append(v)
            elif len(self.violations) < _MAX_VIOLATIONS:
                self.violations.append(v)

    @contextmanager
    def expecting(self):
        exp = _Expectation()
        with self._lock:
            self._expect.append(exp)
        try:
            yield exp
        finally:
            with self._lock:
                self._expect.remove(exp)

    # -- store events ---------------------------------------------------------
    def on_put(self, store: str, connector, key: str, *,
               kind: str = "object", overwrite: bool = False) -> None:
        k = (_conn_id(connector), key)
        with self._lock:
            self._count("puts")
            self._put_seq[k] = self._put_seq.get(k, 0) + 1
            self._freed.pop(k, None)  # a re-put resurrects the key
            rec = self._live.get(k)
            if rec is None:
                self._live[k] = MintRecord(store, key, kind, _stack(3), connector)
            elif kind == "owned":
                rec.kind = kind

    def on_resolve(self, store: str, connector, key: str, *, hit: bool) -> None:
        k = (_conn_id(connector), key)
        with self._lock:
            self._count("resolves")
            if hit:
                freed = self._freed.get(k)
                if freed is not None:
                    self._violate(Violation(
                        "use_after_evict", store, key,
                        "cached resolve returned a value for a freed key",
                        stack=_stack(3), freed_at=freed[0],
                        minted_at=(),
                    ))
                    return
                fill = self._fill_seq.get(k)
                put = self._put_seq.get(k)
                if fill is not None and put is not None and fill < put:
                    self._violate(Violation(
                        "stale_cache_read", store, key,
                        "resolve-cache hit served after the key was re-put "
                        "(read mutable keys with fresh=True)",
                        stack=_stack(3),
                    ))
            else:
                self._fill_seq[k] = self._put_seq.get(k, 0)

    def on_resolve_missing(self, store: str, connector, key: str) -> None:
        k = (_conn_id(connector), key)
        with self._lock:
            self._count("resolve_missing")
            if k in self._freed:
                # The loud, correct failure mode: freed key raises KeyError.
                self._count("resolve_after_free_raised")

    def on_evict(self, store: str, connector, key: str, *, via: str = "evict") -> None:
        k = (_conn_id(connector), key)
        with self._lock:
            self._count(f"evict_{via}")
            rec = self._live.pop(k, None)
            self._put_seq.pop(k, None)
            self._fill_seq.pop(k, None)
            already = self._freed.get(k)
            if rec is None and already is not None and via in ("owned-free", "owned-del"):
                self._violate(Violation(
                    "double_free", store, key,
                    f"ownership free ({via}) of a key already freed",
                    stack=_stack(3), freed_at=already[0],
                ))
                return
            self._freed[k] = (_stack(3), via)
            while len(self._freed) > _MAX_FREED:
                self._freed.popitem(last=False)

    # -- ownership events -----------------------------------------------------
    def on_own_mint(self, store: str, connector, key: str) -> None:
        k = (_conn_id(connector), key)
        with self._lock:
            self._count("own_mints")
            rec = self._live.get(k)
            if rec is None:
                self._live[k] = MintRecord(store, key, "owned", _stack(3), connector)
            else:
                rec.kind = "owned"

    def on_own_free(self, store: str, connector, key: str, *, via: str) -> None:
        self.on_evict(store, connector, key, via=via)

    def on_double_free(self, store: str, connector, key: str) -> None:
        k = (_conn_id(connector), key)
        with self._lock:
            freed = self._freed.get(k)
            self._violate(Violation(
                "double_free", store, key,
                "free() called on an already-freed ownership cell",
                stack=_stack(3), freed_at=freed[0] if freed else (),
            ))

    def on_borrow(self, connector, key: str, token: str, *, mut: bool) -> None:
        k = (_conn_id(connector), key)
        with self._lock:
            self._count("mut_borrows" if mut else "borrows")
            self._borrows.setdefault(k, {})[token] = "out"

    def on_release(self, store: str, connector, key: str, token: str) -> None:
        k = (_conn_id(connector), key)
        with self._lock:
            tokens = self._borrows.get(k)
            state = tokens.get(token) if tokens else None
            if state == "out":
                tokens[token] = "released"
                self._count("releases")
            elif state == "released":
                self._count("redundant_releases")  # idempotent re-release
            else:
                self._violate(Violation(
                    "refcount_underflow", store, key,
                    f"release of borrow token {token!r} that was never "
                    "issued for this cell",
                    stack=_stack(3),
                ))

    def on_move(self, connector, key: str) -> None:
        with self._lock:
            self._count("moves")

    def note_orphan(self, store: str, connector, key: str) -> None:
        """Register an externally-minted payload this process *failed to
        reclaim* (a serve engine's best-effort orphaned-bulk evict threw).

        The payload was put by another process, so no local ``on_put``
        record exists — without this hook the orphan is invisible to the
        sanitizer even though it will sit resident in the channel forever.
        Recording a live mint here makes it surface in ``leak_report()`` /
        ``report()`` for as long as it stays resident, with the *reclaim
        failure site* as its provenance stack.
        """
        k = (_conn_id(connector), key)
        with self._lock:
            self._count("orphans_noted")
            if k not in self._live:
                self._live[k] = MintRecord(store, key, "object", _stack(2), connector)

    # -- reporting ------------------------------------------------------------
    def live_records(self, *, store: str | None = None,
                     kinds: tuple = ("owned", "object")) -> list[MintRecord]:
        with self._lock:
            recs = list(self._live.values())
        return [r for r in recs
                if r.kind in kinds and (store is None or r.store == store)]

    def leak_report(self, *, store: str | None = None,
                    kinds: tuple = ("owned", "object")) -> list[dict]:
        """Minted payloads still resident in their connector.

        Residency is checked at report time (cold path) so payloads whose
        store/connector was torn down — or that another process freed —
        don't count.
        """
        leaks = []
        for rec in self.live_records(store=store, kinds=kinds):
            try:
                resident = rec.connector is not None and rec.connector.exists(rec.key)
            except Exception:
                resident = False
            if resident:
                leaks.append({
                    "kind": rec.kind,
                    "store": rec.store,
                    "key": rec.key,
                    "minted_at": format_stack(rec.stack),
                })
        return leaks

    def assert_clean(self, *, store: str | None = None,
                     kinds: tuple = ("owned", "object")) -> None:
        problems = [v.render() for v in self.violations]
        problems += [
            f"[proxysan:leak] {l['kind']} {l['key']!r} in store {l['store']!r} "
            f"never freed\n  minted at:\n{l['minted_at']}"
            for l in self.leak_report(store=store, kinds=kinds)
        ]
        if problems:
            raise AssertionError(
                f"ProxySan found {len(problems)} problem(s):\n" + "\n".join(problems)
            )

    def report(self, out=None) -> int:
        """Human-readable end-of-run report; returns the problem count."""
        out = out if out is not None else sys.stderr
        leaks = self.leak_report()
        n = len(self.violations) + len(leaks)
        if n == 0:
            print("[proxysan] clean: no violations, no leaks "
                  f"(counters: {self.counters})", file=out)
            return 0
        print(f"[proxysan] {len(self.violations)} violation(s), "
              f"{len(leaks)} leak(s):", file=out)
        for v in self.violations:
            print(v.render(), file=out)
        for l in leaks:
            print(f"[proxysan:leak] {l['kind']} {l['key']!r} in store "
                  f"{l['store']!r} never freed\n  minted at:\n{l['minted_at']}",
                  file=out)
        return n

    def reset(self) -> None:
        with self._lock:
            self._live.clear()
            self._freed.clear()
            self._put_seq.clear()
            self._fill_seq.clear()
            self._borrows.clear()
            self.violations.clear()
            self.counters.clear()


# ---------------------------------------------------------------------------
# Module-level singleton.  ``current()`` is None until someone opts in, so
# the instrumented hot paths pay one attribute load + None test when the
# sanitizer is off.
# ---------------------------------------------------------------------------

_SAN: Sanitizer | None = None
_SAN_LOCK = threading.Lock()


def _get() -> Sanitizer:
    global _SAN
    with _SAN_LOCK:
        if _SAN is None:
            _SAN = Sanitizer()
        return _SAN


def current() -> Sanitizer | None:
    """The active sanitizer, or None when nothing opted in."""
    s = _SAN
    return s if s is not None and (s.enabled or s._opted) else None


def enable() -> Sanitizer:
    """Enable globally (all stores)."""
    s = _get()
    s.enabled = True
    return s


def disable() -> None:
    s = _SAN
    if s is not None:
        s.enabled = False
        s._opted.clear()


def store_sanitizer(store_name: str, opt_in: bool | None = None) -> Sanitizer | None:
    """Resolve the sanitizer a Store should hook into (None = untracked).

    ``opt_in`` is tri-state: ``True`` tracks this store even without
    ``REPRO_PROXYSAN``; ``None`` follows the env switch; ``False`` is an
    explicit opt-OUT that wins over the env switch — for stores whose
    residency is the product, not a leak (checkpoint chunks are durable
    artifacts a later process restores from; reporting them would make
    every retained checkpoint a false positive).
    """
    if opt_in is False:
        s = _SAN
        if s is not None:
            s.untrack_store(store_name)
        return None
    if opt_in:
        s = _get()
        s.track_store(store_name)
        return s
    s = _SAN
    if s is not None and s.tracked(store_name):
        return s
    return None


def active_for(store_name: str) -> Sanitizer | None:
    """Sanitizer for out-of-Store call sites (ownership, stream evicts)."""
    s = _SAN
    if s is not None and s.tracked(store_name):
        return s
    return None


@contextmanager
def expecting():
    """Scope intentional lifecycle misuse (tests of the failure paths)."""
    s = _get()
    with s.expecting() as exp:
        yield exp


def _atexit_report() -> None:  # pragma: no cover - exercised in subprocesses
    s = _SAN
    if s is not None and (s.enabled or s._opted):
        s.report()


if env_enabled():
    enable()

atexit.register(_atexit_report)
