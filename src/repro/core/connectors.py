"""Mediated communication channels (paper §III: *connector* protocol).

A connector is the low-level interface to a mediated channel — producer and
consumer communicate indirectly through it, so they need not be alive at the
same time.  The paper ships Redis/KeyDB/Globus/UCX/Margo connectors; on this
single-node container we provide:

- :class:`InMemoryConnector` — dict-backed, zero-copy, thread-shared.
- :class:`FileConnector`     — directory-backed, cross-process, persistent.
- :class:`SharedMemoryConnector` — POSIX shm backed, cross-process, fast.

All satisfy the :class:`Connector` protocol so higher layers (Store, streams,
futures, ownership) are transport-agnostic, exactly as in the paper.
"""
from __future__ import annotations

import os
import threading
import time
import uuid
from typing import Iterable, Protocol, runtime_checkable


def new_key() -> str:
    return uuid.uuid4().hex


@runtime_checkable
class Connector(Protocol):
    """Low-level mediated-channel interface."""

    def put(self, key: str, data: bytes) -> None: ...

    def get(self, key: str) -> bytes | None: ...

    def exists(self, key: str) -> bool: ...

    def evict(self, key: str) -> None: ...

    def close(self) -> None: ...


class InMemoryConnector:
    """Thread-shared in-process object store (the 'Redis' of one process).

    Class-level registry keyed by namespace so that factories reconstructed
    from pickles within the same process find the same storage.
    """

    _registry: dict[str, dict[str, bytes]] = {}
    _lock = threading.Lock()

    def __init__(self, namespace: str | None = None):
        self.namespace = namespace or new_key()
        with InMemoryConnector._lock:
            InMemoryConnector._registry.setdefault(self.namespace, {})

    @property
    def _store(self) -> dict[str, bytes]:
        return InMemoryConnector._registry.setdefault(self.namespace, {})

    def put(self, key: str, data: bytes) -> None:
        self._store[key] = data

    def get(self, key: str) -> bytes | None:
        return self._store.get(key)

    def exists(self, key: str) -> bool:
        return key in self._store

    def evict(self, key: str) -> None:
        self._store.pop(key, None)

    def keys(self) -> Iterable[str]:
        return list(self._store.keys())

    def close(self) -> None:
        with InMemoryConnector._lock:
            InMemoryConnector._registry.pop(self.namespace, None)

    # picklable: same namespace reattaches in-process; this mirrors the
    # paper's connectors whose pickled form carries server address info.
    def __reduce__(self):
        return (InMemoryConnector, (self.namespace,))


class FileConnector:
    """Filesystem-mediated channel (cross-process, survives restarts).

    Writes are atomic (tmp + rename) so a concurrent ``get``/``exists``
    never observes a partial object — required by the polling resolution
    of distributed futures.
    """

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.directory, key)

    def put(self, key: str, data: bytes) -> None:
        tmp = self._path(key) + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._path(key))

    def get(self, key: str) -> bytes | None:
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            return None

    def exists(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def evict(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def keys(self) -> Iterable[str]:
        return [k for k in os.listdir(self.directory) if ".tmp." not in k]

    def close(self) -> None:
        pass

    def __reduce__(self):
        return (FileConnector, (self.directory,))


class SharedMemoryConnector:
    """POSIX shared-memory channel: cross-process without filesystem I/O.

    Each object gets its own ``multiprocessing.shared_memory`` segment named
    ``psx_<namespace>_<key>``; an index is not needed because keys are
    content-addressed by the caller (Store).  This is the high-bandwidth
    'UCX-like' transport of the single-node setting.
    """

    def __init__(self, namespace: str | None = None):
        self.namespace = (namespace or new_key())[:12]

    def _name(self, key: str) -> str:
        # shm names have tight length limits on some platforms
        return f"psx{self.namespace}{key[:32]}"

    def put(self, key: str, data: bytes) -> None:
        from multiprocessing import shared_memory

        name = self._name(key)
        try:
            seg = shared_memory.SharedMemory(name=name, create=True, size=max(len(data), 1) + 8)
        except FileExistsError:
            old = shared_memory.SharedMemory(name=name)
            old.close()
            old.unlink()
            seg = shared_memory.SharedMemory(name=name, create=True, size=max(len(data), 1) + 8)
        try:
            seg.buf[:8] = len(data).to_bytes(8, "little")
            seg.buf[8 : 8 + len(data)] = data
        finally:
            seg.close()

    def get(self, key: str) -> bytes | None:
        from multiprocessing import shared_memory

        try:
            seg = shared_memory.SharedMemory(name=self._name(key))
        except FileNotFoundError:
            return None
        try:
            n = int.from_bytes(bytes(seg.buf[:8]), "little")
            return bytes(seg.buf[8 : 8 + n])
        finally:
            seg.close()

    def exists(self, key: str) -> bool:
        from multiprocessing import shared_memory

        try:
            seg = shared_memory.SharedMemory(name=self._name(key))
        except FileNotFoundError:
            return False
        seg.close()
        return True

    def evict(self, key: str) -> None:
        from multiprocessing import shared_memory

        try:
            seg = shared_memory.SharedMemory(name=self._name(key))
        except FileNotFoundError:
            return
        seg.close()
        try:
            seg.unlink()
        except FileNotFoundError:
            pass

    def close(self) -> None:
        pass

    def __reduce__(self):
        return (SharedMemoryConnector, (self.namespace,))


def wait_for_key(
    connector: Connector,
    key: str,
    timeout: float | None = None,
    poll_min: float = 1e-4,
    poll_max: float = 0.01,
) -> bytes:
    """Block until ``key`` exists in the channel, with exponential backoff.

    This is the mediated-channel analogue of `Future.result()` used by
    ProxyFuture resolution (paper §IV-A): producer and consumer synchronize
    *through the store*, never through engine-specific primitives.
    """
    deadline = None if timeout is None else time.monotonic() + timeout
    delay = poll_min
    while True:
        data = connector.get(key)
        if data is not None:
            return data
        if deadline is not None and time.monotonic() > deadline:
            raise TimeoutError(f"future target {key!r} not set within {timeout}s")
        time.sleep(delay)
        delay = min(delay * 2.0, poll_max)
