"""Mediated communication channels (paper §III: *connector* protocol).

A connector is the low-level interface to a mediated channel — producer and
consumer communicate indirectly through it, so they need not be alive at the
same time.  The paper ships Redis/KeyDB/Globus/UCX/Margo connectors; on this
single-node container we provide:

- :class:`InMemoryConnector` — dict-backed, zero-copy, thread-shared.
- :class:`FileConnector`     — directory-backed, cross-process, persistent.
- :class:`SharedMemoryConnector` — POSIX shm backed, cross-process, fast.

All satisfy the :class:`Connector` protocol so higher layers (Store, streams,
futures, ownership) are transport-agnostic, exactly as in the paper.

Hot-path extensions (all optional; duck-typed with protocol-level fallbacks
via :func:`put_payload` / :func:`put_batch_payloads` / :func:`get_view` /
:func:`put_payload_new` / :func:`wait_for` / :func:`wait_for_any`):

- ``put_parts(key, parts)`` — vectored put of a framed-parts payload, so the
  connector writes header + raw buffers without a join copy;
- ``put_batch(items)``      — amortized multi-object put (stream batches);
- ``get_view(key)``         — zero-copy read: a memoryview over channel
  memory (dict bytes, shm segment, mmap'd file) instead of a bytes copy;
- ``put_parts_new(key, parts)`` — atomic put-if-absent (``None`` when the
  key already exists): the single-round-trip future ``set_result`` path;
- ``wait_for(key, timeout)`` / ``wait_for_any(keys, timeout)`` — blocking
  existence waits that are *notified* instead of polled: condition-variable
  wake-ups in memory, directory mtime/size watches on files, segment
  watches on shared memory.  Connectors without them fall back to the
  exponential-backoff existence poll (one shared deadline and one backoff
  sweep across every key — the sweep never overshoots ``timeout`` by more
  than the last clamped sleep).

Tier routing (:mod:`repro.core.multi`): a :class:`~repro.core.multi.
MultiConnector` composes a priority-ordered stack of these channels into
one tiered store.  Each put is routed by policy — explicit per-key pins,
``#tag`` segments carried in the key, then size thresholds
(``min_bytes``/``max_bytes`` per tier; tiny → in-memory, medium → shm,
bulk → file/network) — and the winning tier is recorded in a per-process
route map so a resolve goes straight to the right backend.  A miss falls
through the stack in priority order (the cross-process path, and the hook
memory-pressure demotion rides: ``demote`` moves a payload to a colder
tier and resolution keeps working transparently).

Wire protocol (:mod:`repro.core.connectors_net`): the TCP store server
speaks length-prefixed frames —

    ``u32 frame_len | u8 op/status | body``

— where a put body carries the key, the framed-part lengths, and then the
raw PSF1 parts themselves, written with scatter-gather ``sendmsg`` (the
out-of-band pickle-5 buffers are never joined in user space) and read
with ``recv_into`` a single preallocated buffer (payload slices are
zero-copy views of it).  Waits are server-side pushes: the client blocks
on the response while the server blocks in the backing channel's native
notification wait, so no one polls the network.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
import uuid
from typing import Iterable, Protocol, Sequence, runtime_checkable

from repro.core.framing import join_parts, parts_nbytes


# Key generation sits on the put hot path; uuid4 costs a getrandom syscall
# per key (tens of µs on older kernels), so draw entropy once per process,
# append a monotonic counter, and render keys in preallocated blocks (one
# list-comprehension format pass per _KEY_BLOCK keys beats a dict-lookup +
# f-string per call).  Forked children re-seed their prefix.
_KEY_BLOCK = 256
_KEY_STATE = {"prefix": uuid.uuid4().hex[:16], "count": itertools.count(),
              "pool": []}


def _reseed_key_prefix() -> None:
    _KEY_STATE["prefix"] = uuid.uuid4().hex[:16]
    _KEY_STATE["count"] = itertools.count()
    _KEY_STATE["pool"] = []


if hasattr(os, "register_at_fork"):
    os.register_at_fork(after_in_child=_reseed_key_prefix)


def new_key() -> str:
    try:
        return _KEY_STATE["pool"].pop()
    except IndexError:
        # Racing refills are safe: the shared counter keeps every rendered
        # key unique, and list.pop/extend are atomic under the GIL.
        prefix, count = _KEY_STATE["prefix"], _KEY_STATE["count"]
        pool = [f"{prefix}{n:012x}" for n in itertools.islice(count, _KEY_BLOCK)]
        _KEY_STATE["pool"].extend(pool[:-1])
        return pool[-1]


def channel_identity(connector) -> str:
    """Stable identity of the mediated channel *behind* a connector.

    Two connector instances attached to the same channel — two clients of
    one TCP store server, two shm connectors sharing a namespace, a
    pickled copy on the far side — must compare equal here: ProxySan keys
    its lifecycle records by this string, so a server-backed channel is
    one object across clients, not one per socket.  Connectors with a
    composite or remote channel export ``channel_id`` explicitly; the
    rest are identified by their storage handle (namespace, directory).
    """
    cid = getattr(connector, "channel_id", None)
    if isinstance(cid, str) and cid:
        return f"{type(connector).__name__}:{cid}"
    for attr in ("namespace", "name", "directory", "prefix"):
        v = getattr(connector, attr, None)
        if isinstance(v, str) and v:
            return f"{type(connector).__name__}:{v}"
    return f"{type(connector).__name__}@{id(connector):x}"


@runtime_checkable
class Connector(Protocol):
    """Low-level mediated-channel interface."""

    def put(self, key: str, data: bytes) -> None: ...

    def get(self, key: str) -> bytes | None: ...

    def exists(self, key: str) -> bool: ...

    def evict(self, key: str) -> None: ...

    def close(self) -> None: ...


# ---------------------------------------------------------------------------
# Optional-method dispatch helpers.  Higher layers call these instead of the
# connector directly so that any object satisfying the minimal bytes-only
# protocol keeps working, while native connectors get the fast paths.
# ---------------------------------------------------------------------------


def put_payload(connector: Connector, key: str, parts: Sequence) -> int:
    """Put a framed-parts payload; returns the wire size in bytes.

    Vectored (no join copy) when the connector implements ``put_parts``;
    otherwise the parts are flattened once and handed to plain ``put``.
    """
    put_parts = getattr(connector, "put_parts", None)
    if put_parts is not None:
        return put_parts(key, parts)
    data = join_parts(parts)
    connector.put(key, data)
    return len(data)


def put_batch_payloads(
    connector: Connector, items: Sequence[tuple[str, Sequence]]
) -> int:
    """Put many ``(key, parts)`` payloads; returns total wire bytes."""
    put_batch = getattr(connector, "put_batch", None)
    if put_batch is not None:
        return put_batch(items)
    return sum(put_payload(connector, key, parts) for key, parts in items)


def get_view(connector: Connector, key: str) -> memoryview | None:
    """Read a payload as a memoryview (zero-copy where the channel allows)."""
    gv = getattr(connector, "get_view", None)
    if gv is not None:
        return gv(key)
    data = connector.get(key)
    return None if data is None else memoryview(data)


def get_payload(connector: Connector, key: str):
    """Read a payload in its cheapest native form.

    Returns a framed *parts* tuple when the connector stores parts
    (``get_parts``: the fully zero-copy in-memory path — no join ever
    happens), else a memoryview via ``get_view``, else ``None`` when the
    key is missing.  ``framing.decode`` accepts both forms.
    """
    gp = getattr(connector, "get_parts", None)
    if gp is not None:
        parts = gp(key)
        if parts is not None:
            return parts
        return None
    return get_view(connector, key)


def put_payload_new(connector: Connector, key: str, parts: Sequence) -> int | None:
    """Atomic put-if-absent of a framed-parts payload.

    Returns the wire size on success, ``None`` when ``key`` already exists.
    Native connectors implement ``put_parts_new`` atomically (dict setdefault,
    ``link(2)``, shm ``O_EXCL`` create); the generic fallback is a non-atomic
    exists-then-put (documented: last resort for bytes-only connectors).
    """
    ppn = getattr(connector, "put_parts_new", None)
    if ppn is not None:
        return ppn(key, parts)
    pn = getattr(connector, "put_new", None)
    if pn is not None:
        data = join_parts(parts)
        return len(data) if pn(key, data) else None
    if connector.exists(key):
        return None
    return put_payload(connector, key, parts)


def wait_for(
    connector: Connector,
    key: str,
    timeout: float | None = None,
    poll_min: float = 1e-4,
    poll_max: float = 0.01,
) -> None:
    """Block until ``key`` exists in the channel.

    Dispatches to the connector's native ``wait_for`` (notification-based:
    condition variables, directory watches, segment watches) when present;
    otherwise falls back to an exponential-backoff existence poll.  Raises
    ``TimeoutError`` when the deadline passes first.
    """
    wf = getattr(connector, "wait_for", None)
    if wf is not None:
        wf(key, timeout)
        return
    deadline = None if timeout is None else time.monotonic() + timeout
    delay = poll_min
    # documented fallback for connectors without native waits: bounded
    # exponential backoff, not the protocol path.  Each sleep is clamped to
    # the remaining budget so the wait can never overshoot the deadline by
    # a whole backoff interval.
    while not connector.exists(key):  # proxylint: disable=connector-wait-protocol
        if deadline is None:
            time.sleep(delay)  # proxylint: disable=no-sleep-poll
        else:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(f"key {key!r} not set within {timeout}s")
            time.sleep(min(delay, remaining))  # proxylint: disable=no-sleep-poll
        delay = min(delay * 2.0, poll_max)


def wait_for_any(
    connector: Connector,
    keys: Sequence[str],
    timeout: float | None = None,
    poll_min: float = 1e-4,
    poll_max: float = 0.01,
) -> str:
    """Block until *some* key in ``keys`` exists; returns the first ready one.

    One multi-key wait (a single condition sleep / directory watch covers
    every key), not N sequential single-key waits — the ``wait_all`` barrier
    over futures is built on this.

    The duck-typed fallback shares ONE deadline and ONE backoff across the
    whole key set: every iteration sweeps all keys, then sleeps once, with
    the sleep clamped to the remaining budget.  Per-key sequential waits
    would overshoot ``timeout`` by up to N×backoff and starve keys late in
    the list — pinned by the timeout-semantics conformance test.
    """
    keys = list(keys)
    if not keys:
        raise ValueError("wait_for_any requires at least one key")
    wfa = getattr(connector, "wait_for_any", None)
    if wfa is not None:
        return wfa(keys, timeout)
    deadline = None if timeout is None else time.monotonic() + timeout
    delay = poll_min
    while True:
        for k in keys:
            if connector.exists(k):
                return k
        # documented fallback backoff (see wait_for above); one clamped
        # sleep per whole-set sweep
        if deadline is None:
            time.sleep(delay)  # proxylint: disable=no-sleep-poll
        else:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"none of {len(keys)} keys set within {timeout}s"
                )
            time.sleep(min(delay, remaining))  # proxylint: disable=no-sleep-poll
        delay = min(delay * 2.0, poll_max)


def _watch_dir(
    directory: str,
    ready,
    timeout: float | None,
    what: str,
    poll_min: float = 5e-5,
    poll_max: float = 0.01,
):
    """Wait until ``ready()`` returns truthy, watching ``directory`` for
    change.

    A directory's (mtime_ns, size) signature changes whenever an entry is
    created, renamed in, or removed — one ``stat(2)`` covers every key in
    the channel.  While the signature is stable we back off exponentially;
    any change re-checks immediately and resets the backoff, so wake-up
    latency tracks filesystem timestamp granularity instead of a fixed
    polling interval.
    """
    deadline = None if timeout is None else time.monotonic() + timeout
    delay = poll_min
    last_sig = None
    first = True
    while True:
        hit = ready()
        if hit:
            return hit
        try:
            st = os.stat(directory)
            sig = (st.st_mtime_ns, st.st_size)
        except FileNotFoundError:
            sig = None
        changed = sig != last_sig
        last_sig = sig
        if changed:
            delay = poll_min  # activity: re-check soon, backoff resets
        # The deadline is checked every iteration — continuous churn from
        # unrelated keys must not starve the timeout (or pin a CPU).
        if deadline is not None and time.monotonic() > deadline:
            raise TimeoutError(f"{what} not set within {timeout}s")
        if changed and first:
            first = False
            continue  # first signature read: re-check ready() immediately
        # directory-watch backoff: adaptive, bounded by poll_max
        time.sleep(delay)  # proxylint: disable=no-sleep-poll
        if not changed:
            delay = min(delay * 2.0, poll_max)


class InMemoryConnector:
    """Thread-shared in-process object store (the 'Redis' of one process).

    Class-level registry keyed by namespace so that factories reconstructed
    from pickles within the same process find the same storage.
    """

    _registry: dict[str, dict[str, bytes]] = {}
    # namespace → (condition, waiter-count cell) shared by every connector
    # instance attached to the namespace, so a put in one instance wakes
    # blocked waits in another (same mediated channel).
    _conds: dict[str, tuple[threading.Condition, list]] = {}
    _lock = threading.Lock()

    def __init__(self, namespace: str | None = None):
        self.namespace = namespace or new_key()
        with InMemoryConnector._lock:
            InMemoryConnector._registry.setdefault(self.namespace, {})
            self._cond, self._waiters = InMemoryConnector._conds.setdefault(
                self.namespace, (threading.Condition(), [0])
            )

    @property
    def _store(self) -> dict[str, bytes]:
        return InMemoryConnector._registry.setdefault(self.namespace, {})

    def put(self, key: str, data: bytes) -> None:
        self._store[key] = data
        # Waiter-count guard keeps the no-waiter hot path lock-free; the
        # GIL orders the dict write before the count read, and a waiter
        # re-checks the dict under the condition before sleeping, so a
        # wake-up can never be lost.
        if self._waiters[0]:
            with self._cond:
                self._cond.notify_all()

    def put_parts(self, key: str, parts: Sequence) -> int:
        """Zero-copy vectored put: store the parts tuple itself.

        The dominant payload (framed array) is ``[header, memoryview]``
        where the memoryview aliases the producer's buffer — an in-process
        channel is the process heap, so a put is pass-by-reference: O(1)
        in payload size, no join copy, no allocation churn.  Consequence
        (documented, mirrors the shm write-once caveat): a producer must
        treat array payloads as frozen after ``put`` — resolves alias its
        memory until the key is evicted *and* resolved views die.  Callers
        needing snapshot semantics use plain ``put(key, bytes)``.
        """
        entry = tuple(parts)
        self._store[key] = entry
        if self._waiters[0]:
            with self._cond:
                self._cond.notify_all()
        return parts_nbytes(entry)

    def get_parts(self, key: str):
        """Payload as a framed-parts tuple (zero-copy; see ``put_parts``)."""
        data = self._store.get(key)
        if data is None:
            return None
        return data if isinstance(data, tuple) else (memoryview(data),)

    def put_new(self, key: str, data: bytes) -> bool:
        """Atomic put-if-absent (dict setdefault is atomic under the GIL).

        The entry is wrapped in a fresh 1-tuple so the insertion-identity
        check can never be fooled by interned payloads (``b""`` is a
        singleton: two racing setters would otherwise both claim the win).
        """
        entry = (data,)
        if self._store.setdefault(key, entry) is not entry:
            return False
        if self._waiters[0]:
            with self._cond:
                self._cond.notify_all()
        return True

    def wait_for(self, key: str, timeout: float | None = None) -> None:
        store = self._store
        if key in store:  # fast path: no lock when already present
            return
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            self._waiters[0] += 1
            try:
                while key not in store:
                    remaining = (
                        None if deadline is None else deadline - time.monotonic()
                    )
                    if remaining is not None and remaining <= 0:
                        raise TimeoutError(f"key {key!r} not set within {timeout}s")
                    self._cond.wait(remaining)
            finally:
                self._waiters[0] -= 1

    def wait_for_any(self, keys: Sequence[str], timeout: float | None = None) -> str:
        store = self._store
        for k in keys:
            if k in store:
                return k
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            self._waiters[0] += 1
            try:
                while True:
                    for k in keys:
                        if k in store:
                            return k
                    remaining = (
                        None if deadline is None else deadline - time.monotonic()
                    )
                    if remaining is not None and remaining <= 0:
                        raise TimeoutError(
                            f"none of {len(keys)} keys set within {timeout}s"
                        )
                    self._cond.wait(remaining)
            finally:
                self._waiters[0] -= 1

    def get(self, key: str) -> bytes | None:
        data = self._store.get(key)
        if data is None or not isinstance(data, tuple):
            return data
        return join_parts(data)  # parts entry: join on demand (bytes copy)

    def get_view(self, key: str) -> memoryview | None:
        data = self._store.get(key)
        if data is None:
            return None
        if isinstance(data, tuple):
            # contiguous view of a parts entry: one join copy (only paid by
            # custom-codec reads; the default resolve path uses get_parts)
            data = join_parts(data)
        return memoryview(data)

    def exists(self, key: str) -> bool:
        return key in self._store

    def evict(self, key: str) -> None:
        self._store.pop(key, None)

    def keys(self) -> Iterable[str]:
        return list(self._store.keys())

    def close(self) -> None:
        with InMemoryConnector._lock:
            InMemoryConnector._registry.pop(self.namespace, None)
            InMemoryConnector._conds.pop(self.namespace, None)

    # picklable: same namespace reattaches in-process; this mirrors the
    # paper's connectors whose pickled form carries server address info.
    def __reduce__(self):
        return (InMemoryConnector, (self.namespace,))


class FileConnector:
    """Filesystem-mediated channel (cross-process, survives restarts).

    Writes are atomic (tmp + rename) so a concurrent ``get``/``exists``
    never observes a partial object — required by the polling resolution
    of distributed futures.
    """

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.directory, key)

    def put(self, key: str, data: bytes) -> None:
        self.put_parts(key, (data,))

    def _write_one(self, key: str, parts: Sequence, *, fsync: bool) -> int:
        tmp = self._path(key) + f".tmp.{os.getpid()}.{threading.get_ident()}"
        total = 0
        with open(tmp, "wb") as f:
            # writev-style: each framed part streams to the page cache
            # directly; the payload is never joined in user space.
            for part in parts:
                total += f.write(part)
            f.flush()
            if fsync:
                os.fsync(f.fileno())
        os.replace(tmp, self._path(key))
        return total

    def put_parts(self, key: str, parts: Sequence) -> int:
        return self._write_one(key, parts, fsync=True)

    def put_batch(self, items: Sequence[tuple[str, Sequence]]) -> int:
        """Batched multi-object put: one durability point per BATCH.

        Every object still lands via its own tmp-write + atomic rename —
        a concurrent ``get``/``exists`` never observes a partial object —
        but the per-object ``fsync`` is replaced by a single directory
        fsync after the last rename.  A crash can lose the tail of an
        unflushed batch (callers treat a batch as one unit of progress);
        it can never expose a torn object.  For stream payload batches
        this turns N storage flushes into one."""
        total = sum(
            self._write_one(key, parts, fsync=False) for key, parts in items
        )
        if items:
            self._sync_dir()
        return total

    def _sync_dir(self) -> None:
        fd = os.open(self.directory, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def put_parts_new(self, key: str, parts: Sequence) -> int | None:
        """Atomic put-if-absent: ``link(2)`` the temp file into place.

        Unlike ``rename``, ``link`` fails with EEXIST when the target is
        already present — the kernel arbitrates racing producers.
        """
        final = self._path(key)
        if os.path.exists(final):
            return None  # cheap pre-check; the link below is the arbiter
        tmp = final + f".tmp.{os.getpid()}.{threading.get_ident()}"
        total = 0
        with open(tmp, "wb") as f:
            for part in parts:
                total += f.write(part)
            f.flush()
            os.fsync(f.fileno())
        try:
            os.link(tmp, final)
        except FileExistsError:
            return None
        finally:
            os.remove(tmp)
        return total

    def wait_for(self, key: str, timeout: float | None = None) -> None:
        path = self._path(key)
        if os.path.exists(path):
            return
        _watch_dir(
            self.directory, lambda: os.path.exists(path), timeout, f"key {key!r}"
        )

    def wait_for_any(self, keys: Sequence[str], timeout: float | None = None) -> str:
        # One directory listing per wake, not one stat(2) per candidate:
        # with wide key sets (futures wait_all barriers) the per-key
        # os.path.exists probe was an O(N) stat storm on every directory
        # event.  The listing is a snapshot of the same rename-published
        # namespace, so membership is exactly the exists() answer.
        def ready():
            try:
                present = set(os.listdir(self.directory))
            except FileNotFoundError:
                return None
            for k in keys:
                if k in present:
                    return k
            return None

        return _watch_dir(
            self.directory, ready, timeout, f"any of {len(keys)} keys"
        )

    def get(self, key: str) -> bytes | None:
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            return None

    def get_view(self, key: str) -> memoryview | None:
        import mmap

        try:
            f = open(self._path(key), "rb")
        except FileNotFoundError:
            return None
        with f:
            if os.fstat(f.fileno()).st_size == 0:
                return memoryview(b"")
            # The returned memoryview keeps the mapping alive; closing the
            # fd here is safe (POSIX mappings outlive their descriptor), and
            # an evict/unlink while mapped is equally safe on Linux.
            mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
        return memoryview(mm)

    def exists(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def evict(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def keys(self) -> Iterable[str]:
        return [k for k in os.listdir(self.directory) if ".tmp." not in k]

    def close(self) -> None:
        pass

    def __reduce__(self):
        return (FileConnector, (self.directory,))


class SharedMemoryConnector:
    """POSIX shared-memory channel: cross-process without filesystem I/O.

    Each object gets its own ``multiprocessing.shared_memory`` segment named
    ``psx_<namespace>_<key>``; an index is not needed because keys are
    content-addressed by the caller (Store).  This is the high-bandwidth
    'UCX-like' transport of the single-node setting.

    Commit protocol: the 8-byte length header stores ``total + 1`` and is
    written *after* the payload bytes (x86-TSO store order makes this
    visible cross-process in order).  A zero header means "created but not
    yet published" — readers and the segment watch treat it as absent, so
    a notification-latency wake between ``shm_open`` and the payload write
    can never observe a torn or empty object.

    Overwriting an existing key reuses the segment in place when the new
    payload fits — unless *this process* holds live zero-copy views of it
    (then the segment is replaced and the old mapping stays valid until the
    views die).  The guard cannot see other processes' views: treat keys as
    write-once across processes, or evict before re-putting.

    Attach amortization: ``get``/``exists`` keep a per-process cache of
    read-only attachments keyed by segment name + *generation* (the
    /dev/shm inode), so the polling hot paths pay one shm_open + mmap per
    segment lifetime instead of one per call.  A cheap stat validates the
    generation on every hit: an evict-and-recreate under the same name (by
    any process) changes the inode and forces a re-attach, and a local
    evict or put-side replacement drops the entry eagerly.  The cache
    never exports views (``get`` copies; ``get_view`` has its own retained
    mappings), so dropping an entry is always just an munmap.
    """

    _live: "weakref.WeakSet[SharedMemoryConnector]" = None  # type: ignore[assignment]

    def __init__(self, namespace: str | None = None):
        # uuid4, not new_key(): new_key's per-process prefix would collapse
        # every default-namespaced connector onto the same 12 chars
        self.namespace = (namespace or uuid.uuid4().hex)[:12]
        # Segments with exported zero-copy views (get_view); kept mapped
        # until evict/close so resolved arrays never dangle.  The lock keeps
        # a concurrent get_view append from being lost by a reap's rebuild
        # (which would disarm the in-place-overwrite guard).
        self._retained: list = []
        self._retained_lock = threading.Lock()
        # Attach cache: key -> (SharedMemory, /dev/shm inode).  Read-only,
        # never exports views (get copies under the lock), dropped on local
        # evict/replace and on inode change (cross-process generation bump).
        self._attached: dict = {}
        self._attached_lock = threading.Lock()
        if SharedMemoryConnector._live is None:
            import atexit
            import weakref

            SharedMemoryConnector._live = weakref.WeakSet()
            atexit.register(SharedMemoryConnector._atexit_disarm)
        SharedMemoryConnector._live.add(self)

    @classmethod
    def _atexit_disarm(cls) -> None:
        # At interpreter exit, resolved arrays may still alias retained
        # mappings; SharedMemory.__del__ would spam BufferError.  Disarm the
        # close and let the OS unmap on process teardown.
        for conn in list(cls._live or ()):
            for _, seg in conn._retained:
                try:
                    seg.close()
                except BufferError:
                    seg.close = lambda: None

    def _name(self, key: str) -> str:
        # shm names have tight length limits on some platforms
        return f"psx{self.namespace}{key[:32]}"

    def put(self, key: str, data: bytes) -> None:
        self.put_parts(key, (data,))

    def put_parts(self, key: str, parts: Sequence) -> int:
        from multiprocessing import shared_memory

        name = self._name(key)
        total = parts_nbytes(parts)
        size = max(total, 1) + 8
        try:
            seg = shared_memory.SharedMemory(name=name, create=True, size=size)
        except FileExistsError:
            seg = shared_memory.SharedMemory(name=name)
            if seg.size < size or self._has_retained(key):
                # Replace the segment when it's too small — or when resolved
                # arrays in this process still alias it (overwriting in place
                # would mutate results already handed to user code; the old
                # mapping stays valid until those views die).
                seg.unlink()
                seg.close()
                self._drop_attached(key)  # new generation under the same name
                seg = shared_memory.SharedMemory(name=name, create=True, size=size)
            # else: resize-safe reuse — overwrite in place (the length
            # header below masks any trailing stale bytes; a cached reader
            # attachment maps the same inode, so it stays valid)
        try:
            seg.buf[:8] = bytes(8)  # mark unready while the body is written
            off = 8
            for part in parts:
                n = part.nbytes if isinstance(part, memoryview) else len(part)
                seg.buf[off : off + n] = part
                off += n
            seg.buf[:8] = (total + 1).to_bytes(8, "little")  # publish last
        finally:
            seg.close()
        return total

    def put_batch(self, items: Sequence[tuple[str, Sequence]]) -> int:
        return sum(self.put_parts(key, parts) for key, parts in items)

    def put_parts_new(self, key: str, parts: Sequence) -> int | None:
        """Atomic put-if-absent: shm segments are created ``O_EXCL``."""
        from multiprocessing import shared_memory

        total = parts_nbytes(parts)
        try:
            seg = shared_memory.SharedMemory(
                name=self._name(key), create=True, size=max(total, 1) + 8
            )
        except FileExistsError:
            return None
        try:
            off = 8
            for part in parts:
                n = part.nbytes if isinstance(part, memoryview) else len(part)
                seg.buf[off : off + n] = part
                off += n
            seg.buf[:8] = (total + 1).to_bytes(8, "little")  # publish last
        except BaseException:
            # A half-written exclusive segment must not survive: retries
            # would hit FileExistsError (None → "already set") while the
            # zero header keeps readers waiting forever — the wedged-key
            # state.  Unlink so the key is cleanly absent again.
            try:
                seg.unlink()
            except Exception:  # proxylint: disable=swallowed-error
                pass  # best-effort cleanup; the original error re-raises below
            raise
        finally:
            seg.close()
        return total

    def _seg_ready(self, key: str):
        # Segment watch: a segment is *ready* once its commit header is
        # nonzero — existence alone would wake a reader into the
        # create→write window.  On Linux POSIX shm is a /dev/shm file, so
        # the header check is one open+read, no map/unmap round trip.
        path = os.path.join("/dev/shm", self._name(key))
        if os.path.isdir("/dev/shm"):
            try:
                with open(path, "rb") as f:
                    head = f.read(8)
            except FileNotFoundError:
                return False
            return len(head) == 8 and head != bytes(8)
        return self.exists(key)

    def wait_for(self, key: str, timeout: float | None = None) -> None:
        if self._seg_ready(key):
            return
        # When /dev/shm is absent, _watch_dir degrades to the plain
        # adaptive-backoff poll (a missing watch dir never changes signature).
        _watch_dir("/dev/shm", lambda: self._seg_ready(key), timeout, f"key {key!r}")

    def wait_for_any(self, keys: Sequence[str], timeout: float | None = None) -> str:
        def ready():
            for k in keys:
                if self._seg_ready(k):
                    return k
            return None

        return _watch_dir("/dev/shm", ready, timeout, f"any of {len(keys)} keys")

    def _drop_attached(self, key: str) -> None:
        with self._attached_lock:
            ent = self._attached.pop(key, None)
            if ent is not None:
                try:
                    ent[0].close()
                except BufferError:  # pragma: no cover - cache exports no views
                    pass

    def _read_cached(self, key: str, reader):
        """Run ``reader(segment)`` against the cached read-only attachment.

        A stat of the /dev/shm inode validates the cached generation on
        every call (an evict-and-recreate under the same name changes it);
        the read runs under the cache lock so a concurrent local evict
        can't unmap the segment mid-read.  Without /dev/shm there is no
        generation witness, so the call degrades to attach-read-detach.
        """
        from multiprocessing import shared_memory

        name = self._name(key)
        if not os.path.isdir("/dev/shm"):  # pragma: no cover - non-Linux
            try:
                seg = shared_memory.SharedMemory(name=name)
            except FileNotFoundError:
                return None
            try:
                return reader(seg)
            finally:
                seg.close()
        try:
            ino = os.stat(os.path.join("/dev/shm", name)).st_ino
        except FileNotFoundError:
            self._drop_attached(key)
            return None
        with self._attached_lock:
            ent = self._attached.get(key)
            if ent is None or ent[1] != ino:
                if ent is not None:
                    ent[0].close()  # stale generation; cache exports no views
                try:
                    seg = shared_memory.SharedMemory(name=name)
                except FileNotFoundError:
                    self._attached.pop(key, None)
                    return None
                ent = (seg, ino)
                self._attached[key] = ent
            return reader(ent[0])

    def get(self, key: str) -> bytes | None:
        def read(seg):
            h = int.from_bytes(bytes(seg.buf[:8]), "little")
            if h == 0:
                return None  # created but not yet published
            return bytes(seg.buf[8 : 8 + h - 1])

        return self._read_cached(key, read)

    def get_view(self, key: str) -> memoryview | None:
        from multiprocessing import shared_memory

        try:
            seg = shared_memory.SharedMemory(name=self._name(key))
        except FileNotFoundError:
            return None
        h = int.from_bytes(bytes(seg.buf[:8]), "little")
        if h == 0:  # created but not yet published
            seg.close()
            return None
        # read-only: a plain resolve must not be able to scribble on the
        # shared segment (mutators get private copies via decode(writable=))
        view = seg.buf[8 : 8 + h - 1].toreadonly()
        with self._retained_lock:
            self._retained.append((key, seg))
        self._reap_retained(limit=64)
        return view

    def _reap_retained(self, limit: int = 0) -> None:
        # Close mappings whose exported views have been garbage-collected;
        # ones still referenced by live resolved objects raise BufferError
        # and stay mapped.
        with self._retained_lock:
            if len(self._retained) <= limit:
                return
            still = []
            for key, seg in self._retained:
                try:
                    seg.close()
                except BufferError:
                    still.append((key, seg))
            self._retained = still

    def _has_retained(self, key: str) -> bool:
        with self._retained_lock:
            if not any(k == key for k, _ in self._retained):
                return False
        self._reap_retained()  # drop dead views before deciding
        with self._retained_lock:
            return any(k == key for k, _ in self._retained)

    def exists(self, key: str) -> bool:
        # unpublished segments are invisible (commit protocol above)
        return bool(
            self._read_cached(key, lambda seg: bytes(seg.buf[:8]) != bytes(8))
        )

    def evict(self, key: str) -> None:
        from multiprocessing import shared_memory

        self._drop_attached(key)
        try:
            seg = shared_memory.SharedMemory(name=self._name(key))
        except FileNotFoundError:
            return
        seg.close()
        try:
            seg.unlink()
        except FileNotFoundError:
            pass
        self._reap_retained()

    def close(self) -> None:
        with self._attached_lock:
            for seg, _ in self._attached.values():
                try:
                    seg.close()
                except BufferError:  # pragma: no cover - cache exports no views
                    pass
            self._attached.clear()
        self._reap_retained()

    def __reduce__(self):
        return (SharedMemoryConnector, (self.namespace,))


def _wait_then_read(connector, key, timeout, poll_min, poll_max, getter):
    """Shared wait-then-read loop: :func:`wait_for` the key, read it with
    ``getter``, and re-wait if an evict raced the wake-up."""
    deadline = None if timeout is None else time.monotonic() + timeout
    while True:
        remaining = None if deadline is None else max(0.0, deadline - time.monotonic())
        wait_for(connector, key, remaining if timeout is not None else None,
                 poll_min, poll_max)
        payload = getter(connector, key)
        if payload is not None:
            return payload
        if deadline is not None and time.monotonic() > deadline:
            raise TimeoutError(f"future target {key!r} not set within {timeout}s")


def wait_for_key(
    connector: Connector,
    key: str,
    timeout: float | None = None,
    poll_min: float = 1e-4,
    poll_max: float = 0.01,
) -> bytes:
    """Block until ``key`` exists in the channel and return its payload.

    This is the mediated-channel analogue of `Future.result()` used by
    ProxyFuture resolution (paper §IV-A): producer and consumer synchronize
    *through the store*, never through engine-specific primitives.  The wait
    is notification-driven via :func:`wait_for` (condition variables in
    memory, directory/segment watches cross-process); the read is retried in
    case an evict races the wake-up.
    """
    return _wait_then_read(connector, key, timeout, poll_min, poll_max,
                           lambda c, k: c.get(k))


def wait_for_view(
    connector: Connector,
    key: str,
    timeout: float | None = None,
    poll_min: float = 1e-4,
    poll_max: float = 0.01,
) -> memoryview:
    """Like :func:`wait_for_key` but returns a zero-copy view of the payload."""
    return _wait_then_read(connector, key, timeout, poll_min, poll_max, get_view)


def wait_for_payload(
    connector: Connector,
    key: str,
    timeout: float | None = None,
    poll_min: float = 1e-4,
    poll_max: float = 0.01,
):
    """Like :func:`wait_for_view` but in the connector's cheapest native
    form (parts tuple or memoryview — see :func:`get_payload`)."""
    return _wait_then_read(connector, key, timeout, poll_min, poll_max,
                           get_payload)
