"""Mediated communication channels (paper §III: *connector* protocol).

A connector is the low-level interface to a mediated channel — producer and
consumer communicate indirectly through it, so they need not be alive at the
same time.  The paper ships Redis/KeyDB/Globus/UCX/Margo connectors; on this
single-node container we provide:

- :class:`InMemoryConnector` — dict-backed, zero-copy, thread-shared.
- :class:`FileConnector`     — directory-backed, cross-process, persistent.
- :class:`SharedMemoryConnector` — POSIX shm backed, cross-process, fast.

All satisfy the :class:`Connector` protocol so higher layers (Store, streams,
futures, ownership) are transport-agnostic, exactly as in the paper.

Hot-path extensions (all optional; duck-typed with protocol-level fallbacks
via :func:`put_payload` / :func:`put_batch_payloads` / :func:`get_view`):

- ``put_parts(key, parts)`` — vectored put of a framed-parts payload, so the
  connector writes header + raw buffers without a join copy;
- ``put_batch(items)``      — amortized multi-object put (stream batches);
- ``get_view(key)``         — zero-copy read: a memoryview over channel
  memory (dict bytes, shm segment, mmap'd file) instead of a bytes copy.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
import uuid
from typing import Iterable, Protocol, Sequence, runtime_checkable

from repro.core.framing import join_parts, parts_nbytes


# Key generation sits on the put hot path; uuid4 costs a getrandom syscall
# per key (tens of µs on older kernels), so draw entropy once per process
# and append a monotonic counter.  Forked children re-seed their prefix.
_KEY_STATE = {"prefix": uuid.uuid4().hex[:16], "count": itertools.count()}


def _reseed_key_prefix() -> None:
    _KEY_STATE["prefix"] = uuid.uuid4().hex[:16]
    _KEY_STATE["count"] = itertools.count()


if hasattr(os, "register_at_fork"):
    os.register_at_fork(after_in_child=_reseed_key_prefix)


def new_key() -> str:
    return f"{_KEY_STATE['prefix']}{next(_KEY_STATE['count']):012x}"


@runtime_checkable
class Connector(Protocol):
    """Low-level mediated-channel interface."""

    def put(self, key: str, data: bytes) -> None: ...

    def get(self, key: str) -> bytes | None: ...

    def exists(self, key: str) -> bool: ...

    def evict(self, key: str) -> None: ...

    def close(self) -> None: ...


# ---------------------------------------------------------------------------
# Optional-method dispatch helpers.  Higher layers call these instead of the
# connector directly so that any object satisfying the minimal bytes-only
# protocol keeps working, while native connectors get the fast paths.
# ---------------------------------------------------------------------------


def put_payload(connector: Connector, key: str, parts: Sequence) -> int:
    """Put a framed-parts payload; returns the wire size in bytes.

    Vectored (no join copy) when the connector implements ``put_parts``;
    otherwise the parts are flattened once and handed to plain ``put``.
    """
    put_parts = getattr(connector, "put_parts", None)
    if put_parts is not None:
        return put_parts(key, parts)
    data = join_parts(parts)
    connector.put(key, data)
    return len(data)


def put_batch_payloads(
    connector: Connector, items: Sequence[tuple[str, Sequence]]
) -> int:
    """Put many ``(key, parts)`` payloads; returns total wire bytes."""
    put_batch = getattr(connector, "put_batch", None)
    if put_batch is not None:
        return put_batch(items)
    return sum(put_payload(connector, key, parts) for key, parts in items)


def get_view(connector: Connector, key: str) -> memoryview | None:
    """Read a payload as a memoryview (zero-copy where the channel allows)."""
    gv = getattr(connector, "get_view", None)
    if gv is not None:
        return gv(key)
    data = connector.get(key)
    return None if data is None else memoryview(data)


class InMemoryConnector:
    """Thread-shared in-process object store (the 'Redis' of one process).

    Class-level registry keyed by namespace so that factories reconstructed
    from pickles within the same process find the same storage.
    """

    _registry: dict[str, dict[str, bytes]] = {}
    _lock = threading.Lock()

    def __init__(self, namespace: str | None = None):
        self.namespace = namespace or new_key()
        with InMemoryConnector._lock:
            InMemoryConnector._registry.setdefault(self.namespace, {})

    @property
    def _store(self) -> dict[str, bytes]:
        return InMemoryConnector._registry.setdefault(self.namespace, {})

    def put(self, key: str, data: bytes) -> None:
        self._store[key] = data

    # no put_parts/put_batch here: the generic fallbacks (join once into an
    # immutable bytes snapshot, then plain put) are already optimal for a
    # dict-backed channel; get_view over the stored bytes is zero-copy.

    def get(self, key: str) -> bytes | None:
        return self._store.get(key)

    def get_view(self, key: str) -> memoryview | None:
        data = self._store.get(key)
        return None if data is None else memoryview(data)

    def exists(self, key: str) -> bool:
        return key in self._store

    def evict(self, key: str) -> None:
        self._store.pop(key, None)

    def keys(self) -> Iterable[str]:
        return list(self._store.keys())

    def close(self) -> None:
        with InMemoryConnector._lock:
            InMemoryConnector._registry.pop(self.namespace, None)

    # picklable: same namespace reattaches in-process; this mirrors the
    # paper's connectors whose pickled form carries server address info.
    def __reduce__(self):
        return (InMemoryConnector, (self.namespace,))


class FileConnector:
    """Filesystem-mediated channel (cross-process, survives restarts).

    Writes are atomic (tmp + rename) so a concurrent ``get``/``exists``
    never observes a partial object — required by the polling resolution
    of distributed futures.
    """

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.directory, key)

    def put(self, key: str, data: bytes) -> None:
        self.put_parts(key, (data,))

    def put_parts(self, key: str, parts: Sequence) -> int:
        tmp = self._path(key) + f".tmp.{os.getpid()}.{threading.get_ident()}"
        total = 0
        with open(tmp, "wb") as f:
            # writev-style: each framed part streams to the page cache
            # directly; the payload is never joined in user space.
            for part in parts:
                total += f.write(part)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._path(key))
        return total

    def put_batch(self, items: Sequence[tuple[str, Sequence]]) -> int:
        return sum(self.put_parts(key, parts) for key, parts in items)

    def get(self, key: str) -> bytes | None:
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            return None

    def get_view(self, key: str) -> memoryview | None:
        import mmap

        try:
            f = open(self._path(key), "rb")
        except FileNotFoundError:
            return None
        with f:
            if os.fstat(f.fileno()).st_size == 0:
                return memoryview(b"")
            # The returned memoryview keeps the mapping alive; closing the
            # fd here is safe (POSIX mappings outlive their descriptor), and
            # an evict/unlink while mapped is equally safe on Linux.
            mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
        return memoryview(mm)

    def exists(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def evict(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def keys(self) -> Iterable[str]:
        return [k for k in os.listdir(self.directory) if ".tmp." not in k]

    def close(self) -> None:
        pass

    def __reduce__(self):
        return (FileConnector, (self.directory,))


class SharedMemoryConnector:
    """POSIX shared-memory channel: cross-process without filesystem I/O.

    Each object gets its own ``multiprocessing.shared_memory`` segment named
    ``psx_<namespace>_<key>``; an index is not needed because keys are
    content-addressed by the caller (Store).  This is the high-bandwidth
    'UCX-like' transport of the single-node setting.

    Overwriting an existing key reuses the segment in place when the new
    payload fits — unless *this process* holds live zero-copy views of it
    (then the segment is replaced and the old mapping stays valid until the
    views die).  The guard cannot see other processes' views: treat keys as
    write-once across processes, or evict before re-putting.
    """

    _live: "weakref.WeakSet[SharedMemoryConnector]" = None  # type: ignore[assignment]

    def __init__(self, namespace: str | None = None):
        # uuid4, not new_key(): new_key's per-process prefix would collapse
        # every default-namespaced connector onto the same 12 chars
        self.namespace = (namespace or uuid.uuid4().hex)[:12]
        # Segments with exported zero-copy views (get_view); kept mapped
        # until evict/close so resolved arrays never dangle.  The lock keeps
        # a concurrent get_view append from being lost by a reap's rebuild
        # (which would disarm the in-place-overwrite guard).
        self._retained: list = []
        self._retained_lock = threading.Lock()
        if SharedMemoryConnector._live is None:
            import atexit
            import weakref

            SharedMemoryConnector._live = weakref.WeakSet()
            atexit.register(SharedMemoryConnector._atexit_disarm)
        SharedMemoryConnector._live.add(self)

    @classmethod
    def _atexit_disarm(cls) -> None:
        # At interpreter exit, resolved arrays may still alias retained
        # mappings; SharedMemory.__del__ would spam BufferError.  Disarm the
        # close and let the OS unmap on process teardown.
        for conn in list(cls._live or ()):
            for _, seg in conn._retained:
                try:
                    seg.close()
                except BufferError:
                    seg.close = lambda: None

    def _name(self, key: str) -> str:
        # shm names have tight length limits on some platforms
        return f"psx{self.namespace}{key[:32]}"

    def put(self, key: str, data: bytes) -> None:
        self.put_parts(key, (data,))

    def put_parts(self, key: str, parts: Sequence) -> int:
        from multiprocessing import shared_memory

        name = self._name(key)
        total = parts_nbytes(parts)
        size = max(total, 1) + 8
        try:
            seg = shared_memory.SharedMemory(name=name, create=True, size=size)
        except FileExistsError:
            seg = shared_memory.SharedMemory(name=name)
            if seg.size < size or self._has_retained(key):
                # Replace the segment when it's too small — or when resolved
                # arrays in this process still alias it (overwriting in place
                # would mutate results already handed to user code; the old
                # mapping stays valid until those views die).
                seg.unlink()
                seg.close()
                seg = shared_memory.SharedMemory(name=name, create=True, size=size)
            # else: resize-safe reuse — overwrite in place (the length
            # header below masks any trailing stale bytes)
        try:
            seg.buf[:8] = total.to_bytes(8, "little")
            off = 8
            for part in parts:
                n = part.nbytes if isinstance(part, memoryview) else len(part)
                seg.buf[off : off + n] = part
                off += n
        finally:
            seg.close()
        return total

    def put_batch(self, items: Sequence[tuple[str, Sequence]]) -> int:
        return sum(self.put_parts(key, parts) for key, parts in items)

    def get(self, key: str) -> bytes | None:
        from multiprocessing import shared_memory

        try:
            seg = shared_memory.SharedMemory(name=self._name(key))
        except FileNotFoundError:
            return None
        try:
            n = int.from_bytes(bytes(seg.buf[:8]), "little")
            return bytes(seg.buf[8 : 8 + n])
        finally:
            seg.close()

    def get_view(self, key: str) -> memoryview | None:
        from multiprocessing import shared_memory

        try:
            seg = shared_memory.SharedMemory(name=self._name(key))
        except FileNotFoundError:
            return None
        n = int.from_bytes(bytes(seg.buf[:8]), "little")
        # read-only: a plain resolve must not be able to scribble on the
        # shared segment (mutators get private copies via decode(writable=))
        view = seg.buf[8 : 8 + n].toreadonly()
        with self._retained_lock:
            self._retained.append((key, seg))
        self._reap_retained(limit=64)
        return view

    def _reap_retained(self, limit: int = 0) -> None:
        # Close mappings whose exported views have been garbage-collected;
        # ones still referenced by live resolved objects raise BufferError
        # and stay mapped.
        with self._retained_lock:
            if len(self._retained) <= limit:
                return
            still = []
            for key, seg in self._retained:
                try:
                    seg.close()
                except BufferError:
                    still.append((key, seg))
            self._retained = still

    def _has_retained(self, key: str) -> bool:
        with self._retained_lock:
            if not any(k == key for k, _ in self._retained):
                return False
        self._reap_retained()  # drop dead views before deciding
        with self._retained_lock:
            return any(k == key for k, _ in self._retained)

    def exists(self, key: str) -> bool:
        from multiprocessing import shared_memory

        try:
            seg = shared_memory.SharedMemory(name=self._name(key))
        except FileNotFoundError:
            return False
        seg.close()
        return True

    def evict(self, key: str) -> None:
        from multiprocessing import shared_memory

        try:
            seg = shared_memory.SharedMemory(name=self._name(key))
        except FileNotFoundError:
            return
        seg.close()
        try:
            seg.unlink()
        except FileNotFoundError:
            pass
        self._reap_retained()

    def close(self) -> None:
        self._reap_retained()

    def __reduce__(self):
        return (SharedMemoryConnector, (self.namespace,))


def wait_for_key(
    connector: Connector,
    key: str,
    timeout: float | None = None,
    poll_min: float = 1e-4,
    poll_max: float = 0.01,
) -> bytes:
    """Block until ``key`` exists in the channel, with exponential backoff.

    This is the mediated-channel analogue of `Future.result()` used by
    ProxyFuture resolution (paper §IV-A): producer and consumer synchronize
    *through the store*, never through engine-specific primitives.
    """
    deadline = None if timeout is None else time.monotonic() + timeout
    delay = poll_min
    while True:
        data = connector.get(key)
        if data is not None:
            return data
        if deadline is not None and time.monotonic() > deadline:
            raise TimeoutError(f"future target {key!r} not set within {timeout}s")
        time.sleep(delay)
        delay = min(delay * 2.0, poll_max)


def wait_for_view(
    connector: Connector,
    key: str,
    timeout: float | None = None,
    poll_min: float = 1e-4,
    poll_max: float = 0.01,
) -> memoryview:
    """Like :func:`wait_for_key` but returns a zero-copy view of the payload."""
    deadline = None if timeout is None else time.monotonic() + timeout
    delay = poll_min
    while True:
        view = get_view(connector, key)
        if view is not None:
            return view
        if deadline is not None and time.monotonic() > deadline:
            raise TimeoutError(f"future target {key!r} not set within {timeout}s")
        time.sleep(delay)
        delay = min(delay * 2.0, poll_max)
