"""Policy-routed tiered store: one connector over a priority-ordered stack.

ProxyStore's MultiConnector shape ("Accelerating Communications in
Federated Applications with Transparent Object Proxies"): every put is
routed by policy across a stack of backing connectors, and resolution is
transparent — the consumer never learns (or cares) which tier holds the
payload.

Routing rules, evaluated in order for each put:

1. **explicit pin** — :meth:`MultiConnector.pin` maps a key to a tier by
   name before the put lands;
2. **key tags** — ``#tag`` segments carried in the key (``"k123#bulk"``)
   route to the first tier whose ``tags`` intersect;
3. **size thresholds** — the first tier whose ``[min_bytes, max_bytes]``
   window admits the payload wins (tiny → in-memory, medium → shm, bulk →
   file/network);
4. **fallback** — nothing matched: the last tier takes it.

The winning tier is recorded in a per-process route map so a resolve goes
straight to the right backend; a miss (another process's put, a demotion
behind this process's back) falls through the stack in priority order and
re-records.  :meth:`demote` moves a payload to a colder tier in place —
the memory-pressure eviction hook (ROADMAP item 4): resolution after a
demotion transparently re-fetches from the colder tier.

Waits cover the whole stack: a key may land in any tier, so
``wait_for``/``wait_for_any`` park one watcher per tier in that tier's
native notification wait (sliced so losers exit promptly once a winner
reports) — wake-up latency is the winning tier's native latency, and
nothing polls.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Iterable, Sequence

from repro.core import connectors as _c
from repro.core.framing import parts_nbytes

# Watcher wait slice: losers notice the winner within one slice; the
# winner returns at its tier's native notification latency regardless.
_WAIT_SLICE_S = 0.05


@dataclass(frozen=True)
class Tier:
    """One level of the stack: a named connector plus its routing policy."""

    name: str
    connector: object
    min_bytes: int = 0
    max_bytes: int | None = None  # None: no upper bound
    tags: frozenset = field(default_factory=frozenset)

    def admits(self, size: int) -> bool:
        if size < self.min_bytes:
            return False
        return self.max_bytes is None or size <= self.max_bytes


def key_tags(key: str) -> frozenset:
    """Routing tags carried in the key itself (``"abc#bulk#ckpt"``)."""
    if "#" not in key:
        return frozenset()
    return frozenset(t for t in key.split("#")[1:] if t)


class MultiConnector:
    """Priority-ordered multi-tier connector (see module docstring).

    Satisfies the full optional-method table by delegating through the
    protocol helpers, so a tier may itself be a bytes-only connector and
    everything still works.
    """

    def __init__(self, tiers: Sequence[Tier]):
        if not tiers:
            raise ValueError("MultiConnector needs at least one tier")
        self.tiers = list(tiers)
        names = [t.name for t in self.tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        self._by_name = {t.name: t for t in self.tiers}
        # per-process hints; cross-process resolves fall through the stack
        self._routes: dict[str, str] = {}
        self._pins: dict[str, str] = {}
        self.channel_id = "+".join(
            _c.channel_identity(t.connector) for t in self.tiers
        )

    # -- routing ---------------------------------------------------------
    def pin(self, key: str, tier: str) -> None:
        """Route the next put of ``key`` to ``tier`` explicitly."""
        if tier not in self._by_name:
            raise KeyError(f"unknown tier {tier!r} (have {list(self._by_name)})")
        self._pins[key] = tier

    def route_for(self, key: str, size: int) -> Tier:
        """The tier a put of ``size`` bytes under ``key`` lands in."""
        pinned = self._pins.get(key)
        if pinned is not None:
            return self._by_name[pinned]
        tags = key_tags(key)
        if tags:
            for t in self.tiers:
                if t.tags & tags:
                    return t
        for t in self.tiers:
            if t.admits(size):
                return t
        return self.tiers[-1]

    def tier_of(self, key: str) -> str | None:
        """Name of the tier currently holding ``key`` (probing on miss)."""
        name = self._routes.get(key)
        if name is not None and self._by_name[name].connector.exists(key):
            return name
        for t in self.tiers:
            if t.connector.exists(key):
                self._routes[key] = t.name
                return t.name
        self._routes.pop(key, None)
        return None

    def _evict_elsewhere(self, key: str, keep: Tier) -> None:
        # An overwrite that re-routes (new size → new tier) must not leave
        # a stale copy where the old put landed: fall-through would serve
        # whichever tier is hotter, and that may be the stale one.
        old = self._routes.get(key)
        if old is not None and old != keep.name:
            self._by_name[old].connector.evict(key)

    # -- puts ------------------------------------------------------------
    def put(self, key: str, data: bytes) -> None:
        # bytes fast path: route on len() and hand the buffer straight to
        # the tier — no tuple wrap, no parts_nbytes sweep (the routed put
        # is the store hot path; see BENCH_proxy multi_route_overhead_ratio)
        tier = self.route_for(key, len(data))
        self._evict_elsewhere(key, tier)
        tier.connector.put(key, data)
        self._routes[key] = tier.name

    def put_parts(self, key: str, parts: Sequence) -> int:
        size = parts_nbytes(parts)
        tier = self.route_for(key, size)
        self._evict_elsewhere(key, tier)
        n = _c.put_payload(tier.connector, key, parts)
        self._routes[key] = tier.name
        return n

    def put_parts_new(self, key: str, parts: Sequence) -> int | None:
        """Put-if-absent, atomic *within the routed tier*.

        Racing writers of the same key route identically when their
        payloads route identically (the put_if_absent uses — future
        ``set_result``, loader shard commits — write identical values, so
        they do); the routed tier's native atomic op then arbitrates.  A
        cheap cross-tier exists() pre-check rejects keys already resident
        in a *different* tier.
        """
        size = parts_nbytes(parts)
        tier = self.route_for(key, size)
        for t in self.tiers:
            if t is not tier and t.connector.exists(key):
                return None
        n = _c.put_payload_new(tier.connector, key, parts)
        if n is not None:
            self._routes[key] = tier.name
        return n

    def put_batch(self, items: Sequence[tuple[str, Sequence]]) -> int:
        """One batched put per tier group (routing preserved per item)."""
        groups: dict[str, list] = {}
        for key, parts in items:
            tier = self.route_for(key, parts_nbytes(parts))
            self._evict_elsewhere(key, tier)
            groups.setdefault(tier.name, []).append((key, parts))
        total = 0
        for name, group in groups.items():
            total += _c.put_batch_payloads(self._by_name[name].connector, group)
            for key, _ in group:
                self._routes[key] = name
        return total

    # -- reads -----------------------------------------------------------
    def _tier_holding(self, key: str) -> Tier | None:
        name = self._routes.get(key)
        if name is not None:
            tier = self._by_name[name]
            if tier.connector.exists(key):
                return tier
            self._routes.pop(key, None)  # stale hint: fall through below
        for t in self.tiers:
            if t.connector.exists(key):
                self._routes[key] = t.name
                return t
        return None

    def get(self, key: str) -> bytes | None:
        name = self._routes.get(key)
        if name is not None:
            data = self._by_name[name].connector.get(key)
            if data is not None:
                return data
            self._routes.pop(key, None)
        for t in self.tiers:
            data = t.connector.get(key)
            if data is not None:
                self._routes[key] = t.name
                return data
        return None

    def get_parts(self, key: str):
        """Cheapest native payload of the holding tier (parts or view)."""
        name = self._routes.get(key)
        if name is not None:
            payload = _c.get_payload(self._by_name[name].connector, key)
            if payload is not None:
                return self._as_parts(payload)
            self._routes.pop(key, None)
        for t in self.tiers:
            payload = _c.get_payload(t.connector, key)
            if payload is not None:
                self._routes[key] = t.name
                return self._as_parts(payload)
        return None

    @staticmethod
    def _as_parts(payload):
        if isinstance(payload, (tuple, list)):
            return tuple(payload)
        return (payload,)

    def get_view(self, key: str) -> memoryview | None:
        name = self._routes.get(key)
        if name is not None:
            view = _c.get_view(self._by_name[name].connector, key)
            if view is not None:
                return view
            self._routes.pop(key, None)
        for t in self.tiers:
            view = _c.get_view(t.connector, key)
            if view is not None:
                self._routes[key] = t.name
                return view
        return None

    def exists(self, key: str) -> bool:
        return self._tier_holding(key) is not None

    def evict(self, key: str) -> None:
        # correctness over round trips: sweep every tier (a demote or a
        # cross-process re-route may have left the key off this process's
        # route map), then drop the hints
        for t in self.tiers:
            t.connector.evict(key)
        self._routes.pop(key, None)
        self._pins.pop(key, None)

    def keys(self) -> Iterable[str]:
        seen: dict[str, None] = {}
        for t in self.tiers:
            for k in getattr(t.connector, "keys", lambda: ())():
                seen.setdefault(k, None)
        return list(seen)

    # -- waits -----------------------------------------------------------
    def wait_for(self, key: str, timeout: float | None = None) -> None:
        self.wait_for_any([key], timeout)

    def wait_for_any(self, keys: Sequence[str], timeout: float | None = None) -> str:
        keys = list(keys)
        if not keys:
            raise ValueError("wait_for_any requires at least one key")
        if len(self.tiers) == 1:
            return _c.wait_for_any(self.tiers[0].connector, keys, timeout)
        # fast sweep before parking watchers
        for t in self.tiers:
            for k in keys:
                if t.connector.exists(k):
                    self._routes[k] = t.name
                    return k
        deadline = None if timeout is None else time.monotonic() + timeout
        done = threading.Event()
        won: list[tuple[str, str]] = []
        lock = threading.Lock()

        def watch(tier: Tier) -> None:
            while not done.is_set():
                if deadline is None:
                    slice_t = _WAIT_SLICE_S
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return
                    slice_t = min(_WAIT_SLICE_S, remaining)
                try:
                    k = _c.wait_for_any(tier.connector, keys, slice_t)
                except TimeoutError:
                    continue  # slice expired: re-check stop flag, re-park
                with lock:
                    if not won:
                        won.append((k, tier.name))
                done.set()
                return

        watchers = [
            threading.Thread(target=watch, args=(t,), daemon=True)
            for t in self.tiers
        ]
        for w in watchers:
            w.start()
        done.wait(timeout=None if timeout is None else timeout + _WAIT_SLICE_S)
        done.set()  # release losers promptly even on timeout
        with lock:
            if won:
                k, name = won[0]
                self._routes[k] = name
                return k
        raise TimeoutError(f"none of {len(keys)} keys set within {timeout}s")

    # -- demotion (ROADMAP item 4 hook) ----------------------------------
    def demote(self, key: str, to: str) -> bool:
        """Move ``key``'s payload to tier ``to`` (colder, usually).

        Write-through then evict: the payload is never absent from every
        tier at once, so a concurrent fall-through resolve always finds
        it.  Returns False when the key is resident nowhere.
        """
        target = self._by_name.get(to)
        if target is None:
            raise KeyError(f"unknown tier {to!r} (have {list(self._by_name)})")
        src = self._tier_holding(key)
        if src is None:
            return False
        if src.name == to:
            return True
        payload = _c.get_payload(src.connector, key)
        if payload is None:  # evicted under us
            return False
        # materialize: the target may keep parts by reference (InMemory),
        # and the source buffer dies when we evict it below
        parts = tuple(bytes(p) for p in self._as_parts(payload))
        del payload  # release any zero-copy view before evicting the source
        _c.put_payload(target.connector, key, parts)
        src.connector.evict(key)
        self._routes[key] = to
        return True

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        for t in self.tiers:
            t.connector.close()
        self._routes.clear()
        self._pins.clear()

    def __reduce__(self):
        # connectors are picklable channels; routes/pins are process-local
        # hints and deliberately not carried
        return (_rebuild, (self.tiers,))

    def __repr__(self):
        return (
            "MultiConnector("
            + " > ".join(f"{t.name}:{type(t.connector).__name__}" for t in self.tiers)
            + ")"
        )


def _rebuild(tiers):
    return MultiConnector(tiers)
