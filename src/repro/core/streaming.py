"""ProxyStream — object streaming with metadata/bulk decoupling (paper §IV-B).

``StreamProducer.send(topic, obj)`` (1) puts ``obj`` in the topic's Store,
(2) builds a small *event* carrying user metadata + object location, and
(3) publishes the event via a :class:`Publisher`.  A ``StreamConsumer``
iterates events from a :class:`Subscriber` and yields *proxies*: the bulk
bytes move only between the producer's store and whichever process finally
resolves the proxy — a dispatcher in between touches metadata only.

Brokers provided: in-process queue (Redis-pub/sub stand-in) and append-only
file log (Kafka stand-in, cross-process).  The Publisher/Subscriber
protocols mirror the paper so real Kafka/Redis/ZeroMQ shims would slot in.

Hot path:

- an in-process publisher that implements ``send_event_obj`` receives the
  event *dict itself* — one shared object fans out to every subscriber with
  no pickle round trip (events are read-only by contract);
- :class:`FileLogSubscriber` keeps a persistent handle on the topic log and
  drains every complete frame per ``read`` into an event buffer (one
  syscall for N events), waiting for new frames with a size watch instead
  of a fixed-interval reopen-and-sleep loop;
- ``StreamConsumer(prefetch=N)`` resolves bulk payloads ahead of iteration
  on a bounded background pipeline (backpressure at N in-flight), so
  consumer compute overlaps transport.
"""
from __future__ import annotations

import os
import pickle
import queue
import threading
import time
from collections import deque
from typing import Any, Callable, Iterator, Protocol, runtime_checkable

from repro.core import sanitize as _sanitize
from repro.core.proxy import Proxy, extract
from repro.core.store import Store, StoreFactory, invalidate_resolve_cache

_END = "__stream_end__"
_UNSET = object()  # sentinel: "use the consumer's constructor timeout"


@runtime_checkable
class Publisher(Protocol):
    def send_event(self, topic: str, event: bytes) -> None: ...

    def close(self) -> None: ...


@runtime_checkable
class Subscriber(Protocol):
    def next_event(self, timeout: float | None = None) -> bytes: ...

    def close(self) -> None: ...


def publish_event(publisher: Publisher, topic: str, event: dict) -> None:
    """Publish an event dict via the cheapest protocol the broker speaks.

    In-process brokers implementing ``send_event_obj`` get the dict itself
    (zero serialization, one shared object for every subscriber); byte
    brokers get a pickle.  Consumers must treat received events as
    read-only — the same dict may be visible to other subscribers.
    """
    seo = getattr(publisher, "send_event_obj", None)
    if seo is not None:
        seo(topic, event)
    else:
        publisher.send_event(topic, pickle.dumps(event))


def _load_event(raw) -> dict:
    return raw if isinstance(raw, dict) else pickle.loads(raw)


# ---------------------------------------------------------------------------
# In-process queue broker (fanout pub/sub)
# ---------------------------------------------------------------------------


class _QueueBroker:
    _registry: dict[str, "_QueueBroker"] = {}
    _lock = threading.Lock()

    def __init__(self):
        self.cond = threading.Condition()
        self.subscribers: dict[str, list[deque]] = {}

    @classmethod
    def instance(cls, namespace: str) -> "_QueueBroker":
        with cls._lock:
            if namespace not in cls._registry:
                cls._registry[namespace] = _QueueBroker()
            return cls._registry[namespace]

    def publish(self, topic: str, event) -> None:
        # Fanout enqueues the one event object (bytes or dict) into every
        # subscriber deque — per-subscriber copies never happen; consumers
        # treat events as read-only.
        with self.cond:
            for q in self.subscribers.get(topic, []):
                q.append(event)
            self.cond.notify_all()

    def subscribe(self, topic: str) -> deque:
        q: deque = deque()
        with self.cond:
            self.subscribers.setdefault(topic, []).append(q)
        return q

    def pop(self, q: deque, timeout: float | None):
        deadline = None if timeout is None else time.monotonic() + timeout
        with self.cond:
            while not q:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError("no stream event within timeout")
                self.cond.wait(remaining if remaining is not None else None)
            return q.popleft()


class QueuePublisher:
    """In-process pub/sub publisher (single-process benchmarks, threads)."""

    def __init__(self, namespace: str = "default"):
        self.namespace = namespace

    def send_event(self, topic: str, event: bytes) -> None:
        _QueueBroker.instance(self.namespace).publish(topic, event)

    def send_event_obj(self, topic: str, event: dict) -> None:
        """In-process fast path: fan the dict out unpickled (shared object)."""
        _QueueBroker.instance(self.namespace).publish(topic, event)

    def close(self) -> None:
        pass


class QueueSubscriber:
    def __init__(self, topic: str, namespace: str = "default"):
        self.namespace = namespace
        self.topic = topic
        self._broker = _QueueBroker.instance(namespace)
        self._q = self._broker.subscribe(topic)

    def next_event(self, timeout: float | None = None):
        return self._broker.pop(self._q, timeout)

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# File log broker (cross-process; Kafka stand-in)
# ---------------------------------------------------------------------------


class FileLogPublisher:
    """Append-only length-prefixed event log, one file per topic."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, topic: str) -> str:
        return os.path.join(self.directory, f"{topic}.log")

    def send_event(self, topic: str, event: bytes) -> None:
        frame = len(event).to_bytes(8, "little") + event
        # O_APPEND single-write frames are atomic enough for our single-node
        # multi-producer case (frames ≪ typical atomic append sizes); a real
        # deployment uses Kafka.
        fd = os.open(self._path(topic), os.O_WRONLY | os.O_CREAT | os.O_APPEND)
        try:
            os.write(fd, frame)
        finally:
            os.close(fd)

    def close(self) -> None:
        pass

    def __reduce__(self):
        return (FileLogPublisher, (self.directory,))


class FileLogSubscriber:
    """Tails a topic log from a given offset (default: beginning).

    Persistent-handle batched reader: one ``read()`` drains every byte
    appended since the last drain and parses all complete frames into an
    event buffer — one syscall for N events instead of an open/seek/read×2
    round per event.  Waiting for new frames is a file-size watch with
    adaptive backoff (wake latency tracks the producer, bounded by
    ``poll``), not a fixed 2 ms sleep.

    ``offset`` is the byte offset of the next *unconsumed* event: pickling
    the subscriber mid-stream resumes exactly after the last event returned
    (buffered-but-unreturned frames are re-read by the clone).
    """

    def __init__(self, topic: str, directory: str, poll: float = 0.002,
                 offset: int = 0):
        self.topic = topic
        self.directory = directory
        self.offset = offset
        self.poll = poll
        self._file = None
        self._tail = b""  # bytes read past the last complete frame
        self._read_pos = offset  # file position our reads have reached
        self._events: deque = deque()  # (payload, end_offset), parsed ahead

    def _path(self) -> str:
        return os.path.join(self.directory, f"{self.topic}.log")

    def _open(self) -> bool:
        if self._file is None:
            try:
                self._file = open(self._path(), "rb")
            except FileNotFoundError:
                return False
            self._file.seek(self.offset)
            self._read_pos = self.offset
            self._tail = b""
        return True

    # Per-drain read bound: one syscall still batches thousands of frames,
    # but a fresh subscriber attaching to a multi-GB topic log must not
    # materialize the whole tail in memory at once (next_event drains
    # chunk-by-chunk on demand).
    _DRAIN_CHUNK = 4 * 1024 * 1024

    def _drain(self) -> bool:
        """Read the next chunk of appended bytes; parse complete frames."""
        if not self._open():
            return bool(self._events)
        chunk = self._file.read(self._DRAIN_CHUNK)
        if chunk:
            self._read_pos += len(chunk)
            buf = self._tail + chunk if self._tail else chunk
            off, end = 0, len(buf)
            base = self._read_pos - end  # file offset of buf[0]
            events = self._events
            while end - off >= 8:
                n = int.from_bytes(buf[off : off + 8], "little")
                if end - off - 8 < n:
                    break  # incomplete frame: producer append in flight
                off += 8 + n
                events.append((buf[off - n : off], base + off))
            self._tail = buf[off:] if off < end else b""
        return bool(self._events)

    def _pop(self) -> bytes:
        payload, end = self._events.popleft()
        self.offset = end
        return payload

    def next_event(self, timeout: float | None = None) -> bytes:
        if self._events or self._drain():
            return self._pop()
        deadline = None if timeout is None else time.monotonic() + timeout
        delay = min(5e-5, self.poll)
        path = self._path()
        last_size = self._read_pos
        while True:
            # Size watch: the log only ever grows, so one fstat/stat tells
            # whether a drain can find anything new.
            try:
                if self._file is not None:
                    size = os.fstat(self._file.fileno()).st_size
                else:
                    size = os.stat(path).st_size
            except FileNotFoundError:
                size = -1
            if size != last_size:
                last_size = size
                delay = min(5e-5, self.poll)  # growth: reset the backoff
                if self._drain():
                    return self._pop()
                continue
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("no stream event within timeout")
            # documented adaptive size-watch backoff, bounded by ``poll``
            time.sleep(delay)  # proxylint: disable=no-sleep-poll
            delay = min(delay * 2.0, self.poll)

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None

    def __reduce__(self):
        # Carry the consumption offset: an unpickled consumer resumes after
        # the last returned event instead of silently re-reading the topic.
        return (FileLogSubscriber, (self.topic, self.directory, self.poll,
                                    self.offset))


# ---------------------------------------------------------------------------
# StreamProducer / StreamConsumer
# ---------------------------------------------------------------------------


class StreamProducer:
    """Publishes objects to topics: bulk → Store, event → Publisher.

    ``stores`` maps topic → Store, letting different topics use different
    mediated channels (paper: "mapping different stream topics to Store
    instances").  Supports batching and filter/aggregation plugins.
    """

    def __init__(
        self,
        publisher: Publisher,
        stores: dict[str, Store] | Store,
        *,
        batch_size: int = 1,
        filter_: Callable[[Any, dict], bool] | None = None,
        aggregator: Callable[[list[Any]], Any] | None = None,
        evict_on_resolve: bool = True,
    ):
        self.publisher = publisher
        self._stores = stores
        self.batch_size = batch_size
        self.filter = filter_
        self.aggregator = aggregator
        self.evict_on_resolve = evict_on_resolve
        self._buffers: dict[str, list[tuple[Any, dict, Any]]] = {}
        self._seq: dict[str, int] = {}
        self._event_codecs: dict[str, Any] = {}  # store name → picklable codec

    def _event_deserializer(self, store: Store):
        """The store's custom deserializer, if events can carry it.

        Non-picklable codecs (lambdas, closures) are omitted rather than
        failing every send: an in-process consumer still resolves through
        the registered store; only cross-process custom-codec streams need
        a picklable deserializer.
        """
        try:
            return self._event_codecs[store.name]
        except KeyError:
            pass
        deserializer = store._carried_deserializer()
        if deserializer is not None:
            try:
                pickle.dumps(deserializer)
            except Exception:
                deserializer = None
        self._event_codecs[store.name] = deserializer
        return deserializer

    def store_for(self, topic: str) -> Store:
        if isinstance(self._stores, Store):
            return self._stores
        if topic in self._stores:
            return self._stores[topic]
        if "*" in self._stores:
            return self._stores["*"]
        raise KeyError(f"no store mapped for topic {topic!r}")

    def send(self, topic: str, obj: Any, *, metadata: dict | None = None,
             lifetime: Any | None = None) -> None:
        """Queue ``obj`` for the topic.  ``lifetime`` (a
        :class:`repro.core.lifetimes.Lifetime`) takes custody of the bulk
        payload: the minted key is attached at flush, so a payload the
        consumer never resolves (``evict_on_resolve`` one-shots included)
        is evicted when the lifetime closes instead of leaking."""
        metadata = metadata or {}
        if self.filter is not None and not self.filter(obj, metadata):
            return
        buf = self._buffers.setdefault(topic, [])
        buf.append((obj, metadata, lifetime))
        if len(buf) >= self.batch_size:
            self.flush_topic(topic)

    def flush_topic(self, topic: str) -> None:
        buf = self._buffers.get(topic, [])
        if not buf:
            return
        store = self.store_for(topic)
        if self.aggregator is not None and len(buf) > 1:
            objs = [o for o, _, _ in buf]
            merged_meta: dict = {}
            for _, m, _ in buf:
                merged_meta.update(m)
            # the merged payload belongs to every lifetime that covered a
            # constituent send (closing any of them may evict it)
            lifetimes = [lt for _, _, lt in buf if lt is not None]
            buf = [(self.aggregator(objs), merged_meta,
                    lifetimes if lifetimes else None)]
        # one vectored connector round for the whole batch (bulk first, then
        # events: a consumer that sees an event can always fetch its object)
        keys = store.put_batch([obj for obj, _, _ in buf])
        for key, (_, _, lt) in zip(keys, buf):
            if lt is None:
                continue
            for one in lt if isinstance(lt, list) else (lt,):
                one.add(store, key)
        deserializer = self._event_deserializer(store)
        for key, (_, metadata, _) in zip(keys, buf):
            seq = self._seq.get(topic, 0)
            self._seq[topic] = seq + 1
            event = {
                "topic": topic,
                "key": key,
                "store": store.name,
                "connector": store.connector,
                # snapshot: the obj fast path shares the event unpickled,
                # so a producer mutating its metadata dict after send()
                # must not retroactively edit published events
                "metadata": dict(metadata),
                "seq": seq,
                "evict_on_resolve": self.evict_on_resolve,
            }
            if deserializer is not None:
                event["deserializer"] = deserializer
            publish_event(self.publisher, topic, event)
        self._buffers[topic] = []

    def send_committed(
        self,
        topic: str,
        obj: Any,
        *,
        key: str,
        metadata: dict | None = None,
        lifetime: Any | None = None,
    ) -> bool:
        """Exactly-once publish: commit ``obj`` at a *deterministic* key
        with ``put_if_absent``, then publish an event referencing that key
        — whether or not this producer won the commit.

        The ``DispatchingDataLoader`` twin-commit pattern lifted into the
        stream layer: when two producers race the same logical result (a
        redispatched serve request re-completed by a survivor engine),
        exactly one payload lands in the channel, every producer's event
        points at the *same* cell, and the consumer's one-shot resolve
        (``evict_on_resolve``) reclaims it exactly once.  Duplicate events
        are the dedup point — a router/client drops all but the first
        terminal event per key, and the dropped events reference a payload
        that the winning resolve already evicted (or will).

        Returns ``True`` when this call's put won the commit.  ``lifetime``
        takes custody only on a win — the loser does not own the cell.
        Bypasses batching; buffered sends flush first (event order).
        """
        self.flush_topic(topic)
        store = self.store_for(topic)
        won = store.put_if_absent(obj, key)
        if won and lifetime is not None:
            lifetime.add(store, key)
        deserializer = self._event_deserializer(store)
        seq = self._seq.get(topic, 0)
        self._seq[topic] = seq + 1
        event = {
            "topic": topic,
            "key": key,
            "store": store.name,
            "connector": store.connector,
            "metadata": dict(metadata or {}),
            "seq": seq,
            # one-shot: the first resolve reclaims the committed cell
            "evict_on_resolve": True,
        }
        if deserializer is not None:
            event["deserializer"] = deserializer
        publish_event(self.publisher, topic, event)
        return won

    def send_meta(self, topic: str, metadata: dict) -> None:
        """Publish a *metadata-only* event: no bulk payload, no store put.

        The cheap half of the metadata/bulk split: token deltas, progress
        ticks, heartbeats — anything small enough to live in the event
        itself rides the broker alone and never touches the channel.
        Consumers see it from ``next_with_metadata`` as ``(None, metadata)``;
        plain proxy iteration (``__next__``) skips such events.

        Bypasses batching; buffered ``send``s for the topic are flushed
        first so the event order on the topic matches the call order.
        """
        self.flush_topic(topic)
        seq = self._seq.get(topic, 0)
        self._seq[topic] = seq + 1
        event = {
            "topic": topic,
            "meta_only": True,
            # snapshot, same reason as flush_topic: the obj fast path
            # shares the event dict unpickled across subscribers
            "metadata": dict(metadata),
            "seq": seq,
        }
        publish_event(self.publisher, topic, event)

    def flush(self) -> None:
        for topic in list(self._buffers):
            self.flush_topic(topic)

    def close_topic(self, topic: str) -> None:
        self.flush_topic(topic)
        publish_event(self.publisher, topic, {_END: True, "topic": topic})

    def close(self, *, close_topics: bool = True) -> None:
        self.flush()
        if close_topics:
            for topic in set(self._buffers) | set(self._seq):
                publish_event(self.publisher, topic, {_END: True, "topic": topic})
        self.publisher.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


_ITEM, _DONE, _ERR = "item", "done", "err"


class StreamConsumer:
    """Iterates a topic, yielding lazy proxies of streamed objects.

    ``next()`` waits only for *metadata*; the bulk object is fetched where —
    and only if — the proxy is resolved.

    ``prefetch=N`` turns on consumer-side pipelining: a background thread
    pulls events and resolves their bulk payloads ahead of iteration, with
    at most N resolved items in flight (the thread blocks — backpressure —
    until the consumer catches up).  Yielded proxies arrive already
    resolved, in event order, so per-item transport overlaps the consumer's
    compute.  A resolution or subscriber error surfaces on the next
    ``__next__``.  Give the consumer a ``timeout`` when prefetching from a
    topic that may never close, so the background thread can exit.
    """

    def __init__(
        self,
        subscriber: Subscriber,
        *,
        filter_: Callable[[dict], bool] | None = None,
        timeout: float | None = None,
        prefetch: int = 0,
    ):
        self.subscriber = subscriber
        self.filter = filter_
        self.timeout = timeout
        self.prefetch = prefetch
        self._closed = False
        self._stop = False
        self._ready = None
        if prefetch:
            self._ready = queue.Queue(maxsize=prefetch)
            self._thread = threading.Thread(
                target=self._prefetch_loop, daemon=True
            )
            self._thread.start()

    def _next_event(self, timeout=_UNSET) -> dict:
        if timeout is _UNSET:
            timeout = self.timeout
        while True:
            event = _load_event(self.subscriber.next_event(timeout=timeout))
            if event.get(_END):
                # prefetch mode: items may still sit in the ready queue —
                # only the dequeue of the DONE marker closes the consumer
                if self._ready is None:
                    self._closed = True
                raise StopIteration
            if self.filter is not None and not self.filter(event.get("metadata", {})):
                # skipped events still evict their payload to avoid leaks
                if event.get("evict_on_resolve"):
                    event["connector"].evict(event["key"])
                    invalidate_resolve_cache(event["store"], event["key"])
                    san = _sanitize.active_for(event["store"])
                    if san:
                        san.on_evict(event["store"], event["connector"],
                                     event["key"], via="stream-skip")
                continue
            return event

    def _pull(self, timeout=_UNSET) -> tuple[Proxy | None, dict]:
        event = self._next_event(timeout)
        if event.get("meta_only"):
            # metadata-only event (StreamProducer.send_meta): nothing to
            # resolve — the metadata *is* the message
            return None, dict(event["metadata"])
        factory = StoreFactory(
            event["key"],
            event["store"],
            event["connector"],
            evict_on_resolve=event.get("evict_on_resolve", False),
            block=True,
            deserializer=event.get("deserializer"),
        )
        # Private copy: in-process events are one dict shared by every
        # subscriber (and the producer), so the metadata handed to user
        # code must be theirs to mutate.
        meta = dict(event["metadata"])
        proxy = Proxy(
            factory,
            metadata=dict(
                meta,
                seq=event["seq"],
                key=event["key"],
                store=event["store"],
            ),
        )
        return proxy, meta

    # -- prefetch pipeline -------------------------------------------------
    def _prefetch_loop(self) -> None:
        while not self._stop:
            try:
                proxy, meta = self._pull()
            except StopIteration:
                self._enqueue((_DONE, None))
                return
            except BaseException as e:
                self._enqueue((_ERR, e))
                return
            try:
                if proxy is not None:  # meta-only events have no bulk
                    extract(proxy)  # resolve the bulk ahead of the consumer
            except BaseException as e:
                self._enqueue((_ERR, e))
                return
            if not self._enqueue((_ITEM, (proxy, meta))):
                return

    def _enqueue(self, item) -> bool:
        # Bounded put with a stop check so close() can always unblock the
        # pipeline thread (backpressure must not outlive the consumer).
        while not self._stop:
            try:
                self._ready.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def next_with_metadata(self, timeout=_UNSET) -> tuple[Proxy | None, dict]:
        """Next ``(proxy, metadata)`` pair; ``(None, metadata)`` for
        metadata-only events.  ``timeout`` (seconds, or ``None`` to block
        forever) overrides the constructor timeout for this call — serving
        loops pull with their own deadline without rebuilding the consumer.
        """
        if self._closed:  # a closed topic stays closed (sticky END)
            raise StopIteration
        if self._ready is not None:
            if timeout is _UNSET:
                kind, val = self._ready.get()
            else:
                try:
                    if timeout is not None and timeout <= 0:
                        kind, val = self._ready.get_nowait()
                    else:
                        kind, val = self._ready.get(timeout=timeout)
                except queue.Empty:
                    raise TimeoutError("no stream event within timeout") from None
            if kind != _ITEM:
                # Terminal markers are sticky: the pipeline thread has
                # exited, so put the marker back — a retry after
                # exhaustion/error must re-raise, never block on an empty
                # queue forever.  (The marker is always the last entry, so
                # the queue has room.)
                self._ready.put((kind, val))
                if kind == _DONE:
                    self._closed = True
                    raise StopIteration
                raise val
            return val
        return self._pull(timeout)

    def __iter__(self) -> Iterator[Proxy]:
        return self

    def __next__(self) -> Proxy:
        if self._closed:
            raise StopIteration
        while True:
            proxy, _ = self.next_with_metadata()
            if proxy is not None:  # plain iteration skips meta-only events
                return proxy

    def close(self) -> None:
        self._stop = True
        self.subscriber.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
