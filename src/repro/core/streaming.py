"""ProxyStream — object streaming with metadata/bulk decoupling (paper §IV-B).

``StreamProducer.send(topic, obj)`` (1) puts ``obj`` in the topic's Store,
(2) builds a small *event* carrying user metadata + object location, and
(3) publishes the event via a :class:`Publisher`.  A ``StreamConsumer``
iterates events from a :class:`Subscriber` and yields *proxies*: the bulk
bytes move only between the producer's store and whichever process finally
resolves the proxy — a dispatcher in between touches metadata only.

Brokers provided: in-process queue (Redis-pub/sub stand-in) and append-only
file log (Kafka stand-in, cross-process).  The Publisher/Subscriber
protocols mirror the paper so real Kafka/Redis/ZeroMQ shims would slot in.
"""
from __future__ import annotations

import os
import pickle
import threading
import time
from collections import deque
from typing import Any, Callable, Iterator, Protocol, runtime_checkable

from repro.core.proxy import Proxy
from repro.core.store import Store, StoreFactory, invalidate_resolve_cache

_END = "__stream_end__"


@runtime_checkable
class Publisher(Protocol):
    def send_event(self, topic: str, event: bytes) -> None: ...

    def close(self) -> None: ...


@runtime_checkable
class Subscriber(Protocol):
    def next_event(self, timeout: float | None = None) -> bytes: ...

    def close(self) -> None: ...


# ---------------------------------------------------------------------------
# In-process queue broker (fanout pub/sub)
# ---------------------------------------------------------------------------


class _QueueBroker:
    _registry: dict[str, "_QueueBroker"] = {}
    _lock = threading.Lock()

    def __init__(self):
        self.cond = threading.Condition()
        self.subscribers: dict[str, list[deque]] = {}

    @classmethod
    def instance(cls, namespace: str) -> "_QueueBroker":
        with cls._lock:
            if namespace not in cls._registry:
                cls._registry[namespace] = _QueueBroker()
            return cls._registry[namespace]

    def publish(self, topic: str, event: bytes) -> None:
        with self.cond:
            for q in self.subscribers.get(topic, []):
                q.append(event)
            self.cond.notify_all()

    def subscribe(self, topic: str) -> deque:
        q: deque = deque()
        with self.cond:
            self.subscribers.setdefault(topic, []).append(q)
        return q

    def pop(self, q: deque, timeout: float | None) -> bytes:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self.cond:
            while not q:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError("no stream event within timeout")
                self.cond.wait(remaining if remaining is not None else None)
            return q.popleft()


class QueuePublisher:
    """In-process pub/sub publisher (single-process benchmarks, threads)."""

    def __init__(self, namespace: str = "default"):
        self.namespace = namespace

    def send_event(self, topic: str, event: bytes) -> None:
        _QueueBroker.instance(self.namespace).publish(topic, event)

    def close(self) -> None:
        pass


class QueueSubscriber:
    def __init__(self, topic: str, namespace: str = "default"):
        self.namespace = namespace
        self.topic = topic
        self._broker = _QueueBroker.instance(namespace)
        self._q = self._broker.subscribe(topic)

    def next_event(self, timeout: float | None = None) -> bytes:
        return self._broker.pop(self._q, timeout)

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# File log broker (cross-process; Kafka stand-in)
# ---------------------------------------------------------------------------


class FileLogPublisher:
    """Append-only length-prefixed event log, one file per topic."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, topic: str) -> str:
        return os.path.join(self.directory, f"{topic}.log")

    def send_event(self, topic: str, event: bytes) -> None:
        frame = len(event).to_bytes(8, "little") + event
        # O_APPEND single-write frames are atomic enough for our single-node
        # multi-producer case (frames ≪ typical atomic append sizes); a real
        # deployment uses Kafka.
        fd = os.open(self._path(topic), os.O_WRONLY | os.O_CREAT | os.O_APPEND)
        try:
            os.write(fd, frame)
        finally:
            os.close(fd)

    def close(self) -> None:
        pass

    def __reduce__(self):
        return (FileLogPublisher, (self.directory,))


class FileLogSubscriber:
    """Tails a topic log from a given offset (default: beginning)."""

    def __init__(self, topic: str, directory: str, poll: float = 0.002):
        self.topic = topic
        self.directory = directory
        self.offset = 0
        self.poll = poll

    def _path(self) -> str:
        return os.path.join(self.directory, f"{self.topic}.log")

    def next_event(self, timeout: float | None = None) -> bytes:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            try:
                with open(self._path(), "rb") as f:
                    f.seek(self.offset)
                    header = f.read(8)
                    if len(header) == 8:
                        n = int.from_bytes(header, "little")
                        payload = f.read(n)
                        if len(payload) == n:
                            self.offset += 8 + n
                            return payload
            except FileNotFoundError:
                pass
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("no stream event within timeout")
            time.sleep(self.poll)

    def close(self) -> None:
        pass

    def __reduce__(self):
        return (FileLogSubscriber, (self.topic, self.directory, self.poll))


# ---------------------------------------------------------------------------
# StreamProducer / StreamConsumer
# ---------------------------------------------------------------------------


class StreamProducer:
    """Publishes objects to topics: bulk → Store, event → Publisher.

    ``stores`` maps topic → Store, letting different topics use different
    mediated channels (paper: "mapping different stream topics to Store
    instances").  Supports batching and filter/aggregation plugins.
    """

    def __init__(
        self,
        publisher: Publisher,
        stores: dict[str, Store] | Store,
        *,
        batch_size: int = 1,
        filter_: Callable[[Any, dict], bool] | None = None,
        aggregator: Callable[[list[Any]], Any] | None = None,
        evict_on_resolve: bool = True,
    ):
        self.publisher = publisher
        self._stores = stores
        self.batch_size = batch_size
        self.filter = filter_
        self.aggregator = aggregator
        self.evict_on_resolve = evict_on_resolve
        self._buffers: dict[str, list[tuple[Any, dict]]] = {}
        self._seq: dict[str, int] = {}
        self._event_codecs: dict[str, Any] = {}  # store name → picklable codec

    def _event_deserializer(self, store: Store):
        """The store's custom deserializer, if events can carry it.

        Non-picklable codecs (lambdas, closures) are omitted rather than
        failing every send: an in-process consumer still resolves through
        the registered store; only cross-process custom-codec streams need
        a picklable deserializer.
        """
        try:
            return self._event_codecs[store.name]
        except KeyError:
            pass
        deserializer = store._carried_deserializer()
        if deserializer is not None:
            try:
                pickle.dumps(deserializer)
            except Exception:
                deserializer = None
        self._event_codecs[store.name] = deserializer
        return deserializer

    def store_for(self, topic: str) -> Store:
        if isinstance(self._stores, Store):
            return self._stores
        if topic in self._stores:
            return self._stores[topic]
        if "*" in self._stores:
            return self._stores["*"]
        raise KeyError(f"no store mapped for topic {topic!r}")

    def send(self, topic: str, obj: Any, *, metadata: dict | None = None) -> None:
        metadata = metadata or {}
        if self.filter is not None and not self.filter(obj, metadata):
            return
        buf = self._buffers.setdefault(topic, [])
        buf.append((obj, metadata))
        if len(buf) >= self.batch_size:
            self.flush_topic(topic)

    def flush_topic(self, topic: str) -> None:
        buf = self._buffers.get(topic, [])
        if not buf:
            return
        store = self.store_for(topic)
        if self.aggregator is not None and len(buf) > 1:
            objs = [o for o, _ in buf]
            merged_meta: dict = {}
            for _, m in buf:
                merged_meta.update(m)
            buf = [(self.aggregator(objs), merged_meta)]
        # one vectored connector round for the whole batch (bulk first, then
        # events: a consumer that sees an event can always fetch its object)
        keys = store.put_batch([obj for obj, _ in buf])
        deserializer = self._event_deserializer(store)
        for key, (_, metadata) in zip(keys, buf):
            seq = self._seq.get(topic, 0)
            self._seq[topic] = seq + 1
            event = {
                "topic": topic,
                "key": key,
                "store": store.name,
                "connector": store.connector,
                "metadata": metadata,
                "seq": seq,
                "evict_on_resolve": self.evict_on_resolve,
            }
            if deserializer is not None:
                event["deserializer"] = deserializer
            self.publisher.send_event(topic, pickle.dumps(event))
        self._buffers[topic] = []

    def flush(self) -> None:
        for topic in list(self._buffers):
            self.flush_topic(topic)

    def close_topic(self, topic: str) -> None:
        self.flush_topic(topic)
        self.publisher.send_event(topic, pickle.dumps({_END: True, "topic": topic}))

    def close(self, *, close_topics: bool = True) -> None:
        self.flush()
        if close_topics:
            for topic in set(self._buffers) | set(self._seq):
                self.publisher.send_event(
                    topic, pickle.dumps({_END: True, "topic": topic})
                )
        self.publisher.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class StreamConsumer:
    """Iterates a topic, yielding lazy proxies of streamed objects.

    ``next()`` waits only for *metadata*; the bulk object is fetched where —
    and only if — the proxy is resolved.
    """

    def __init__(
        self,
        subscriber: Subscriber,
        *,
        filter_: Callable[[dict], bool] | None = None,
        timeout: float | None = None,
    ):
        self.subscriber = subscriber
        self.filter = filter_
        self.timeout = timeout
        self._closed = False

    def _next_event(self) -> dict:
        while True:
            event = pickle.loads(self.subscriber.next_event(timeout=self.timeout))
            if event.get(_END):
                self._closed = True
                raise StopIteration
            if self.filter is not None and not self.filter(event.get("metadata", {})):
                # skipped events still evict their payload to avoid leaks
                if event.get("evict_on_resolve"):
                    event["connector"].evict(event["key"])
                    invalidate_resolve_cache(event["store"], event["key"])
                continue
            return event

    def next_with_metadata(self) -> tuple[Proxy, dict]:
        event = self._next_event()
        factory = StoreFactory(
            event["key"],
            event["store"],
            event["connector"],
            evict_on_resolve=event.get("evict_on_resolve", False),
            block=True,
            deserializer=event.get("deserializer"),
        )
        proxy = Proxy(
            factory,
            metadata=dict(
                event["metadata"],
                seq=event["seq"],
                key=event["key"],
                store=event["store"],
            ),
        )
        return proxy, event["metadata"]

    def __iter__(self) -> Iterator[Proxy]:
        return self

    def __next__(self) -> Proxy:
        if self._closed:
            raise StopIteration
        proxy, _ = self.next_with_metadata()
        return proxy

    def close(self) -> None:
        self.subscriber.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
