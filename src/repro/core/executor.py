"""StoreExecutor — engine shim that auto-proxies task I/O (paper §IV-C).

Wraps any ``concurrent.futures``-style executor (thread/process pools here;
Dask/Parsl/Globus Compute in the paper) and:

1. auto-proxies task arguments/results larger than a policy threshold,
2. tracks Ref/RefMut borrows passed into a task and releases them via a
   done-callback on the task's future — "a reference passed to a task goes
   out of scope when the task completes",
3. offers :meth:`submit_future`, which returns a :class:`ProxyFuture`
   *immediately*: downstream tasks take ``future.proxy()`` and submit
   without waiting, so Fig-5-style producer/consumer chains overlap compute
   with transport by default.
"""
from __future__ import annotations

from concurrent.futures import Executor, Future
from dataclasses import dataclass
from typing import Any, Callable

from repro.core import framing
from repro.core.futures import ProxyFuture
from repro.core.ownership import (
    OwnedProxy,
    RefMutProxy,
    RefProxy,
    _state,
    release_by_token,
)
from repro.core.proxy import Proxy
from repro.core.store import Store


@dataclass
class ProxyPolicy:
    """When to proxy task inputs/outputs (paper §VI: >1 kB for MOF-gen)."""

    min_bytes: int = 1024
    proxy_args: bool = True
    proxy_results: bool = True

    def should_proxy(self, obj: Any) -> bool:
        if isinstance(obj, Proxy):
            return False
        # Tiny knowns skip the framing estimate entirely: scalars can never
        # reach a real threshold, and str/bytes sizes bound their payloads
        # (a str is ≤4 B/char encoded; the +64 covers pickle overhead).
        t = type(obj)
        if obj is None or t in (bool, float, complex) or (
            t is int and obj.bit_length() <= 512  # ints are unbounded
        ):
            if self.min_bytes > 64:
                return False
        elif t is bytes or t is bytearray:
            if len(obj) >= self.min_bytes:
                return True
            if len(obj) + 64 < self.min_bytes:
                return False
        elif t is str:
            if 4 * len(obj) + 64 < self.min_bytes:
                return False
        # framing's estimate is copy-free for array-likes (reads .nbytes)
        # and out-of-band for everything else — no full in-band dumps here.
        size = framing.estimated_nbytes(obj)
        return size >= self.min_bytes


def _publish_error(result: ProxyFuture, exc: BaseException) -> None:
    """Best-effort: make *some* error payload reach the channel.

    A consumer blocked on the future can only be released through the
    store — if the real exception (or result) is unpicklable, publish a
    picklable stand-in rather than leaving the key forever unset (the
    silent-hang failure mode the notification protocol exists to kill).
    """
    try:
        result.set_exception(exc)
    except RuntimeError:
        pass  # already set: nothing to release
    except BaseException:
        try:
            result.set_exception(
                RuntimeError(f"task failed with unpicklable payload: {exc!r}")
            )
        except BaseException:  # proxylint: disable=swallowed-error
            pass  # last resort: the result future itself is unusable


def _proxy_result_wrapper(fn: Callable, store: Store, policy: ProxyPolicy):
    def wrapped(*args, **kwargs):
        out = fn(*args, **kwargs)
        if policy.proxy_results and policy.should_proxy(out):
            return store.proxy(out, evict_on_resolve=True)
        return out

    return wrapped


class StoreExecutor:
    """Engine-agnostic executor wrapper with proxy + ownership integration."""

    def __init__(
        self,
        engine: Executor,
        store: Store,
        *,
        policy: ProxyPolicy | None = None,
    ):
        self.engine = engine
        self.store = store
        self.policy = policy or ProxyPolicy()

    def _transform_args(self, args, kwargs):
        """Proxy large args, collect Ref/RefMut borrows for auto-release."""
        borrows: list[tuple[Any, str]] = []  # (_RefState, token)

        def xform(obj):
            if isinstance(obj, (RefProxy, RefMutProxy)):
                meta = object.__getattribute__(obj, "__proxy_metadata__")
                borrows.append((_state(obj), meta["token"]))
                return obj
            if isinstance(obj, (OwnedProxy, Proxy)):
                return obj
            if self.policy.proxy_args and self.policy.should_proxy(obj):
                return self.store.proxy(obj, evict_on_resolve=True)
            return obj

        return (
            tuple(xform(a) for a in args),
            {k: xform(v) for k, v in kwargs.items()},
            borrows,
        )

    @staticmethod
    def _attach_release(fut: Future, borrows) -> None:
        if borrows:

            def _release(_f: Future, borrows=borrows):
                for st, token in borrows:
                    release_by_token(st, token)

            fut.add_done_callback(_release)

    def submit(self, fn: Callable, *args, **kwargs) -> Future:
        args, kwargs, borrows = self._transform_args(args, kwargs)
        fut = self.engine.submit(
            _proxy_result_wrapper(fn, self.store, self.policy), *args, **kwargs
        )
        self._attach_release(fut, borrows)
        return fut

    def submit_future(self, fn: Callable, *args, **kwargs) -> ProxyFuture:
        """Submit ``fn`` and return a :class:`ProxyFuture` of its result.

        The future exists before the task runs: mint proxies from it and
        submit consumers immediately — they block just-in-time in the store
        (paper §IV-A pipelining).  The task's result travels through the
        channel via ``set_result``; a task exception is propagated with
        ``set_exception`` and re-raised by ``result()``/proxy resolution.
        The engine-side handle is exposed as ``future.task``.
        """
        result = self.store.future()
        args, kwargs, borrows = self._transform_args(args, kwargs)

        def run(*a, **kw):
            try:
                out = fn(*a, **kw)
            except BaseException as e:
                _publish_error(result, e)
                raise
            try:
                result.set_result(out)
            except RuntimeError:
                raise  # double-set: a genuine protocol violation
            except BaseException as e:
                # e.g. an unserializable result: consumers must still wake
                _publish_error(result, e)
                raise

        task = self.engine.submit(run, *args, **kwargs)
        self._attach_release(task, borrows)
        result.task = task
        return result

    def map(self, fn: Callable, *iterables):
        futs = [self.submit(fn, *xs) for xs in zip(*iterables)]
        for f in futs:
            yield f.result()

    def shutdown(self, wait: bool = True) -> None:
        self.engine.shutdown(wait=wait)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
