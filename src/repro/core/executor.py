"""StoreExecutor — engine shim that auto-proxies task I/O (paper §IV-C).

Wraps any ``concurrent.futures``-style executor (thread/process pools here;
Dask/Parsl/Globus Compute in the paper) and:

1. auto-proxies task arguments/results larger than a policy threshold,
2. tracks Ref/RefMut borrows passed into a task and releases them via a
   done-callback on the task's future — "a reference passed to a task goes
   out of scope when the task completes".
"""
from __future__ import annotations

from concurrent.futures import Executor, Future
from dataclasses import dataclass
from typing import Any, Callable

from repro.core import framing
from repro.core.ownership import (
    OwnedProxy,
    RefMutProxy,
    RefProxy,
    _state,
    release_by_token,
)
from repro.core.proxy import Proxy
from repro.core.store import Store


@dataclass
class ProxyPolicy:
    """When to proxy task inputs/outputs (paper §VI: >1 kB for MOF-gen)."""

    min_bytes: int = 1024
    proxy_args: bool = True
    proxy_results: bool = True

    def should_proxy(self, obj: Any) -> bool:
        if isinstance(obj, Proxy):
            return False
        # framing's estimate is copy-free for array-likes (reads .nbytes)
        # and out-of-band for everything else — no full in-band dumps here.
        size = framing.estimated_nbytes(obj)
        return size >= self.min_bytes


def _proxy_result_wrapper(fn: Callable, store: Store, policy: ProxyPolicy):
    def wrapped(*args, **kwargs):
        out = fn(*args, **kwargs)
        if policy.proxy_results and policy.should_proxy(out):
            return store.proxy(out, evict_on_resolve=True)
        return out

    return wrapped


class StoreExecutor:
    """Engine-agnostic executor wrapper with proxy + ownership integration."""

    def __init__(
        self,
        engine: Executor,
        store: Store,
        *,
        policy: ProxyPolicy | None = None,
    ):
        self.engine = engine
        self.store = store
        self.policy = policy or ProxyPolicy()

    def submit(self, fn: Callable, *args, **kwargs) -> Future:
        borrows: list[tuple[Any, str]] = []  # (_RefState, token)

        def xform(obj):
            if isinstance(obj, (RefProxy, RefMutProxy)):
                meta = object.__getattribute__(obj, "__proxy_metadata__")
                borrows.append((_state(obj), meta["token"]))
                return obj
            if isinstance(obj, (OwnedProxy, Proxy)):
                return obj
            if self.policy.proxy_args and self.policy.should_proxy(obj):
                return self.store.proxy(obj, evict_on_resolve=True)
            return obj

        args = tuple(xform(a) for a in args)
        kwargs = {k: xform(v) for k, v in kwargs.items()}

        fut = self.engine.submit(
            _proxy_result_wrapper(fn, self.store, self.policy), *args, **kwargs
        )

        if borrows:

            def _release(_f: Future, borrows=borrows):
                for st, token in borrows:
                    release_by_token(st, token)

            fut.add_done_callback(_release)
        return fut

    def map(self, fn: Callable, *iterables):
        futs = [self.submit(fn, *xs) for xs in zip(*iterables)]
        for f in futs:
            yield f.result()

    def shutdown(self, wait: bool = True) -> None:
        self.engine.shutdown(wait=wait)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
