"""TCP store server: the network leg of the mediated-channel protocol.

The paper's connectors (Redis, Margo, UCX endpoints) resolve a proxy "to
data regardless of location"; this module is that leg for this repo — a
:class:`StoreServer` hosting any backing connector behind a socket, and a
:class:`StoreServerConnector` client implementing the *full* optional-
method table of :mod:`repro.core.connectors` (``put_parts``, ``put_batch``,
``put_parts_new``, ``get_view``, ``wait_for``, ``wait_for_any``), so the
lease service, the dispatching loader, and the serve request/response
protocol run across processes (and, with a routable address, hosts)
unchanged.

Wire format — length-prefixed PSF1 frames::

    request  := u32 frame_len | u8 op     | body
    response := u32 frame_len | u8 status | body

Put bodies carry ``key | u32 nparts | u64 len × n | raw parts``: the
framed PSF1 parts (header, pickle, out-of-band pickle-5 buffers) are
handed to ``sendmsg`` as a scatter-gather list and are never joined in
user space.  Responses land in ONE ``recv_into`` buffer per frame; payload
and key fields are zero-copy views of it.

Waits are server-side pushes: a ``WAIT``/``WAIT_ANY`` request parks the
connection's server thread in the *backing* connector's native
notification wait (condition variables for the in-memory backing) and the
response is pushed the moment the key lands — the client simply blocks on
the socket, polling nothing.

Concurrency model: the client keeps a small pool of connections; each
round trip checks one out (dialing on demand), so a thread blocked in a
wait never blocks a concurrent put — the serve engine's puller thread and
admission loop share one connector safely.  Server side is one thread per
connection; a parked wait occupies only its own connection's thread.
"""
from __future__ import annotations

import os
import socket
import struct
import threading
import time
from contextlib import contextmanager
from typing import Iterable, Sequence

from repro.core.connectors import (
    InMemoryConnector,
    get_payload,
    put_batch_payloads,
    put_payload,
    put_payload_new,
)
from repro.core.connectors import (
    wait_for as _wait_for,
    wait_for_any as _wait_for_any,
)
from repro.core.framing import parts_nbytes

# -- ops / statuses ----------------------------------------------------------

OP_PUT = 1
OP_PUT_NEW = 2
OP_PUT_BATCH = 3
OP_GET = 4
OP_EXISTS = 5
OP_EVICT = 6
OP_WAIT = 7
OP_WAIT_ANY = 8
OP_KEYS = 9
OP_PING = 10

ST_OK = 0
ST_MISSING = 1
ST_EXISTS = 2
ST_TIMEOUT = 3
ST_ERR = 4

_LEN = struct.Struct("<I")
_U8 = struct.Struct("<B")
_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_F64 = struct.Struct("<d")

# sendmsg is capped at IOV_MAX buffers per call; stay far below it
_IOV_CHUNK = 512
# slack added to the socket read timeout over a wait's own deadline: the
# server owns timeout arbitration, the socket guard only catches a dead
# server
_WAIT_SLACK_S = 30.0
# server-side parked waits probe their connection's peer at this cadence:
# a rudely-disconnected client (crash, SIGKILL) releases the connection
# thread within one tick instead of holding it for the wait's full budget
_PEER_TICK = 0.25


class _PeerGone(Exception):
    """The waiting connection's client hung up: abandon the wait, no
    response frame (there is nobody to read it)."""


def _peer_alive(sock: socket.socket) -> bool:
    """Non-blocking peek: has the peer closed (or reset) the connection?

    The request/response protocol is strictly half-duplex per connection,
    so while the server owes a response nothing should be readable — a
    readable EOF (``b""``) or a reset means the client is gone."""
    try:
        probe = sock.recv(1, socket.MSG_PEEK | socket.MSG_DONTWAIT)
    except (BlockingIOError, InterruptedError):
        return True  # nothing to read: peer still there
    except OSError:
        return False  # reset / bad fd: peer gone
    return len(probe) > 0


# -- low-level frame I/O -----------------------------------------------------


def _sendmsg_all(sock: socket.socket, bufs: Sequence) -> None:
    """Scatter-gather send of every buffer, handling partial sendmsg."""
    views = []
    for b in bufs:
        mv = b if isinstance(b, memoryview) else memoryview(b)
        if mv.ndim != 1 or mv.format != "B":
            mv = mv.cast("B")
        if mv.nbytes:
            views.append(mv)
    while views:
        sent = sock.sendmsg(views[:_IOV_CHUNK])
        i = 0
        while i < len(views) and sent >= views[i].nbytes:
            sent -= views[i].nbytes
            i += 1
        views = views[i:]
        if sent and views:
            views[0] = views[0][sent:]


def _recv_exact(sock: socket.socket, n: int) -> memoryview:
    """Read exactly ``n`` bytes into one fresh buffer (zero-copy slices of
    the returned view are safe to retain: the buffer is never reused)."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:], n - got)
        if r == 0:
            raise ConnectionError("store-server peer closed mid-frame")
        got += r
    return view


def send_frame(sock: socket.socket, code: int, body_parts: Sequence) -> None:
    """One ``u32 len | u8 code | body`` frame, body as scatter-gather parts."""
    body_len = parts_nbytes(body_parts)
    head = _LEN.pack(1 + body_len) + _U8.pack(code)
    _sendmsg_all(sock, [head, *body_parts])


def recv_frame(sock: socket.socket) -> tuple[int, memoryview]:
    """Read one frame; returns ``(code, body_view)``."""
    (frame_len,) = _LEN.unpack(bytes(_recv_exact(sock, _LEN.size)))
    frame = _recv_exact(sock, frame_len)
    return frame[0], frame[1:]


def _pack_key(key: str) -> bytes:
    kb = key.encode()
    return _U16.pack(len(kb)) + kb


def _unpack_key(body: memoryview, off: int) -> tuple[str, int]:
    (klen,) = _U16.unpack_from(body, off)
    off += _U16.size
    return bytes(body[off : off + klen]).decode(), off + klen


def _unpack_parts(body: memoryview, off: int) -> tuple[list[memoryview], int]:
    """Part lengths + raw bytes → zero-copy views of the receive buffer."""
    (nparts,) = _U32.unpack_from(body, off)
    off += _U32.size
    lens = [
        _U64.unpack_from(body, off + i * _U64.size)[0] for i in range(nparts)
    ]
    off += nparts * _U64.size
    parts = []
    for n in lens:
        parts.append(body[off : off + n])
        off += n
    return parts, off


def _pack_parts_meta(parts: Sequence) -> bytes:
    return _U32.pack(len(parts)) + b"".join(
        _U64.pack(p.nbytes if isinstance(p, memoryview) else len(p))
        for p in parts
    )


# -- server ------------------------------------------------------------------


class StoreServer:
    """TCP front end over any backing connector (default: in-memory).

    One accept thread, one thread per connection; every request on a
    connection is handled in order, so a parked wait blocks only its own
    connection (clients pool connections precisely for this).  Dispatch
    errors are answered as ``ST_ERR`` frames, never by dropping the
    connection — a misbehaving request can't wedge its peer.
    """

    def __init__(self, backing=None, host: str = "127.0.0.1", port: int = 0):
        self.backing = backing if backing is not None else InMemoryConnector("srv")
        self._listener = socket.create_server((host, port), backlog=64)
        self.host, self.port = self._listener.getsockname()[:2]
        self._stop = threading.Event()
        self._conns: set[socket.socket] = set()
        self._conns_lock = threading.Lock()
        self._accept_thread: threading.Thread | None = None

    # -- lifecycle --
    def start(self) -> "StoreServer":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="store-server-accept", daemon=True
        )
        self._accept_thread.start()
        return self

    def serve_forever(self) -> None:
        self.start()
        self._stop.wait()

    def stop(self) -> None:
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._conns_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.close()
            except OSError:
                pass
        self.backing.close()

    @property
    def address(self) -> str:
        return f"{self.host}:{self.port}"

    # -- loops --
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return  # listener closed: shutting down
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conns_lock:
                self._conns.add(conn)
            threading.Thread(
                target=self._serve_conn, args=(conn,),
                name="store-server-conn", daemon=True,
            ).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                try:
                    op, body = recv_frame(conn)
                except (ConnectionError, OSError):
                    return  # client went away: normal teardown
                try:
                    status, out = self._dispatch(op, body, conn)
                except _PeerGone:
                    return  # waiting client hung up: release the thread
                except TimeoutError:
                    status, out = ST_TIMEOUT, ()
                except Exception as e:  # answered loudly, connection survives
                    status, out = ST_ERR, (repr(e).encode(),)
                try:
                    send_frame(conn, status, out)
                except (ConnectionError, OSError):
                    return
        finally:
            with self._conns_lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    # -- dispatch --
    def _wait_sliced(self, conn, wait_once, timeout: float | None):
        """Run a backing wait in ``_PEER_TICK`` slices, probing the
        connection's peer between slices.

        The backing wait is notification-driven (condition variables), so
        slicing costs one spurious wakeup per tick, not a busy poll — but
        it bounds how long a thread parked for a rudely-disconnected
        client lingers: one tick, not the wait's full budget (a client
        crash during an unbounded wait used to leak the thread forever).
        Raises :class:`_PeerGone` when the probe says the client left.
        """
        if conn is None:
            return wait_once(timeout)
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            tick = _PEER_TICK
            if deadline is not None:
                tick = min(tick, max(deadline - time.monotonic(), 0.0))
            try:
                return wait_once(tick)
            except TimeoutError:
                if deadline is not None and time.monotonic() >= deadline:
                    raise
                if not _peer_alive(conn):
                    raise _PeerGone from None

    def _dispatch(
        self, op: int, body: memoryview, conn: socket.socket | None = None
    ) -> tuple[int, tuple]:
        b = self.backing
        if op == OP_PUT or op == OP_PUT_NEW:
            key, off = _unpack_key(body, 0)
            parts, _ = _unpack_parts(body, off)
            if op == OP_PUT:
                put_payload(b, key, parts)
                return ST_OK, ()
            if put_payload_new(b, key, parts) is None:
                return ST_EXISTS, ()
            return ST_OK, ()
        if op == OP_PUT_BATCH:
            (nitems,) = _U32.unpack_from(body, 0)
            off = _U32.size
            metas = []
            for _ in range(nitems):
                key, off = _unpack_key(body, off)
                (nparts,) = _U32.unpack_from(body, off)
                off += _U32.size
                lens = [
                    _U64.unpack_from(body, off + i * _U64.size)[0]
                    for i in range(nparts)
                ]
                off += nparts * _U64.size
                metas.append((key, lens))
            items = []
            for key, lens in metas:
                parts = []
                for n in lens:
                    parts.append(body[off : off + n])
                    off += n
                items.append((key, parts))
            put_batch_payloads(b, items)
            return ST_OK, ()
        if op == OP_GET:
            key, _ = _unpack_key(body, 0)
            payload = get_payload(b, key)
            if payload is None:
                return ST_MISSING, ()
            if not isinstance(payload, (tuple, list)):
                payload = (payload,)
            return ST_OK, tuple(payload)
        if op == OP_EXISTS:
            key, _ = _unpack_key(body, 0)
            return ST_OK, (_U8.pack(1 if b.exists(key) else 0),)
        if op == OP_EVICT:
            key, _ = _unpack_key(body, 0)
            b.evict(key)
            return ST_OK, ()
        if op == OP_WAIT:
            (t,) = _F64.unpack_from(body, 0)
            key, _ = _unpack_key(body, _F64.size)
            # raises TimeoutError on deadline, _PeerGone on client hangup
            self._wait_sliced(
                conn, lambda tt: _wait_for(b, key, tt), None if t < 0 else t
            )
            return ST_OK, ()
        if op == OP_WAIT_ANY:
            (t,) = _F64.unpack_from(body, 0)
            (nkeys,) = _U32.unpack_from(body, _F64.size)
            off = _F64.size + _U32.size
            keys = []
            for _ in range(nkeys):
                k, off = _unpack_key(body, off)
                keys.append(k)
            won = self._wait_sliced(
                conn,
                lambda tt: _wait_for_any(b, keys, tt),
                None if t < 0 else t,
            )
            return ST_OK, (_pack_key(won),)
        if op == OP_KEYS:
            prefix, _ = _unpack_key(body, 0)
            ks = getattr(b, "keys", lambda: ())()
            hits = [k for k in ks if k.startswith(prefix)]
            return ST_OK, (
                _U32.pack(len(hits)),
                b"".join(_pack_key(k) for k in hits),
            )
        if op == OP_PING:
            info = f"{os.getpid()}:{type(self.backing).__name__}".encode()
            return ST_OK, (info,)
        raise ValueError(f"unknown store-server op {op}")


# -- client ------------------------------------------------------------------


class StoreServerConnector:
    """Client connector for a :class:`StoreServer` channel.

    Implements the full optional-method table, so every higher layer
    (Store hot path, futures, streams, lease service, serve protocol)
    treats a remote server exactly like a local channel.  Keys are
    namespaced client-side (``<namespace>|<key>`` on the wire) so many
    logical stores can share one server process.

    Picklable: the reduced form carries only ``(address, namespace)`` —
    the far side re-dials, which is exactly the paper's "factory carries
    server address info" contract.
    """

    def __init__(
        self,
        address: str,
        namespace: str = "d",
        *,
        connect_timeout: float = 5.0,
        op_timeout: float = 60.0,
    ):
        host, _, port = address.rpartition(":")
        self.address = address
        self.host, self.port = host or "127.0.0.1", int(port)
        self.namespace = namespace
        # one channel across every client socket/process (ProxySan keying)
        self.channel_id = f"tcp://{self.host}:{self.port}/{namespace}"
        self.connect_timeout = connect_timeout
        self.op_timeout = op_timeout
        self._prefix = namespace + "|"
        self._pool: list[socket.socket] = []
        self._pool_lock = threading.Lock()

    # -- connection pool --
    def _dial(self) -> socket.socket:
        sock = socket.create_connection(
            (self.host, self.port), timeout=self.connect_timeout
        )
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    @contextmanager
    def _conn(self):
        with self._pool_lock:
            sock = self._pool.pop() if self._pool else None
        if sock is None:
            sock = self._dial()
        try:
            yield sock
        except BaseException:
            # a failed round trip leaves the stream in an unknown state:
            # drop the socket, never return it to the pool
            try:
                sock.close()
            except OSError:
                pass
            raise
        else:
            with self._pool_lock:
                self._pool.append(sock)

    def _request(
        self, op: int, body_parts: Sequence, *, timeout: float | None = "op"
    ) -> tuple[int, memoryview]:
        """One pooled round trip; returns ``(status, body)``.

        ``timeout`` is the socket read guard: default is the flat op
        budget; wait ops pass their own deadline (+slack) or ``None`` for
        an unbounded wait.  Protocol-level statuses (MISSING/EXISTS/
        TIMEOUT) are returns, not errors — the connection stays pooled.
        """
        with self._conn() as sock:
            sock.settimeout(self.op_timeout if timeout == "op" else timeout)
            send_frame(sock, op, body_parts)
            status, body = recv_frame(sock)
        if status == ST_ERR:
            raise RuntimeError(
                f"store server error: {bytes(body).decode(errors='replace')}"
            )
        return status, body

    # -- required protocol --
    def put(self, key: str, data: bytes) -> None:
        self.put_parts(key, (data,))

    def get(self, key: str) -> bytes | None:
        view = self.get_view(key)
        return None if view is None else bytes(view)

    def exists(self, key: str) -> bool:
        status, body = self._request(OP_EXISTS, (_pack_key(self._prefix + key),))
        return status == ST_OK and body[0] == 1

    def evict(self, key: str) -> None:
        self._request(OP_EVICT, (_pack_key(self._prefix + key),))

    def close(self) -> None:
        # closes this client's sockets only; the server channel (and other
        # clients) live on — same semantics as FileConnector.close()
        with self._pool_lock:
            pool, self._pool = self._pool, []
        for sock in pool:
            try:
                sock.close()
            except OSError:
                pass

    # -- optional-method table --
    def put_parts(self, key: str, parts: Sequence) -> int:
        meta = _pack_key(self._prefix + key) + _pack_parts_meta(parts)
        self._request(OP_PUT, (meta, *parts))
        return parts_nbytes(parts)

    def put_parts_new(self, key: str, parts: Sequence) -> int | None:
        meta = _pack_key(self._prefix + key) + _pack_parts_meta(parts)
        status, _ = self._request(OP_PUT_NEW, (meta, *parts))
        return None if status == ST_EXISTS else parts_nbytes(parts)

    def put_batch(self, items: Sequence[tuple[str, Sequence]]) -> int:
        metas = [
            _pack_key(self._prefix + key) + _pack_parts_meta(parts)
            for key, parts in items
        ]
        raw = [p for _, parts in items for p in parts]
        self._request(OP_PUT_BATCH, (_U32.pack(len(items)), *metas, *raw))
        return sum(parts_nbytes(parts) for _, parts in items)

    def get_view(self, key: str) -> memoryview | None:
        status, body = self._request(OP_GET, (_pack_key(self._prefix + key),))
        if status == ST_MISSING:
            return None
        # body is a fresh per-frame buffer (never reused): a zero-copy
        # read-only view of it is safe to hand to the resolve path
        return body.toreadonly()

    def wait_for(self, key: str, timeout: float | None = None) -> None:
        body = (
            _F64.pack(-1.0 if timeout is None else timeout),
            _pack_key(self._prefix + key),
        )
        guard = None if timeout is None else timeout + _WAIT_SLACK_S
        status, _ = self._request(OP_WAIT, body, timeout=guard)
        if status == ST_TIMEOUT:
            raise TimeoutError(f"key {key!r} not set within {timeout}s")

    def wait_for_any(self, keys: Sequence[str], timeout: float | None = None) -> str:
        keys = list(keys)
        body = (
            _F64.pack(-1.0 if timeout is None else timeout),
            _U32.pack(len(keys)),
            b"".join(_pack_key(self._prefix + k) for k in keys),
        )
        guard = None if timeout is None else timeout + _WAIT_SLACK_S
        status, resp = self._request(OP_WAIT_ANY, body, timeout=guard)
        if status == ST_TIMEOUT:
            raise TimeoutError(f"none of {len(keys)} keys set within {timeout}s")
        won, _ = _unpack_key(resp, 0)
        return won[len(self._prefix):]

    def keys(self) -> Iterable[str]:
        status, body = self._request(OP_KEYS, (_pack_key(self._prefix),))
        (n,) = _U32.unpack_from(body, 0)
        off = _U32.size
        out = []
        for _ in range(n):
            k, off = _unpack_key(body, off)
            out.append(k[len(self._prefix):])
        return out

    def ping(self) -> str:
        """Round-trip liveness probe; returns ``pid:BackingType``."""
        _, body = self._request(OP_PING, ())
        return bytes(body).decode()

    def __reduce__(self):
        return (StoreServerConnector, (self.address, self.namespace))

    def __repr__(self):
        return f"StoreServerConnector({self.address!r}, ns={self.namespace!r})"
