"""The paper's contribution: transparent object proxies + three patterns.

- Proxy / Store / Connector: the low-level proxy model (paper §III).
- ProxyFuture: distributed futures for pipelining (paper §IV-A).
- StreamProducer/StreamConsumer: metadata/bulk-decoupled streaming (§IV-B).
- OwnedProxy/RefProxy/RefMutProxy + Lifetimes: ownership model (§IV-C).
"""
from repro.core import framing
from repro.core.connectors import (
    Connector,
    FileConnector,
    InMemoryConnector,
    SharedMemoryConnector,
    channel_identity,
    get_view,
    put_batch_payloads,
    put_payload,
    put_payload_new,
    wait_for,
    wait_for_any,
    wait_for_key,
    wait_for_view,
)
from repro.core.connectors_net import StoreServer, StoreServerConnector
from repro.core.executor import ProxyPolicy, StoreExecutor
from repro.core.futures import ProxyFuture, wait_all
from repro.core.lifetimes import (
    ContextLifetime,
    LeaseLifetime,
    Lifetime,
    StaticLifetime,
)
from repro.core.multi import MultiConnector, Tier
from repro.core.ownership import (
    OwnedProxy,
    OwnershipError,
    RefMutProxy,
    RefProxy,
    borrow,
    clone,
    free,
    into_owned,
    mut_borrow,
    owned_proxy,
    release,
    update,
)
from repro.core.proxy import Proxy, extract, get_factory, is_resolved, reset
from repro.core.store import (
    Store,
    StoreFactory,
    StoreMetrics,
    default_deserializer,
    default_serializer,
    invalidate_resolve_cache,
)
from repro.core.streaming import (
    FileLogPublisher,
    FileLogSubscriber,
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
    publish_event,
)

__all__ = [
    "Connector",
    "ContextLifetime",
    "FileConnector",
    "FileLogPublisher",
    "FileLogSubscriber",
    "InMemoryConnector",
    "LeaseLifetime",
    "Lifetime",
    "MultiConnector",
    "OwnedProxy",
    "OwnershipError",
    "Proxy",
    "ProxyFuture",
    "ProxyPolicy",
    "QueuePublisher",
    "QueueSubscriber",
    "RefMutProxy",
    "RefProxy",
    "SharedMemoryConnector",
    "StaticLifetime",
    "Store",
    "StoreExecutor",
    "StoreFactory",
    "StoreMetrics",
    "StoreServer",
    "StoreServerConnector",
    "StreamConsumer",
    "StreamProducer",
    "Tier",
    "borrow",
    "channel_identity",
    "clone",
    "default_deserializer",
    "default_serializer",
    "extract",
    "framing",
    "free",
    "get_factory",
    "get_view",
    "into_owned",
    "invalidate_resolve_cache",
    "is_resolved",
    "mut_borrow",
    "owned_proxy",
    "publish_event",
    "put_batch_payloads",
    "put_payload",
    "put_payload_new",
    "release",
    "reset",
    "update",
    "wait_all",
    "wait_for",
    "wait_for_any",
    "wait_for_key",
    "wait_for_view",
]
