"""The paper's contribution: transparent object proxies + three patterns.

- Proxy / Store / Connector: the low-level proxy model (paper §III).
- ProxyFuture: distributed futures for pipelining (paper §IV-A).
- StreamProducer/StreamConsumer: metadata/bulk-decoupled streaming (§IV-B).
- OwnedProxy/RefProxy/RefMutProxy + Lifetimes: ownership model (§IV-C).
"""
from repro.core.connectors import (
    Connector,
    FileConnector,
    InMemoryConnector,
    SharedMemoryConnector,
)
from repro.core.executor import ProxyPolicy, StoreExecutor
from repro.core.futures import ProxyFuture, wait_all
from repro.core.lifetimes import (
    ContextLifetime,
    LeaseLifetime,
    Lifetime,
    StaticLifetime,
)
from repro.core.ownership import (
    OwnedProxy,
    OwnershipError,
    RefMutProxy,
    RefProxy,
    borrow,
    clone,
    free,
    into_owned,
    mut_borrow,
    owned_proxy,
    release,
    update,
)
from repro.core.proxy import Proxy, extract, get_factory, is_resolved, reset
from repro.core.store import Store, StoreFactory
from repro.core.streaming import (
    FileLogPublisher,
    FileLogSubscriber,
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)

__all__ = [
    "Connector",
    "ContextLifetime",
    "FileConnector",
    "FileLogPublisher",
    "FileLogSubscriber",
    "InMemoryConnector",
    "LeaseLifetime",
    "Lifetime",
    "OwnedProxy",
    "OwnershipError",
    "Proxy",
    "ProxyFuture",
    "ProxyPolicy",
    "QueuePublisher",
    "QueueSubscriber",
    "RefMutProxy",
    "RefProxy",
    "SharedMemoryConnector",
    "StaticLifetime",
    "Store",
    "StoreExecutor",
    "StoreFactory",
    "StreamConsumer",
    "StreamProducer",
    "borrow",
    "clone",
    "extract",
    "free",
    "get_factory",
    "into_owned",
    "is_resolved",
    "mut_borrow",
    "owned_proxy",
    "release",
    "reset",
    "update",
    "wait_all",
]
