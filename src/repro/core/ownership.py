"""Ownership pattern — Rust-style borrowing for proxies (paper §IV-C).

Three proxy reference types with runtime-enforced rules:

- :class:`OwnedProxy` — sole owner; target evicted when it goes out of scope.
- :class:`RefProxy` — immutable borrow; any number may exist at a time.
- :class:`RefMutProxy` — mutable borrow; at most one, and never alongside
  RefProxies.

Rules (c.f. Rust): one owner per global object; a value is deleted when its
owner goes out of scope; borrows must not outlive the owner.  Violations
raise :class:`OwnershipError` at runtime.

Free functions (paper Listing 3 prefers functions over methods so target
attributes are never clobbered): ``owned_proxy``, ``into_owned``, ``borrow``,
``mut_borrow``, ``clone``, ``update``, ``release``, ``free``.
"""
from __future__ import annotations

import threading
from typing import Any, TypeVar

from repro.core import sanitize as _sanitize
from repro.core.connectors import new_key
from repro.core.proxy import Proxy, _resolve, is_resolved
from repro.core.store import Store, StoreFactory, invalidate_resolve_cache

T = TypeVar("T")


class OwnershipError(RuntimeError):
    """Violation of ownership/borrowing rules."""


class _RefState:
    """Client-side bookkeeping shared by an owner and its borrows."""

    __slots__ = (
        "store_name",
        "connector",
        "key",
        "lock",
        "refs",
        "mut_ref",
        "valid",
        "moved",
    )

    def __init__(self, store_name: str, connector, key: str):
        self.store_name = store_name
        self.connector = connector
        self.key = key
        self.lock = threading.Lock()
        self.refs: set[str] = set()  # outstanding immutable borrow tokens
        self.mut_ref: str | None = None  # outstanding mutable borrow token
        self.valid = True  # False once freed
        self.moved = False  # True once ownership yielded elsewhere


def _state(p: Proxy) -> _RefState:
    st = object.__getattribute__(p, "__owner_state__")
    if st is None:
        raise OwnershipError("proxy has no ownership state")
    return st


def _codec_of(p: Proxy) -> tuple:
    """(serializer, deserializer) carried by a proxy's factory."""
    f = object.__getattribute__(p, "__factory__")
    return f.serializer, f.deserializer


def _mk(
    cls,
    state: _RefState,
    *,
    token: str | None = None,
    remote: bool = False,
    serializer=None,
    deserializer=None,
) -> Proxy:
    # Owners and mutable borrows resolve writable private copies (their
    # contract is mutate-then-update); immutable RefProxies keep the
    # zero-copy read-only view, which *enforces* the no-mutation rule for
    # array targets.
    factory = StoreFactory(
        state.key,
        state.store_name,
        state.connector,
        serializer=serializer,
        deserializer=deserializer,
        writable=cls is not RefProxy,
    )
    p = Proxy.__new__(cls)
    object.__setattr__(p, "__factory__", factory)
    from repro.core.proxy import _UNRESOLVED

    object.__setattr__(p, "__target_cache__", _UNRESOLVED)
    object.__setattr__(
        p,
        "__proxy_metadata__",
        {"key": state.key, "store": state.store_name, "token": token, "remote": remote},
    )
    object.__setattr__(p, "__owner_state__", state)
    return p


class OwnedProxy(Proxy[T]):
    """Owning reference: exactly one per global object; frees on del."""

    def __del__(self):
        try:
            st = object.__getattribute__(self, "__owner_state__")
        except Exception:
            return
        if st is None or st.moved or not st.valid:
            return
        if st.refs or st.mut_ref:
            # Out-of-scope owner with live borrows: rule violation.  __del__
            # exceptions don't propagate, so record + raise for visibility.
            st.valid = False
            raise OwnershipError(
                f"OwnedProxy({st.key}) destroyed while borrows outstanding: "
                f"{len(st.refs)} refs, mut={st.mut_ref is not None}"
            )
        st.valid = False
        try:
            st.connector.evict(st.key)
            invalidate_resolve_cache(st.store_name, st.key)
            san = _sanitize.active_for(st.store_name)
            if san:
                san.on_own_free(st.store_name, st.connector, st.key, via="owned-del")
        except Exception:
            pass

    def __reduce__(self):
        # Pickling an OwnedProxy transfers ownership: the local copy is
        # marked moved (its __del__ becomes a no-op) and the remote side
        # reconstructs a full owner.
        st = _state(self)
        with st.lock:
            if st.refs or st.mut_ref:
                raise OwnershipError(
                    f"cannot move OwnedProxy({st.key}) while borrows outstanding"
                )
            if not st.valid:
                raise OwnershipError(f"use of freed OwnedProxy({st.key})")
            st.moved = True
        san = _sanitize.active_for(st.store_name)
        if san:
            san.on_move(st.connector, st.key)
        ser, de = _codec_of(self)
        return (_rebuild_owned, (st.store_name, st.connector, st.key, de, ser))


class RefProxy(Proxy[T]):
    """Immutable borrow: read-only view; release on del / task completion."""

    def __del__(self):
        try:
            st = object.__getattribute__(self, "__owner_state__")
            meta = object.__getattribute__(self, "__proxy_metadata__")
        except Exception:
            return
        if st is None or meta.get("remote"):
            return
        with st.lock:
            st.refs.discard(meta.get("token"))

    def __reduce__(self):
        # A pickled borrow is detached: the remote copy does not decrement
        # on deletion — the client-side executor releases via callback when
        # the task completes (paper: "a reference passed to a task goes out
        # of scope when the task completes").
        st = _state(self)
        meta = object.__getattribute__(self, "__proxy_metadata__")
        ser, de = _codec_of(self)
        return (
            _rebuild_borrow,
            (type(self), st.store_name, st.connector, st.key, meta.get("token"),
             de, ser),
        )


class RefMutProxy(Proxy[T]):
    """Mutable borrow: sole writer; must be released (or task-completed)."""

    def __del__(self):
        try:
            st = object.__getattribute__(self, "__owner_state__")
            meta = object.__getattribute__(self, "__proxy_metadata__")
        except Exception:
            return
        if st is None or meta.get("remote"):
            return
        with st.lock:
            if st.mut_ref == meta.get("token"):
                st.mut_ref = None

    __reduce__ = RefProxy.__reduce__


def _rebuild_owned(store_name, connector, key, deserializer=None, serializer=None):
    st = _RefState(store_name, connector, key)
    san = _sanitize.active_for(store_name)
    if san:
        san.on_own_mint(store_name, connector, key)
    return _mk(OwnedProxy, st, serializer=serializer, deserializer=deserializer)


def _rebuild_borrow(cls, store_name, connector, key, token,
                    deserializer=None, serializer=None):
    st = _RefState(store_name, connector, key)
    return _mk(cls, st, token=token, remote=True,
               serializer=serializer, deserializer=deserializer)


# ---------------------------------------------------------------------------
# Free functions (paper Listing 3)
# ---------------------------------------------------------------------------


def owned_proxy(store: Store, obj: T, *, key: str | None = None) -> OwnedProxy[T]:
    """Serialize ``obj`` into the store and return its (sole) owner proxy."""
    key = store.put(obj, key=key)
    st = _RefState(store.name, store.connector, key)
    san = _sanitize.active_for(store.name)
    if san:
        san.on_own_mint(store.name, store.connector, key)
    return _mk(OwnedProxy, st,
               serializer=store._carried_serializer(),
               deserializer=store._carried_deserializer())


def into_owned(proxy: Proxy[T]) -> OwnedProxy[T]:
    """Promote a plain proxy to an owned one (caller asserts uniqueness)."""
    if isinstance(proxy, (OwnedProxy, RefProxy, RefMutProxy)):
        raise OwnershipError("proxy already participates in ownership")
    meta = object.__getattribute__(proxy, "__proxy_metadata__")
    factory = object.__getattribute__(proxy, "__factory__")
    if not isinstance(factory, StoreFactory):
        raise OwnershipError("only store-backed proxies can become owned")
    st = _RefState(meta["store"], factory.connector, meta["key"])
    san = _sanitize.active_for(meta["store"])
    if san:
        san.on_own_mint(meta["store"], factory.connector, meta["key"])
    return _mk(OwnedProxy, st,
               serializer=factory.serializer, deserializer=factory.deserializer)


def borrow(owner: OwnedProxy[T]) -> RefProxy[T]:
    st = _state(owner)
    with st.lock:
        if not st.valid or st.moved:
            raise OwnershipError(f"borrow of invalid/moved OwnedProxy({st.key})")
        if st.mut_ref is not None:
            raise OwnershipError(
                f"cannot borrow OwnedProxy({st.key}): mutable borrow outstanding"
            )
        token = new_key()
        st.refs.add(token)
    san = _sanitize.active_for(st.store_name)
    if san:
        san.on_borrow(st.connector, st.key, token, mut=False)
    ser, de = _codec_of(owner)
    return _mk(RefProxy, st, token=token, serializer=ser, deserializer=de)


def mut_borrow(owner: OwnedProxy[T]) -> RefMutProxy[T]:
    st = _state(owner)
    with st.lock:
        if not st.valid or st.moved:
            raise OwnershipError(f"mut_borrow of invalid/moved OwnedProxy({st.key})")
        if st.mut_ref is not None or st.refs:
            raise OwnershipError(
                f"cannot mut_borrow OwnedProxy({st.key}): borrows outstanding "
                f"({len(st.refs)} refs, mut={st.mut_ref is not None})"
            )
        token = new_key()
        st.mut_ref = token
    san = _sanitize.active_for(st.store_name)
    if san:
        san.on_borrow(st.connector, st.key, token, mut=True)
    ser, de = _codec_of(owner)
    return _mk(RefMutProxy, st, token=token, serializer=ser, deserializer=de)


def clone(owner: OwnedProxy[T]) -> OwnedProxy[T]:
    """Deep-copy the global object under a fresh key with a fresh owner."""
    st = _state(owner)
    if not st.valid:
        raise OwnershipError(f"clone of freed OwnedProxy({st.key})")
    data = st.connector.get(st.key)
    if data is None:
        raise OwnershipError(f"target of OwnedProxy({st.key}) missing")
    nk = new_key()
    st.connector.put(nk, data)
    san = _sanitize.active_for(st.store_name)
    if san:
        san.on_own_mint(st.store_name, st.connector, nk)
    ser, de = _codec_of(owner)
    return _mk(OwnedProxy, _RefState(st.store_name, st.connector, nk),
               serializer=ser, deserializer=de)


def update(proxy: Proxy[T]) -> None:
    """Write the locally-mutated resolved copy back to the global store.

    Allowed for owners (no outstanding borrows) and mutable borrows only.
    """
    st = _state(proxy)
    if isinstance(proxy, RefProxy):
        raise OwnershipError("cannot update through an immutable RefProxy")
    if isinstance(proxy, OwnedProxy):
        with st.lock:
            if st.mut_ref is not None:
                raise OwnershipError(
                    "owner cannot update while a mutable borrow is outstanding"
                )
    if not is_resolved(proxy):
        raise OwnershipError("nothing to update: proxy never resolved/mutated")
    ser, de = _codec_of(proxy)
    # reattach with the carried codec pair so the write-back is encoded the
    # way every reader of this key will decode it
    store = Store.get_or_reattach(
        st.store_name, st.connector, serializer=ser, deserializer=de
    )
    store.put(_resolve(proxy), key=st.key)


def release(ref: RefProxy | RefMutProxy) -> None:
    """Explicitly end a borrow (idempotent)."""
    st = _state(ref)
    meta = object.__getattribute__(ref, "__proxy_metadata__")
    token = meta.get("token")
    was_remote = meta.get("remote")
    with st.lock:
        st.refs.discard(token)
        if st.mut_ref == token:
            st.mut_ref = None
    meta["remote"] = True  # disarm __del__
    if not was_remote:  # remote copies never saw the mint; don't false-flag
        san = _sanitize.active_for(st.store_name)
        if san:
            san.on_release(st.store_name, st.connector, st.key, token)


def release_by_token(st: _RefState, token: str) -> None:
    with st.lock:
        st.refs.discard(token)
        if st.mut_ref == token:
            st.mut_ref = None
    san = _sanitize.active_for(st.store_name)
    if san:
        san.on_release(st.store_name, st.connector, st.key, token)


def free(owner: OwnedProxy) -> None:
    """Explicitly free the owned object (what going out of scope does)."""
    st = _state(owner)
    with st.lock:
        if not st.valid:
            # Forgiving API (double-free is a no-op), but under ProxySan the
            # second free is exactly the misuse the sanitizer exists to flag.
            san = _sanitize.active_for(st.store_name)
            if san:
                san.on_double_free(st.store_name, st.connector, st.key)
            return
        if st.moved:
            raise OwnershipError(f"free of moved OwnedProxy({st.key})")
        if st.refs or st.mut_ref:
            raise OwnershipError(
                f"free of OwnedProxy({st.key}) while borrows outstanding"
            )
        st.valid = False
    st.connector.evict(st.key)
    invalidate_resolve_cache(st.store_name, st.key)
    san = _sanitize.active_for(st.store_name)
    if san:
        san.on_own_free(st.store_name, st.connector, st.key, via="owned-free")


def is_valid(p: Proxy) -> bool:
    try:
        st = _state(p)
    except OwnershipError:
        return False
    return st.valid and not st.moved


def num_borrows(owner: OwnedProxy) -> tuple[int, bool]:
    st = _state(owner)
    with st.lock:
        return len(st.refs), st.mut_ref is not None
