"""Transparent lazy object proxy (paper §III).

A :class:`Proxy` wraps a *factory* — a zero-argument callable returning the
target object.  The proxy forwards every operation to the target, resolving
it just-in-time on first use and caching it locally.  Transparency means
``isinstance(p, type(target))`` is true because ``__class__`` is forwarded.

This is the low-level building block on which the three paper patterns
(futures, streaming, ownership) are built.
"""
from __future__ import annotations

import operator
from typing import Any, Callable, Generic, TypeVar

T = TypeVar("T")

_UNRESOLVED = object()

# Attributes that live on the proxy itself, never forwarded.
_PROXY_SLOTS = frozenset(
    (
        "__factory__",
        "__target_cache__",
        "__proxy_metadata__",
        "__owner_state__",  # used by the ownership pattern (ownership.py)
    )
)


class Factory(Generic[T]):
    """Base factory: callable that materializes the target object.

    Factories must be serializable (picklable) so proxies can travel across
    process/machine boundaries and still resolve (paper §III: "no external
    information is required to resolve a proxy").
    """

    def __call__(self) -> T:  # pragma: no cover - interface
        raise NotImplementedError


class SimpleFactory(Factory[T]):
    """Factory wrapping an already-available object (eager proxy)."""

    def __init__(self, obj: T):
        self.obj = obj

    def __call__(self) -> T:
        return self.obj


def _resolve(proxy: "Proxy") -> Any:
    tgt = object.__getattribute__(proxy, "__target_cache__")
    if tgt is _UNRESOLVED:
        factory = object.__getattribute__(proxy, "__factory__")
        tgt = factory()
        object.__setattr__(proxy, "__target_cache__", tgt)
    return tgt


def is_resolved(proxy: "Proxy") -> bool:
    return object.__getattribute__(proxy, "__target_cache__") is not _UNRESOLVED


def extract(proxy: "Proxy") -> Any:
    """Return the resolved target object (resolving if needed)."""
    return _resolve(proxy)


def get_factory(proxy: "Proxy") -> Factory:
    return object.__getattribute__(proxy, "__factory__")


def reset(proxy: "Proxy") -> None:
    """Drop the locally cached target so the next use re-resolves."""
    object.__setattr__(proxy, "__target_cache__", _UNRESOLVED)


class Proxy(Generic[T]):
    """Lazy transparent object proxy.

    ``Proxy(factory)`` defers ``factory()`` until the first operation on the
    proxy.  All dunder/attribute/operator traffic forwards to the target.
    """

    def __init__(self, factory: Callable[[], T], *, metadata: dict | None = None):
        object.__setattr__(self, "__factory__", factory)
        object.__setattr__(self, "__target_cache__", _UNRESOLVED)
        object.__setattr__(self, "__proxy_metadata__", metadata or {})

    # -- pickling: a proxy serializes as (factory, metadata); the cached
    # target is intentionally dropped (pass-by-reference semantics).
    def __reduce__(self):
        return (
            _reconstruct_proxy,
            (
                object.__getattribute__(self, "__factory__"),
                object.__getattribute__(self, "__proxy_metadata__"),
                type(self),
            ),
        )

    def __reduce_ex__(self, protocol):
        return self.__reduce__()

    # -- attribute protocol ------------------------------------------------
    def __getattribute__(self, name):
        if name in _PROXY_SLOTS or name in ("__reduce__", "__reduce_ex__", "__init__"):
            return object.__getattribute__(self, name)
        if name == "__class__":
            return type(_resolve(self))
        return getattr(_resolve(self), name)

    def __setattr__(self, name, value):
        if name in _PROXY_SLOTS:
            object.__setattr__(self, name, value)
        else:
            setattr(_resolve(self), name, value)

    def __delattr__(self, name):
        delattr(_resolve(self), name)

    # -- repr / str ---------------------------------------------------------
    def __repr__(self):
        if is_resolved(self):
            return repr(_resolve(self))
        return f"<Proxy unresolved factory={object.__getattribute__(self, '__factory__')!r}>"

    def __str__(self):
        return str(_resolve(self))

    def __format__(self, spec):
        return format(_resolve(self), spec)

    # -- comparison / hashing ------------------------------------------------
    def __eq__(self, other):
        return _resolve(self) == other

    def __ne__(self, other):
        return _resolve(self) != other

    def __lt__(self, other):
        return _resolve(self) < other

    def __le__(self, other):
        return _resolve(self) <= other

    def __gt__(self, other):
        return _resolve(self) > other

    def __ge__(self, other):
        return _resolve(self) >= other

    def __hash__(self):
        return hash(_resolve(self))

    def __bool__(self):
        return bool(_resolve(self))

    # -- containers -----------------------------------------------------------
    def __len__(self):
        return len(_resolve(self))

    def __getitem__(self, k):
        return _resolve(self)[k]

    def __setitem__(self, k, v):
        _resolve(self)[k] = v

    def __delitem__(self, k):
        del _resolve(self)[k]

    def __iter__(self):
        return iter(_resolve(self))

    def __contains__(self, item):
        return item in _resolve(self)

    def __next__(self):
        return next(_resolve(self))

    # -- callables -------------------------------------------------------------
    def __call__(self, *args, **kwargs):
        return _resolve(self)(*args, **kwargs)

    # -- numeric protocol --------------------------------------------------------
    def __add__(self, o):
        return _resolve(self) + o

    def __radd__(self, o):
        return o + _resolve(self)

    def __sub__(self, o):
        return _resolve(self) - o

    def __rsub__(self, o):
        return o - _resolve(self)

    def __mul__(self, o):
        return _resolve(self) * o

    def __rmul__(self, o):
        return o * _resolve(self)

    def __truediv__(self, o):
        return _resolve(self) / o

    def __rtruediv__(self, o):
        return o / _resolve(self)

    def __floordiv__(self, o):
        return _resolve(self) // o

    def __rfloordiv__(self, o):
        return o // _resolve(self)

    def __mod__(self, o):
        return _resolve(self) % o

    def __rmod__(self, o):
        return o % _resolve(self)

    def __pow__(self, o):
        return _resolve(self) ** o

    def __rpow__(self, o):
        return o ** _resolve(self)

    def __matmul__(self, o):
        return operator.matmul(_resolve(self), o)

    def __rmatmul__(self, o):
        return operator.matmul(o, _resolve(self))

    def __neg__(self):
        return -_resolve(self)

    def __pos__(self):
        return +_resolve(self)

    def __abs__(self):
        return abs(_resolve(self))

    def __invert__(self):
        return ~_resolve(self)

    def __and__(self, o):
        return _resolve(self) & o

    def __rand__(self, o):
        return o & _resolve(self)

    def __or__(self, o):
        return _resolve(self) | o

    def __ror__(self, o):
        return o | _resolve(self)

    def __xor__(self, o):
        return _resolve(self) ^ o

    def __rxor__(self, o):
        return o ^ _resolve(self)

    def __lshift__(self, o):
        return _resolve(self) << o

    def __rshift__(self, o):
        return _resolve(self) >> o

    def __int__(self):
        return int(_resolve(self))

    def __float__(self):
        return float(_resolve(self))

    def __index__(self):
        return operator.index(_resolve(self))

    def __round__(self, n=None):
        return round(_resolve(self), n) if n is not None else round(_resolve(self))

    # -- numpy/jax interop: forward the array protocol so a Proxy of an
    # ndarray can be consumed by jnp/np functions directly.
    def __array__(self, dtype=None, copy=None):
        import numpy as np

        tgt = _resolve(self)
        arr = np.asarray(tgt)
        if dtype is not None:
            arr = arr.astype(dtype, copy=False)
        return arr

    @property
    def __array_interface__(self):  # pragma: no cover - numpy internal path
        return _resolve(self).__array_interface__

    def __jax_array__(self):
        import jax.numpy as jnp

        return jnp.asarray(_resolve(self))

    # -- context manager --------------------------------------------------------
    def __enter__(self):
        return _resolve(self).__enter__()

    def __exit__(self, *exc):
        return _resolve(self).__exit__(*exc)


def _reconstruct_proxy(factory, metadata, cls):
    # Ownership proxies override pickling; plain proxies rebuild lazily.
    p = Proxy.__new__(cls)
    object.__setattr__(p, "__factory__", factory)
    object.__setattr__(p, "__target_cache__", _UNRESOLVED)
    object.__setattr__(p, "__proxy_metadata__", metadata or {})
    return p
