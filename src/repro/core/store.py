"""High-level Store interface (paper §III).

``Store.proxy(obj)`` serializes the target, puts it in the mediated channel
via the connector, builds a :class:`StoreFactory` with the metadata needed
for just-in-time retrieval, and returns a transparent :class:`Proxy`.

The store also exposes the three pattern entry points:
``future()`` (§IV-A), stream producers/consumers consume stores directly
(§IV-B), and ``owned_proxy()`` (§IV-C).

Hot path (see :mod:`repro.core.framing`): the default serializer frames
payloads as ``header || pickle || raw buffers`` (pickle protocol 5
out-of-band), puts go through the connector's vectored ``put_parts`` when
available, resolves read zero-copy ``get_view`` memoryviews, and resolved
targets are kept in a per-store LRU cache so a warm re-resolve never touches
the channel.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Generic, Sequence, TypeVar

from repro.core import framing
from repro.core import sanitize as _sanitize
from repro.core.connectors import (
    Connector,
    InMemoryConnector,
    get_payload,
    new_key,
    put_batch_payloads,
    put_payload,
    put_payload_new,
    wait_for_payload,
)
from repro.core.connectors import (
    wait_for as connectors_wait_for,
    wait_for_any as connectors_wait_for_any,
)
from repro.core.proxy import Factory, Proxy

T = TypeVar("T")

# ---------------------------------------------------------------------------
# Serialization entry points.  The default pair speaks the framed zero-copy
# format; both remain plain ``obj <-> bytes`` callables so custom
# serializers slot in unchanged.
# ---------------------------------------------------------------------------


def default_serializer(obj: Any) -> bytes:
    return framing.join_parts(framing.encode(obj))


def default_deserializer(data: bytes) -> Any:
    # Accepts framed payloads *and* legacy plain pickles (pre-framing data).
    return framing.decode(data)


# Marks a deserializer as accepting memoryviews (zero-copy resolve path);
# custom bytes-only deserializers are fed a one-time copy instead.
default_deserializer.accepts_buffers = True  # type: ignore[attr-defined]


# ---------------------------------------------------------------------------


@dataclass
class StoreMetrics:
    """Instrumentation used by the paper-style benchmarks."""

    put_count: int = 0
    put_bytes: int = 0
    put_time: float = 0.0
    get_count: int = 0
    get_bytes: int = 0
    get_time: float = 0.0
    evict_count: int = 0
    cache_hits: int = 0
    cache_misses: int = 0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


_MISS = object()
_RAISE = object()


class _ResolveCache:
    """Thread-safe LRU of resolved targets, keyed ``(key, deserializer)``.

    The deserializer participates in the key so one channel key resolved
    under two different deserializers never aliases; invalidation is by
    channel key alone (an evict must drop every variant).
    """

    def __init__(self, maxsize: int):
        self.maxsize = max(0, maxsize)
        self._data: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        # Bumped by every invalidate/clear.  A resolver snapshots the
        # generation before fetching and inserts with set_if, so a resolve
        # that raced an overwrite/evict can never install a stale object.
        self.generation = 0

    def get(self, key: tuple) -> Any:
        if not self._data:  # lock-free miss fast path (hot on evicting flows)
            return _MISS
        with self._lock:
            try:
                self._data.move_to_end(key)
            except KeyError:
                return _MISS
            return self._data[key]

    def set_if(self, key: tuple, value: Any, generation: int) -> None:
        if self.maxsize == 0:
            return
        with self._lock:
            if self.generation != generation:
                return  # an invalidate raced the fetch; don't cache
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)

    def invalidate(self, channel_key: str) -> None:
        with self._lock:
            self.generation += 1  # even when empty: an in-flight set_if must lose
            for k in [k for k in self._data if k[0] == channel_key]:
                del self._data[k]

    def clear(self) -> None:
        with self._lock:
            self.generation += 1
            self._data.clear()

    def __len__(self) -> int:
        return len(self._data)


_STORE_REGISTRY: dict[str, "Store"] = {}
_REGISTRY_LOCK = threading.Lock()


def _same_codec(a, b) -> bool:
    """True when two codec callables are interchangeable.

    Identity fails for codecs that don't unpickle to the same object
    (functools.partial, callable instances); their pickled forms still
    agree, so compare those before declaring a conflict.
    """
    import pickle as _pickle

    try:
        return _pickle.dumps(a) == _pickle.dumps(b)
    except Exception:
        return False


def invalidate_resolve_cache(store_name: str, key: str) -> None:
    """Drop ``key`` from the named store's resolve cache, if registered.

    Connector-level evicts (ownership ``free``, stream skip-evicts) bypass
    :meth:`Store.evict`; they call this so a cached resolve can never serve
    a freed object.
    """
    st = _STORE_REGISTRY.get(store_name)
    if st is not None:
        st._cache.invalidate(key)


class StoreFactory(Factory[T]):
    """Factory that retrieves a serialized target from a mediated channel.

    Self-contained: carries the store name + connector (picklable) and, when
    the originating store used a non-default serializer, the matching
    deserializer — so a proxy resolves anywhere with "no external
    information" (paper §III) *and* with the right codec even if the far
    side reattached the store with defaults.
    """

    def __init__(
        self,
        key: str,
        store_name: str,
        connector: Connector,
        *,
        evict_on_resolve: bool = False,
        block: bool = False,
        timeout: float | None = None,
        deserializer: Callable[[bytes], Any] | None = None,
        serializer: Callable[[Any], bytes] | None = None,
        writable: bool = False,
    ):
        self.key = key
        self.store_name = store_name
        self.connector = connector
        self.evict_on_resolve = evict_on_resolve
        self.block = block
        self.timeout = timeout
        self.deserializer = deserializer
        # not used to resolve; carried so write-back paths (ownership
        # update) can reattach the store with the matching encoder
        self.serializer = serializer
        self.writable = writable

    def __call__(self) -> T:
        store = Store.get_or_reattach(self.store_name, self.connector)
        return store.resolve(
            self.key,
            deserializer=self.deserializer,
            block=self.block,
            timeout=self.timeout,
            evict_on_resolve=self.evict_on_resolve,
            writable=self.writable,
        )

    def __repr__(self):
        return f"StoreFactory(key={self.key!r}, store={self.store_name!r})"


class Store(Generic[T]):
    """High-level interface for creating proxies of objects."""

    def __init__(
        self,
        name: str,
        connector: Connector | None = None,
        *,
        serializer: Callable[[Any], bytes] = default_serializer,
        deserializer: Callable[[bytes], Any] = default_deserializer,
        cache_size: int = 16,
        timed_metrics: bool = True,
        register: bool = True,
        sanitize: bool | None = None,
    ):
        self.name = name
        self.connector = connector if connector is not None else InMemoryConnector(name)
        self.serializer = serializer
        self.deserializer = deserializer
        self.cache_size = cache_size
        self._cache = _ResolveCache(cache_size)
        self.metrics = StoreMetrics()
        # ProxySan tri-state: True opts this store in, None follows
        # REPRO_PROXYSAN, False opts out (durable stores — checkpoint
        # chunks are artifacts, not leaks).  _san None keeps every hook
        # below a single falsy test.
        self._san = _sanitize.store_sanitizer(name, sanitize)
        # One-bool guard around the perf_counter pairs on put/resolve:
        # counts/bytes are always kept (cheap adds), the clock reads are
        # skippable fixed overhead on the tiny-object hot path.
        self._timed = timed_metrics
        self._closed = False
        if register:
            with _REGISTRY_LOCK:
                _STORE_REGISTRY[name] = self

    # -- registry ------------------------------------------------------------
    @classmethod
    def get_or_reattach(
        cls,
        name: str,
        connector: Connector,
        *,
        serializer: Callable[[Any], bytes] | None = None,
        deserializer: Callable[[bytes], Any] | None = None,
    ) -> "Store":
        # Lock-free fast path (resolve hot path); double-checked construction
        # under the lock so two racing reattaches can't clobber each other.
        st = _STORE_REGISTRY.get(name)
        if st is None:
            with _REGISTRY_LOCK:
                st = _STORE_REGISTRY.get(name)
                if st is None:
                    st = cls(
                        name,
                        connector,
                        serializer=serializer or default_serializer,
                        deserializer=deserializer or default_deserializer,
                        register=False,
                    )
                    _STORE_REGISTRY[name] = st
                    return st
        if serializer is not None or deserializer is not None:
            st._adopt_codec(serializer, deserializer)
        return st

    def _adopt_codec(self, serializer, deserializer) -> None:
        """Reconcile a carried custom codec with an already-registered store.

        A plain resolve may have registered this store with defaults before
        the pickled original (carrying the real codec) arrived; upgrade the
        defaults in place.  Two *different* custom codecs for one store name
        is unreconcilable — fail loudly rather than corrupt payloads.
        """
        for attr, new, default in (
            ("serializer", serializer, default_serializer),
            ("deserializer", deserializer, default_deserializer),
        ):
            if new is None:
                continue
            cur = getattr(self, attr)
            if cur is default:
                setattr(self, attr, new)
            elif cur is not new and not _same_codec(cur, new):
                raise ValueError(
                    f"store {self.name!r} reattached with a conflicting "
                    f"custom {attr} ({cur!r} vs {new!r})"
                )

    # -- codec ---------------------------------------------------------------
    def _encode(self, obj: Any) -> Sequence:
        """Serialize to framed parts (vectored; raw buffers uncopied)."""
        if self.serializer is default_serializer:
            return framing.encode(obj)
        return (self.serializer(obj),)

    def _decode(
        self,
        payload,
        deserializer: Callable[[bytes], Any] | None = None,
        *,
        writable: bool = False,
    ) -> Any:
        deserializer = deserializer or self.deserializer
        if deserializer is default_deserializer:
            # framing.decode consumes both forms zero-copy: a contiguous
            # view *or* a framed-parts tuple (in-memory pass-by-reference)
            return framing.decode(payload, writable=writable)
        if isinstance(payload, (tuple, list)):
            payload = framing.join_parts(payload)
        elif isinstance(payload, memoryview) and not getattr(
            deserializer, "accepts_buffers", False
        ):
            payload = payload.tobytes()  # custom codecs get an owned copy
        return deserializer(payload)

    def _carried_deserializer(self) -> Callable[[bytes], Any] | None:
        return None if self.deserializer is default_deserializer else self.deserializer

    def _carried_serializer(self) -> Callable[[Any], bytes] | None:
        return None if self.serializer is default_serializer else self.serializer

    # -- raw k/v --------------------------------------------------------------
    def put(self, obj: Any, key: str | None = None) -> str:
        # A freshly minted key can never have a cached resolve (nobody has
        # seen it), so the invalidate — a lock acquire plus a generation
        # bump that would kill unrelated in-flight cache fills — only runs
        # for caller-supplied keys (potential overwrites).
        fresh = key is None
        if fresh:
            key = new_key()
        parts = self._encode(obj)
        m = self.metrics
        if self._timed:
            t0 = time.perf_counter()
            nbytes = put_payload(self.connector, key, parts)
            m.put_time += time.perf_counter() - t0
        else:
            nbytes = put_payload(self.connector, key, parts)
        m.put_count += 1
        m.put_bytes += nbytes
        if not fresh:
            self._cache.invalidate(key)  # overwrite must not serve a stale resolve
        if self._san:
            self._san.on_put(self.name, self.connector, key, overwrite=not fresh)
        return key

    def put_if_absent(self, obj: Any, key: str) -> bool:
        """Atomic put-unless-exists; ``False`` when the key was already set.

        One connector round trip (``put_parts_new``: dict setdefault,
        ``link(2)``, shm exclusive create) — the single-writer arbitration
        behind ``ProxyFuture.set_result``.
        """
        parts = self._encode(obj)
        m = self.metrics
        if self._timed:
            t0 = time.perf_counter()
            nbytes = put_payload_new(self.connector, key, parts)
            if nbytes is None:
                return False
            m.put_time += time.perf_counter() - t0
        else:
            nbytes = put_payload_new(self.connector, key, parts)
            if nbytes is None:
                return False
        m.put_count += 1
        m.put_bytes += nbytes
        self._cache.invalidate(key)  # key may have been cached before an evict
        if self._san:
            self._san.on_put(self.name, self.connector, key)
        return True

    def put_batch(self, objs: Sequence[Any], *, keys: Sequence[str] | None = None) -> list[str]:
        """Amortized multi-object put (one connector round for the batch)."""
        objs = list(objs)  # a generator must not be exhausted minting keys
        fresh = keys is None
        keys = list(keys) if keys is not None else [new_key() for _ in objs]
        items = [(k, self._encode(o)) for k, o in zip(keys, objs)]
        t0 = time.perf_counter()
        nbytes = put_batch_payloads(self.connector, items)
        m = self.metrics
        m.put_time += time.perf_counter() - t0
        m.put_count += len(items)
        m.put_bytes += nbytes
        if not fresh:  # minted keys can't be cached anywhere yet
            for k in keys:
                self._cache.invalidate(k)
        if self._san:
            for k in keys:
                self._san.on_put(self.name, self.connector, k, overwrite=not fresh)
        return keys

    def resolve(
        self,
        key: str,
        *,
        deserializer: Callable[[bytes], Any] | None = None,
        block: bool = False,
        timeout: float | None = None,
        evict_on_resolve: bool = False,
        writable: bool = False,
        fresh: bool = False,
        default: Any = _RAISE,
    ) -> Any:
        """The one resolve hot path (factories, futures, and ``get`` all
        land here): resolve-cache probe → zero-copy fetch → decode →
        metrics → cache fill (generation-guarded against racing evicts).

        ``writable`` resolves (ownership mutation paths) decode private
        copies and bypass the cache entirely — a cached object is shared,
        so it must never be handed to a mutator, and a mutator's copy must
        never be served to readers.  ``fresh`` also bypasses the cache:
        it is for *mutable-key* reads (lease renewals, config cells) where
        another process or store instance may have re-put the key — cache
        invalidation is in-process only.

        Contract: cached resolves of the same key return the *same* object.
        Framed arrays are read-only (enforced); plain Python containers are
        shared by convention — treat resolved objects as immutable, and
        mutate through ownership proxies (``writable`` private copies) or
        re-read with ``fresh=True``/``writable=True`` when isolation
        matters.
        """
        deserializer = deserializer or self.deserializer
        bypass = writable or fresh
        obj = _MISS
        if not bypass:
            obj = self._cache.get((key, deserializer))
        if obj is not _MISS:
            self.metrics.cache_hits += 1
            if self._san:
                self._san.on_resolve(self.name, self.connector, key, hit=True)
        else:
            self.metrics.cache_misses += 1
            gen = self._cache.generation
            timed = self._timed
            if timed:
                t0 = time.perf_counter()  # before any wait: blocking is fetch time
            if block:
                payload = wait_for_payload(self.connector, key, timeout=timeout)
            else:
                payload = get_payload(self.connector, key)
                if payload is None:
                    if self._san:
                        self._san.on_resolve_missing(self.name, self.connector, key)
                    if default is not _RAISE:
                        return default
                    raise KeyError(
                        f"proxy target {key!r} missing from store "
                        f"{self.name!r} (freed early? see ownership rules)"
                    )
            obj = self._decode(payload, deserializer, writable=writable)
            self.metrics.get_count += 1
            self.metrics.get_bytes += (
                framing.parts_nbytes(payload)
                if isinstance(payload, (tuple, list))
                else payload.nbytes
            )
            if timed:
                self.metrics.get_time += time.perf_counter() - t0
            if not (evict_on_resolve or bypass):
                self._cache.set_if((key, deserializer), obj, gen)
                if self._san:
                    self._san.on_resolve(self.name, self.connector, key, hit=False)
        if evict_on_resolve:
            # also on a cache hit: the one-shot contract reclaims the payload
            self.connector.evict(key)
            self._cache.invalidate(key)
            if self._san:
                self._san.on_evict(self.name, self.connector, key, via="resolve-evict")
        return obj

    def get(self, key: str, default: Any = None, *, fresh: bool = False) -> Any:
        # missing key → default; a deserializer failure still propagates
        return self.resolve(key, default=default, fresh=fresh)

    def exists(self, key: str) -> bool:
        return self.connector.exists(key)

    def wait_for(self, key: str, timeout: float | None = None) -> None:
        """Block until ``key`` exists (connector-native notification wait)."""
        connectors_wait_for(self.connector, key, timeout)

    def wait_for_any(self, keys: Sequence[str], timeout: float | None = None) -> str:
        """Block until some key exists; returns the first ready one."""
        return connectors_wait_for_any(self.connector, keys, timeout)

    def evict(self, key: str) -> None:
        self.connector.evict(key)
        self._cache.invalidate(key)
        self.metrics.evict_count += 1
        if self._san:
            self._san.on_evict(self.name, self.connector, key, via="evict")

    # -- tiering (MultiConnector-backed stores) --------------------------------
    def tier_of(self, key: str) -> str | None:
        """Name of the tier holding ``key``; None for single-tier connectors."""
        tier_of = getattr(self.connector, "tier_of", None)
        if tier_of is None:
            return None
        return tier_of(key)

    def demote(self, key: str, to: str) -> bool:
        """Move ``key`` to a colder tier (no-op False on non-tiered connectors).

        Invalidates the resolve cache so the next resolve re-fetches from
        the new tier rather than serving the pre-demotion object.
        """
        demote = getattr(self.connector, "demote", None)
        if demote is None:
            return False
        moved = demote(key, to)
        if moved:
            self._cache.invalidate(key)
        return moved

    # -- proxies ---------------------------------------------------------------
    def proxy(
        self,
        obj: T,
        *,
        evict_on_resolve: bool = False,
        lifetime: "Lifetime | None" = None,
        key: str | None = None,
    ) -> Proxy[T]:
        """Serialize ``obj`` into the channel and return a lazy proxy of it."""
        key = self.put(obj, key=key)
        if lifetime is not None:
            try:
                lifetime.add(self, key)
            except BaseException:
                # an ended lifetime must not orphan the payload we just
                # minted on its behalf (found by ProxySan's leak report)
                self.evict(key)
                raise
        factory = StoreFactory(
            key,
            self.name,
            self.connector,
            evict_on_resolve=evict_on_resolve,
            deserializer=self._carried_deserializer(),
            serializer=self._carried_serializer(),
        )
        return Proxy(factory, metadata={"key": key, "store": self.name})

    def proxy_from_key(
        self, key: str, *, block: bool = False, evict_on_resolve: bool = False
    ) -> Proxy[T]:
        """Build a proxy for an object already (or eventually) in the channel."""
        factory = StoreFactory(
            key,
            self.name,
            self.connector,
            block=block,
            evict_on_resolve=evict_on_resolve,
            deserializer=self._carried_deserializer(),
            serializer=self._carried_serializer(),
        )
        return Proxy(factory, metadata={"key": key, "store": self.name})

    # -- pattern entry points ----------------------------------------------------
    def future(self, *, timeout: float | None = None) -> "ProxyFuture[T]":
        from repro.core.futures import ProxyFuture

        return ProxyFuture(self, key=new_key(), timeout=timeout)

    def owned_proxy(self, obj: T, **kw) -> "OwnedProxy[T]":
        from repro.core.ownership import owned_proxy

        return owned_proxy(self, obj, **kw)

    # -- lifecycle ---------------------------------------------------------------
    def close(self) -> None:
        if not self._closed:
            self._closed = True
            with _REGISTRY_LOCK:
                _STORE_REGISTRY.pop(self.name, None)
            self._cache.clear()
            self.connector.close()

    def __enter__(self) -> "Store":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __reduce__(self):
        # Reattach by (name, connector) on the far side, carrying custom
        # serializers when present.  A non-picklable custom codec fails
        # *here*, loudly, instead of silently reattaching with defaults.
        return (
            _reattach,
            (
                self.name,
                self.connector,
                None if self.serializer is default_serializer else self.serializer,
                self._carried_deserializer(),
            ),
        )

    def __repr__(self):
        return f"Store(name={self.name!r}, connector={type(self.connector).__name__})"


def _reattach(name, connector, serializer, deserializer):
    return Store.get_or_reattach(
        name, connector, serializer=serializer, deserializer=deserializer
    )
