"""High-level Store interface (paper §III).

``Store.proxy(obj)`` serializes the target, puts it in the mediated channel
via the connector, builds a :class:`StoreFactory` with the metadata needed
for just-in-time retrieval, and returns a transparent :class:`Proxy`.

The store also exposes the three pattern entry points:
``future()`` (§IV-A), stream producers/consumers consume stores directly
(§IV-B), and ``owned_proxy()`` (§IV-C).
"""
from __future__ import annotations

import io
import pickle
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Generic, TypeVar

from repro.core.connectors import Connector, InMemoryConnector, new_key, wait_for_key
from repro.core.proxy import Factory, Proxy

T = TypeVar("T")

# ---------------------------------------------------------------------------
# Serialization: pickle with a jax-array-aware path.  jax.Array does not
# pickle across processes reliably; convert to numpy on the way in and let
# consumers re-device_put (just-in-time resolution does this lazily).
# ---------------------------------------------------------------------------


class _JaxAwarePickler(pickle.Pickler):
    """Pickler that converts jax arrays to numpy on the way into the store.

    Consumers re-``device_put`` lazily on resolution — the proxy's
    just-in-time semantics make this transparent.
    """

    def reducer_override(self, o):
        import sys

        # sys.modules check, NOT an import: if jax was never imported, ``o``
        # cannot be a jax array, and a lazy ``import jax`` here would inject
        # a ~1.5 s GIL-holding import into the first put() of a process that
        # never touches jax (observed in the Fig-5 benchmark).
        jax = sys.modules.get("jax")
        if jax is None:
            return NotImplemented
        import numpy as np

        if isinstance(o, jax.Array):
            return (np.asarray, (np.asarray(o),))
        return NotImplemented


def default_serializer(obj: Any) -> bytes:
    buf = io.BytesIO()
    _JaxAwarePickler(buf, protocol=pickle.HIGHEST_PROTOCOL).dump(obj)
    return buf.getvalue()


def default_deserializer(data: bytes) -> Any:
    return pickle.loads(data)


# ---------------------------------------------------------------------------


@dataclass
class StoreMetrics:
    """Instrumentation used by the paper-style benchmarks."""

    put_count: int = 0
    put_bytes: int = 0
    put_time: float = 0.0
    get_count: int = 0
    get_bytes: int = 0
    get_time: float = 0.0
    evict_count: int = 0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


_STORE_REGISTRY: dict[str, "Store"] = {}
_REGISTRY_LOCK = threading.Lock()


class StoreFactory(Factory[T]):
    """Factory that retrieves a serialized target from a mediated channel.

    Self-contained: carries the store name + connector (picklable), so a
    proxy can resolve anywhere with "no external information" (paper §III).
    """

    def __init__(
        self,
        key: str,
        store_name: str,
        connector: Connector,
        *,
        evict_on_resolve: bool = False,
        block: bool = False,
        timeout: float | None = None,
    ):
        self.key = key
        self.store_name = store_name
        self.connector = connector
        self.evict_on_resolve = evict_on_resolve
        self.block = block
        self.timeout = timeout

    def __call__(self) -> T:
        store = Store.get_or_reattach(self.store_name, self.connector)
        if self.block:
            data = wait_for_key(self.connector, self.key, timeout=self.timeout)
            t0 = time.perf_counter()
        else:
            t0 = time.perf_counter()
            data = self.connector.get(self.key)
            if data is None:
                raise KeyError(
                    f"proxy target {self.key!r} missing from store "
                    f"{self.store_name!r} (freed early? see ownership rules)"
                )
        obj = store.deserializer(data)
        store.metrics.get_count += 1
        store.metrics.get_bytes += len(data)
        store.metrics.get_time += time.perf_counter() - t0
        if self.evict_on_resolve:
            self.connector.evict(self.key)
        return obj

    def __repr__(self):
        return f"StoreFactory(key={self.key!r}, store={self.store_name!r})"


class Store(Generic[T]):
    """High-level interface for creating proxies of objects."""

    def __init__(
        self,
        name: str,
        connector: Connector | None = None,
        *,
        serializer: Callable[[Any], bytes] = default_serializer,
        deserializer: Callable[[bytes], Any] = default_deserializer,
        cache_size: int = 16,
        register: bool = True,
    ):
        self.name = name
        self.connector = connector if connector is not None else InMemoryConnector(name)
        self.serializer = serializer
        self.deserializer = deserializer
        self.metrics = StoreMetrics()
        self._closed = False
        if register:
            with _REGISTRY_LOCK:
                _STORE_REGISTRY[name] = self

    # -- registry ------------------------------------------------------------
    @classmethod
    def get_or_reattach(cls, name: str, connector: Connector) -> "Store":
        with _REGISTRY_LOCK:
            st = _STORE_REGISTRY.get(name)
        if st is None:
            st = Store(name, connector)
        return st

    # -- raw k/v --------------------------------------------------------------
    def put(self, obj: Any, key: str | None = None) -> str:
        key = key or new_key()
        data = self.serializer(obj)
        t0 = time.perf_counter()
        self.connector.put(key, data)
        self.metrics.put_time += time.perf_counter() - t0
        self.metrics.put_count += 1
        self.metrics.put_bytes += len(data)
        return key

    def get(self, key: str, default: Any = None) -> Any:
        data = self.connector.get(key)
        if data is None:
            return default
        self.metrics.get_count += 1
        self.metrics.get_bytes += len(data)
        return self.deserializer(data)

    def exists(self, key: str) -> bool:
        return self.connector.exists(key)

    def evict(self, key: str) -> None:
        self.connector.evict(key)
        self.metrics.evict_count += 1

    # -- proxies ---------------------------------------------------------------
    def proxy(
        self,
        obj: T,
        *,
        evict_on_resolve: bool = False,
        lifetime: "Lifetime | None" = None,
        key: str | None = None,
    ) -> Proxy[T]:
        """Serialize ``obj`` into the channel and return a lazy proxy of it."""
        key = self.put(obj, key=key)
        factory = StoreFactory(
            key, self.name, self.connector, evict_on_resolve=evict_on_resolve
        )
        p = Proxy(factory, metadata={"key": key, "store": self.name})
        if lifetime is not None:
            lifetime.add(self, key)
        return p

    def proxy_from_key(self, key: str, *, block: bool = False) -> Proxy[T]:
        """Build a proxy for an object already (or eventually) in the channel."""
        factory = StoreFactory(key, self.name, self.connector, block=block)
        return Proxy(factory, metadata={"key": key, "store": self.name})

    # -- pattern entry points ----------------------------------------------------
    def future(self, *, timeout: float | None = None) -> "ProxyFuture[T]":
        from repro.core.futures import ProxyFuture

        return ProxyFuture(self, key=new_key(), timeout=timeout)

    def owned_proxy(self, obj: T, **kw) -> "OwnedProxy[T]":
        from repro.core.ownership import owned_proxy

        return owned_proxy(self, obj, **kw)

    # -- lifecycle ---------------------------------------------------------------
    def close(self) -> None:
        if not self._closed:
            self._closed = True
            with _REGISTRY_LOCK:
                _STORE_REGISTRY.pop(self.name, None)
            self.connector.close()

    def __enter__(self) -> "Store":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __reduce__(self):
        # Reattach by (name, connector) on the far side.
        return (Store.get_or_reattach, (self.name, self.connector))

    def __repr__(self):
        return f"Store(name={self.name!r}, connector={type(self.connector).__name__})"
