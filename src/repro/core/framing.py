"""Zero-copy serialization framing for the store hot path (paper §III).

Pickle protocol 5 separates the object graph (small pickle stream) from its
large binary payloads (out-of-band ``PickleBuffer``\\ s).  We frame the two as

    ``MAGIC | n_buffers:u32 | pickle_len:u64 | buf_len:u64 * n | pickle | bufs``

so a payload travels through a connector as a *sequence of buffer parts* —
the raw numpy/jax array bytes are handed to the channel as memoryviews and
never copied through an intermediate ``BytesIO``.  On the way out,
:func:`decode` slices sub-views of the connector's single contiguous view
and feeds them to ``pickle.loads(..., buffers=...)``; numpy reconstructs
arrays *over* those views (``_frombuffer``), so a resolve from a view-capable
connector (in-memory, shm, mmap'd file) performs zero payload copies.

Caveats of zero-copy resolution (standard for UCX-style transports):
- arrays resolved from a read-only view are non-writable (copy to mutate);
- the resolved array aliases the channel buffer, so overwriting the same key
  in a shared-memory segment mutates previously resolved arrays.  The
  Store's resolve cache + evict invalidation keep the common paths safe.

Legacy payloads (plain pickle, protocol ≥2 streams start with ``0x80``) are
transparently accepted by :func:`decode`, so stores can read objects written
before this framing existed.
"""
from __future__ import annotations

import io
import pickle
import struct
from typing import Any, Sequence

MAGIC = b"PSF1"
MAGIC_ARR = b"PSA1"  # contiguous-ndarray fast frame: no pickle at all
_HEAD = struct.Struct("<IQ")  # n_buffers, pickle_len
_LEN = struct.Struct("<Q")


class _JaxAwarePickler(pickle.Pickler):
    """Pickler that converts jax arrays to numpy on the way into the store.

    Consumers re-``device_put`` lazily on resolution — the proxy's
    just-in-time semantics make this transparent.
    """

    def reducer_override(self, o):
        import sys

        # sys.modules check, NOT an import: if jax was never imported, ``o``
        # cannot be a jax array, and a lazy ``import jax`` here would inject
        # a ~1.5 s GIL-holding import into the first put() of a process that
        # never touches jax (observed in the Fig-5 benchmark).
        jax = sys.modules.get("jax")
        if jax is None:
            return NotImplemented
        import numpy as np

        if isinstance(o, jax.Array):
            # The numpy copy (device→host) is unavoidable; handing the copy
            # to the pickler lets protocol 5 take its buffer out-of-band.
            return (np.asarray, (np.asarray(o),))
        return NotImplemented


def encode(obj: Any) -> list:
    """Serialize ``obj`` into framed parts: ``[header, pickle, *raw_bufs]``.

    Every part is bytes-like; large array payloads appear as out-of-band
    memoryviews over the original object's memory (no copy).  Join the parts
    (or hand them to a vectored connector put) to form the wire payload.

    A bare C-contiguous numpy array — the dominant payload in the paper's
    workloads — short-circuits to an array frame (``PSA1``): dtype + shape
    header followed by the raw buffer, skipping pickle entirely on both
    ends (this is the serializer the small-object crossover lives or dies
    by).
    """
    import sys

    np = sys.modules.get("numpy")
    if (
        np is not None
        and type(obj) is np.ndarray
        and obj.flags.c_contiguous
        and obj.dtype.kind in "biufc"  # kinds that export a plain buffer
    ):
        dt = obj.dtype.str.encode()
        header = b"".join(
            (
                MAGIC_ARR,
                bytes((len(dt), obj.ndim)),
                dt,
                struct.pack(f"<{obj.ndim}Q", *obj.shape),
            )
        )
        # cast("B") rejects views with a 0 in shape/strides; a zero-size
        # array's payload is simply empty
        buf = memoryview(obj).cast("B") if obj.size else memoryview(b"")
        return [header, buf]

    bufs: list[memoryview] = []

    def grab(pb: pickle.PickleBuffer):
        bufs.append(pb.raw())
        return False  # take out-of-band

    try:
        if "jax" not in sys.modules:
            # no jax arrays can exist → use the C pickler end-to-end (a
            # Pickler subclass with reducer_override pays a Python callback
            # per object, measurable on the small-object hot path)
            pkl = pickle.dumps(obj, protocol=5, buffer_callback=grab)
        else:
            stream = io.BytesIO()
            _JaxAwarePickler(stream, protocol=5, buffer_callback=grab).dump(obj)
            pkl = stream.getbuffer()
    except pickle.PickleError:
        # e.g. a non-contiguous PickleBuffer with no contiguous raw() view;
        # fall back to fully in-band pickling (still decodable: legacy path).
        return [pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)]
    plen = pkl.nbytes if isinstance(pkl, memoryview) else len(pkl)
    header = b"".join(
        (
            MAGIC,
            _HEAD.pack(len(bufs), plen),
            b"".join(_LEN.pack(b.nbytes) for b in bufs),
        )
    )
    return [header, pkl, *bufs]


def is_framed(data) -> bool:
    view = data if isinstance(data, memoryview) else memoryview(data)
    if view.nbytes < 4:
        return False
    head = view[:4]
    return head == MAGIC or head == MAGIC_ARR


def decode(data, *, writable: bool = False) -> Any:
    """Deserialize a framed (or legacy plain-pickle) payload.

    Accepts any bytes-like object; when given a memoryview over channel
    memory, out-of-band buffers are zero-copy sub-views of it — resolved
    arrays are then read-only aliases of the channel.  ``writable=True``
    copies each raw buffer once (into a private bytearray) so reconstructed
    arrays are mutable and independent of the channel; mutation-bearing
    paths (ownership Owned/RefMut proxies) use this.

    Also accepts a framed *parts* sequence as produced by :func:`encode`
    (see :func:`decode_parts`) — the fully zero-copy path for channels that
    store parts instead of a joined payload.
    """
    if isinstance(data, (tuple, list)):
        return decode_parts(data, writable=writable)
    view = data if isinstance(data, memoryview) else memoryview(data)
    if view.ndim != 1 or view.format != "B":
        view = view.cast("B")
    if view[:4] == MAGIC_ARR:
        import numpy as np

        dt_len, ndim = view[4], view[5]
        off = 6 + dt_len
        dtype = np.dtype(bytes(view[6:off]).decode())
        shape = struct.unpack_from(f"<{ndim}Q", view, off)
        buf = view[off + ndim * 8 :]
        if writable:
            buf = memoryview(bytearray(buf))
        return np.frombuffer(buf, dtype=dtype).reshape(shape)
    if not is_framed(view):
        return pickle.loads(view)
    off = len(MAGIC)
    nbuf, plen = _HEAD.unpack_from(view, off)
    off += _HEAD.size
    lens = [_LEN.unpack_from(view, off + i * _LEN.size)[0] for i in range(nbuf)]
    off += nbuf * _LEN.size
    pkl = view[off : off + plen]
    off += plen
    bufs = []
    for n in lens:
        buf = view[off : off + n]
        bufs.append(memoryview(bytearray(buf)) if writable else buf)
        off += n
    return pickle.loads(pkl, buffers=bufs)


def decode_parts(parts: Sequence, *, writable: bool = False) -> Any:
    """Deserialize a framed *parts* sequence without joining it.

    ``encode`` emits ``[header, pickle, *bufs]`` (or ``[header, buf]`` for
    the bare-array frame); a connector that stores the parts as-is hands
    them back here and the out-of-band buffers are consumed *in place* —
    no join copy, resolved arrays alias the producer's original memory
    (read-only).  Parts that don't match the encode layout (single part,
    foreign split) fall back to join + :func:`decode`.
    """
    if len(parts) == 1:
        return decode(parts[0], writable=writable)
    head = parts[0]
    hview = head if isinstance(head, memoryview) else memoryview(head)
    if hview.ndim != 1 or hview.format != "B":
        hview = hview.cast("B")

    def _buf(part):
        mv = part if isinstance(part, memoryview) else memoryview(part)
        if writable:
            return memoryview(bytearray(mv))
        return mv.toreadonly()

    if hview[:4] == MAGIC_ARR and len(parts) == 2:
        import numpy as np

        dt_len, ndim = hview[4], hview[5]
        off = 6 + dt_len
        if hview.nbytes == off + ndim * 8:  # header part is exactly the header
            dtype = np.dtype(bytes(hview[6:off]).decode())
            shape = struct.unpack_from(f"<{ndim}Q", hview, off)
            return np.frombuffer(_buf(parts[1]), dtype=dtype).reshape(shape)
    elif hview[:4] == MAGIC:
        nbuf, plen = _HEAD.unpack_from(hview, 4)
        lens_end = 4 + _HEAD.size + nbuf * _LEN.size
        if (
            len(parts) == 2 + nbuf
            and hview.nbytes == lens_end
            and all(
                _LEN.unpack_from(hview, 4 + _HEAD.size + i * _LEN.size)[0]
                == (parts[2 + i].nbytes if isinstance(parts[2 + i], memoryview)
                    else len(parts[2 + i]))
                for i in range(nbuf)
            )
        ):
            bufs = [_buf(p) for p in parts[2:]]
            return pickle.loads(parts[1], buffers=bufs)
    return decode(join_parts(parts), writable=writable)


def parts_nbytes(parts: Sequence) -> int:
    """Total wire size of a framed-parts payload."""
    return sum(
        p.nbytes if isinstance(p, memoryview) else len(p) for p in parts
    )


def join_parts(parts: Sequence) -> bytes:
    """Flatten framed parts into one contiguous payload (single copy)."""
    if len(parts) == 1:
        p = parts[0]
        return p if isinstance(p, bytes) else bytes(p)
    return b"".join(parts)


def estimated_nbytes(obj: Any) -> int:
    """Cheap serialized-size estimate for proxy-policy thresholds.

    numpy arrays report ``nbytes`` directly (no serialization); everything
    else pays one framed encode, which is itself copy-free for buffers.
    Returns -1 for objects that cannot be serialized at all — the .nbytes
    shortcut is restricted to ndarrays precisely so that unpicklable
    buffer types (memoryview, mmap) fall through to the encode probe and
    report unserializable instead of a plausible size.
    """
    import sys

    np = sys.modules.get("numpy")
    if np is not None and isinstance(obj, np.ndarray) and not obj.dtype.hasobject:
        return obj.nbytes
    try:
        return parts_nbytes(encode(obj))
    except Exception:
        return -1
