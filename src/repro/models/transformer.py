"""Decoder-only LM covering the dense / moe / mla_moe families.

Layers are ``lax.scan``-stacked (one compiled body, small HLO) with a
selectable remat policy.  deepseek-v3's ``first_k_dense`` leading layers are
unrolled separately (heterogeneous vs. the MoE stack), and its MTP head
(depth 1) adds a weighted auxiliary next-next-token loss.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import ParamSpec
from repro.models import layers as L
from repro.models.layers import ModelContext
from repro.models.moe import apply_moe, moe_specs


def stack_specs(specs, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (None,) + s.axes, s.dtype, s.init_scale),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)  # "full"


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def block_specs(cfg: ArchConfig, *, moe: bool) -> dict:
    s = {
        "ln1": L.norm_specs(cfg, cfg.d_model),
        "ln2": L.norm_specs(cfg, cfg.d_model),
    }
    s["attn"] = L.mla_specs(cfg) if cfg.use_mla else L.attention_specs(cfg)
    s["ffn"] = moe_specs(cfg) if moe else L.mlp_specs(cfg)
    return s


def apply_block(
    ctx: ModelContext,
    p: dict,
    x: jax.Array,
    rope,
    *,
    moe: bool,
    cache: dict | None = None,
    cache_index=None,
):
    cfg = ctx.cfg
    h = L.apply_norm(cfg, p["ln1"], x)
    if cfg.use_mla:
        attn_out, new_cache = L.apply_mla(
            ctx, p["attn"], h, rope=rope, cache=cache, cache_index=cache_index
        )
    else:
        attn_out, new_cache = L.apply_attention(
            ctx, p["attn"], h, rope=rope, cache=cache, cache_index=cache_index
        )
    x = x + attn_out
    h = L.apply_norm(cfg, p["ln2"], x)
    if moe:
        ffn_out, aux = apply_moe(ctx, p["ffn"], h)
    else:
        ffn_out, aux = L.apply_mlp(ctx, p["ffn"], h), jnp.float32(0.0)
    return x + ffn_out, new_cache, aux


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class DecoderLM:
    def __init__(self, ctx: ModelContext):
        self.ctx = ctx
        self.cfg = ctx.cfg

    # -- params -------------------------------------------------------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        is_moe = cfg.family in ("moe", "mla_moe")
        n_moe = cfg.n_layers - cfg.first_k_dense if is_moe else 0
        n_dense = cfg.first_k_dense if is_moe else cfg.n_layers
        s: dict = {"embed": L.embed_specs(cfg), "final_norm": L.norm_specs(cfg, cfg.d_model)}
        if n_dense:
            s["dense_layers"] = stack_specs(block_specs(cfg, moe=False), n_dense)
        if n_moe:
            s["moe_layers"] = stack_specs(block_specs(cfg, moe=True), n_moe)
        if cfg.use_mtp:
            s["mtp"] = {
                "proj": ParamSpec((2 * cfg.d_model, cfg.d_model), (None, "embed")),
                "block": block_specs(cfg, moe=False),
                "final_norm": L.norm_specs(cfg, cfg.d_model),
            }
        return s

    # -- shared trunk ---------------------------------------------------------
    def _rope(self, batch: dict, positions=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        if cfg.use_mrope:
            pos = batch.get("positions")
            if pos is None:
                p1 = jnp.broadcast_to(
                    positions if positions is not None else jnp.arange(S)[None], (B, S)
                )
                pos = jnp.stack([p1, p1, p1])
            dim = cfg.qk_rope_dim if cfg.use_mla else int(cfg.rotary_pct * cfg.head_dim_)
            return L.mrope_cos_sin(pos, dim, cfg.rope_theta, cfg.mrope_sections)
        pos = positions if positions is not None else jnp.arange(S)[None]
        pos = jnp.broadcast_to(pos, (B, S))
        dim = cfg.qk_rope_dim if cfg.use_mla else int(cfg.rotary_pct * cfg.head_dim_)
        dim -= dim % 2
        if dim == 0:
            return None
        return L.rope_cos_sin(pos, dim, cfg.rope_theta)

    def _embed_inputs(self, params, batch):
        cfg, ctx = self.cfg, self.ctx
        x = L.apply_embed(ctx, params["embed"], batch["tokens"])
        if cfg.vision_embeds and "vision_embeds" in batch:
            V = cfg.vision_embeds
            ve = batch["vision_embeds"].astype(x.dtype)
            x = jnp.concatenate([ve, x[:, V:]], axis=1)
        return x

    def _trunk(self, params, x, rope):
        """Run all layers (train/scoring path: no cache)."""
        cfg, ctx = self.cfg, self.ctx
        aux_total = jnp.float32(0.0)

        def dense_body(x, p):
            out, _, aux = apply_block(ctx, p, x, rope, moe=False)
            return out, aux

        def moe_body(x, p):
            out, _, aux = apply_block(ctx, p, x, rope, moe=True)
            return out, aux

        if "dense_layers" in params:
            if cfg.scan_layers:
                x, auxs = jax.lax.scan(_remat(cfg, dense_body), x, params["dense_layers"])
                aux_total += auxs.sum()
            else:
                nd = jax.tree.leaves(params["dense_layers"])[0].shape[0]
                for i in range(nd):
                    p = jax.tree.map(lambda a: a[i], params["dense_layers"])
                    x, aux = _remat(cfg, dense_body)(x, p)
                    aux_total += aux
        if "moe_layers" in params:
            if cfg.scan_layers:
                x, auxs = jax.lax.scan(_remat(cfg, moe_body), x, params["moe_layers"])
                aux_total += auxs.sum()
            else:
                nm = jax.tree.leaves(params["moe_layers"])[0].shape[0]
                for i in range(nm):
                    p = jax.tree.map(lambda a: a[i], params["moe_layers"])
                    x, aux = _remat(cfg, moe_body)(x, p)
                    aux_total += aux
        return x, aux_total

    # -- training loss ----------------------------------------------------------
    def loss(self, params, batch):
        cfg, ctx = self.cfg, self.ctx
        rope = self._rope(batch)
        x = self._embed_inputs(params, batch)
        h, aux = self._trunk(params, x, rope)
        hn = L.apply_norm(cfg, params["final_norm"], h)
        logits = L.apply_unembed(ctx, params["embed"], hn)
        labels = batch["labels"]
        loss = L.cross_entropy(ctx, logits, labels)
        metrics = {"ce": loss, "aux": aux}
        total = loss + cfg.router_aux_weight * aux

        if cfg.use_mtp:
            mtp_loss = self._mtp_loss(params, batch, h, rope)
            metrics["mtp"] = mtp_loss
            total = total + 0.3 * mtp_loss
        return total, metrics

    def _mtp_loss(self, params, batch, h, rope):
        """deepseek-v3 MTP (depth 1): predict t+2 from h_t ++ emb(t+1)."""
        cfg, ctx = self.cfg, self.ctx
        p = params["mtp"]
        tokens, labels = batch["tokens"], batch["labels"]
        nxt = jnp.roll(tokens, -1, axis=1)  # token t+1
        emb_next = L.apply_embed(ctx, params["embed"], nxt)
        hcat = jnp.concatenate(
            [L.rmsnorm_nogain(h), L.rmsnorm_nogain(emb_next)], axis=-1
        )
        hp = jnp.einsum("bsf,fe->bse", hcat, p["proj"])
        hp, _, _ = apply_block(ctx, p["block"], hp, rope, moe=False)
        hp = L.apply_norm(cfg, p["final_norm"], hp)
        logits = L.apply_unembed(ctx, params["embed"], hp)
        # label for position t is tok_{t+2} ≡ labels shifted by 1; mask tail
        lab2 = jnp.roll(labels, -1, axis=1).at[:, -1].set(-1).at[:, -2].set(-1)
        return L.cross_entropy(ctx, logits, lab2)

    # -- serving ------------------------------------------------------------------
    def cache_specs(self, batch_size: int, max_len: int) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        if cfg.use_mla:
            per = {
                "ckv": ParamSpec(
                    (batch_size, max_len, cfg.kv_lora_rank),
                    ("batch", "kv_seq", None), dt, 0.0,
                ),
                "kr": ParamSpec(
                    (batch_size, max_len, 1, cfg.qk_rope_dim),
                    ("batch", "kv_seq", None, None), dt, 0.0,
                ),
            }
        else:
            per = {
                "k": ParamSpec(
                    (batch_size, max_len, cfg.n_kv_heads, cfg.head_dim_),
                    ("batch", "kv_seq", "kv_heads", None), dt, 0.0,
                ),
                "v": ParamSpec(
                    (batch_size, max_len, cfg.n_kv_heads, cfg.head_dim_),
                    ("batch", "kv_seq", "kv_heads", None), dt, 0.0,
                ),
            }
        return stack_specs(per, cfg.n_layers)

    def _decode_trunk(self, params, cache, tokens, index):
        """Shared decode trunk: embed ``tokens`` (B, K) at positions
        ``index .. index+K-1``, run every layer against the stacked cache
        (each layer writes its K new KV entries at ``index``), and return
        (hidden (B, K, E), new stacked cache)."""
        cfg, ctx = self.cfg, self.ctx
        K = tokens.shape[1]
        rope = self._rope(
            {"tokens": tokens}, positions=index + jnp.arange(K)[None]
        )
        x = L.apply_embed(ctx, params["embed"], tokens)

        all_layers = []
        if "dense_layers" in params:
            all_layers.append((params["dense_layers"], False))
        if "moe_layers" in params:
            all_layers.append((params["moe_layers"], True))
        # split the stacked cache to match the dense/moe partition
        n_dense = (
            jax.tree.leaves(params["dense_layers"])[0].shape[0]
            if "dense_layers" in params else 0
        )
        caches = []
        if n_dense:
            caches.append(jax.tree.map(lambda c: c[:n_dense], cache))
        if "moe_layers" in params:
            caches.append(jax.tree.map(lambda c: c[n_dense:], cache))

        new_caches = []
        for (lp, is_moe), lc in zip(all_layers, caches):
            def body(x, scanned, is_moe=is_moe):
                p, c = scanned
                out, nc, _ = apply_block(
                    ctx, p, x, rope, moe=is_moe, cache=c, cache_index=index
                )
                return out, nc

            x, nc = L.scan_stack(cfg, body, x, (lp, lc))
            new_caches.append(nc)

        new_cache = (
            jax.tree.map(lambda *cs: jnp.concatenate(cs, 0), *new_caches)
            if len(new_caches) > 1 else new_caches[0]
        )
        return x, new_cache

    def decode_step(self, params, cache, tokens, index):
        """One decode step.  tokens (B, 1); cache stacked (L, ...);
        index: scalar position of the new token."""
        cfg, ctx = self.cfg, self.ctx
        x, new_cache = self._decode_trunk(params, cache, tokens, index)
        hn = L.apply_norm(cfg, params["final_norm"], x)
        logits = L.apply_unembed(ctx, params["embed"], hn)
        return logits[:, 0], new_cache

    def decode_multi(self, params, cache, tokens, index):
        """K-token decode for speculative verify: ``tokens`` (B, K) are
        already-chosen tokens (last accepted + k draft proposals) written
        at positions ``index .. index+K-1``; query ``t`` attends the cache
        through position ``index+t`` (causal within the block).  Returns
        (logits (B, K, V), new cache) — ``logits[:, t]`` is the target
        distribution AFTER token ``t``, so K == 1 reduces exactly to
        :meth:`decode_step`."""
        cfg, ctx = self.cfg, self.ctx
        x, new_cache = self._decode_trunk(params, cache, tokens, index)
        hn = L.apply_norm(cfg, params["final_norm"], x)
        logits = L.apply_unembed(ctx, params["embed"], hn)
        return logits, new_cache

    def verify_batch(self, params, cache, tokens, lens):
        """Per-row multi-position decode (the speculative verify pass):
        row ``b``'s K tokens sit at positions ``lens[b] .. lens[b]+K-1``
        of its own cache row.  ``cache`` leaves are stacked ``(L, B, S,
        ...)``; ``tokens`` (B, K); ``lens`` (B,) per-row cached lengths.
        Returns (logits (B, K, V), new cache)."""

        def one(cache_b, tok_b, len_b):
            cb = jax.tree.map(lambda c: c[:, None], cache_b)
            logits, nc = self.decode_multi(params, cb, tok_b[None], len_b)
            return logits[0], jax.tree.map(lambda c: c[:, 0], nc)

        return jax.vmap(one, in_axes=(1, 0, 0), out_axes=(0, 1))(
            cache, tokens, lens
        )

    def _prefill_trunk(self, params, tokens, max_len: int):
        """Shared prefill trunk: run the full (B, S) prompt batch, return
        the final hidden states and the cache padded to ``max_len``."""
        cfg, ctx = self.cfg, self.ctx
        rope = self._rope({"tokens": tokens})
        x = self._embed_inputs(params, {"tokens": tokens})

        def mk_body(is_moe):
            def body(x, p):
                out, nc, _ = apply_block(
                    ctx, p, x, rope, moe=is_moe, cache={}, cache_index=None
                )
                return out, nc
            return body

        new_caches = []
        if "dense_layers" in params:
            x, nc = L.scan_stack(cfg, mk_body(False), x, params["dense_layers"])
            new_caches.append(nc)
        if "moe_layers" in params:
            x, nc = L.scan_stack(cfg, mk_body(True), x, params["moe_layers"])
            new_caches.append(nc)
        cache = (
            jax.tree.map(lambda *cs: jnp.concatenate(cs, 0), *new_caches)
            if len(new_caches) > 1 else new_caches[0]
        )
        # pad cache out to max_len along the sequence axis
        def pad(c):
            pad_len = max_len - c.shape[2]
            if pad_len <= 0:
                return c
            pad_width = [(0, 0)] * c.ndim
            pad_width[2] = (0, pad_len)
            return jnp.pad(c, pad_width)

        return x, jax.tree.map(pad, cache)

    def prefill(self, params, tokens, max_len: int):
        """Prefill: run the full prompt, return (last-token logits, cache)."""
        cfg, ctx = self.cfg, self.ctx
        x, cache = self._prefill_trunk(params, tokens, max_len)
        hn = L.apply_norm(cfg, params["final_norm"], x)
        logits = L.apply_unembed(ctx, params["embed"], hn[:, -1:])
        return logits[:, 0], cache

    def prefill_batch(self, params, tokens, lens, max_len: int):
        """Batched multi-request prefill: ``tokens`` (B, S) right-padded
        prompts, ``lens`` (B,) valid lengths.  Returns per-row logits at
        position ``lens[b]-1`` and the padded cache.  Causal attention
        keeps right-padding inert: a padded position never influences a
        valid one, so rows of different true lengths batch into one call;
        cache rows beyond ``lens[b]`` hold pad garbage the engine's paged
        insert never maps."""
        cfg, ctx = self.cfg, self.ctx
        x, cache = self._prefill_trunk(params, tokens, max_len)
        idx = jnp.maximum(lens - 1, 0)
        last = jnp.take_along_axis(x, idx[:, None, None], axis=1)  # (B, 1, E)
        hn = L.apply_norm(cfg, params["final_norm"], last)
        logits = L.apply_unembed(ctx, params["embed"], hn)
        return logits[:, 0], cache
