"""Whisper-style encoder-decoder (audio family).

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, frames, d_model).  Encoder = bidirectional
self-attention; decoder = causal self-attention + cross-attention.
Sinusoidal positions (whisper uses no rope).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import ParamSpec
from repro.models import layers as L
from repro.models.layers import ModelContext
from repro.models.transformer import _remat, stack_specs


def _sinusoid(S: int, E: int) -> np.ndarray:
    pos = np.arange(S)[:, None]
    dim = np.arange(E // 2)[None]
    ang = pos / np.power(10_000.0, 2 * dim / E)
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


def enc_block_specs(cfg: ArchConfig) -> dict:
    return {
        "ln1": L.norm_specs(cfg, cfg.d_model),
        "attn": L.attention_specs(cfg),
        "ln2": L.norm_specs(cfg, cfg.d_model),
        "ffn": L.mlp_specs(cfg, gated=False),
    }


def dec_block_specs(cfg: ArchConfig) -> dict:
    return {
        "ln1": L.norm_specs(cfg, cfg.d_model),
        "self_attn": L.attention_specs(cfg),
        "ln_x": L.norm_specs(cfg, cfg.d_model),
        "cross_attn": L.attention_specs(cfg, cross=True),
        "ln2": L.norm_specs(cfg, cfg.d_model),
        "ffn": L.mlp_specs(cfg, gated=False),
    }


class EncDecLM:
    def __init__(self, ctx: ModelContext):
        self.ctx = ctx
        self.cfg = ctx.cfg

    def param_specs(self) -> dict:
        cfg = self.cfg
        return {
            "embed": L.embed_specs(cfg),
            "enc_layers": stack_specs(enc_block_specs(cfg), cfg.encoder_layers),
            "enc_norm": L.norm_specs(cfg, cfg.d_model),
            "dec_layers": stack_specs(dec_block_specs(cfg), cfg.n_layers),
            "final_norm": L.norm_specs(cfg, cfg.d_model),
        }

    # -- encoder --------------------------------------------------------------
    def encode(self, params, frames):
        """frames (B, F, E): precomputed frame embeddings (stub frontend)."""
        cfg, ctx = self.cfg, self.ctx
        F = frames.shape[1]
        pos = jnp.asarray(_sinusoid(F, cfg.d_model))
        x = frames.astype(ctx.compute_dtype) + pos.astype(ctx.compute_dtype)
        x = ctx.constrain(x, ("batch", None, None))

        def body(x, p):
            h = L.apply_norm(cfg, p["ln1"], x)
            att, _ = L.apply_attention(ctx, p["attn"], h, rope=None, causal=False)
            x = x + att
            h = L.apply_norm(cfg, p["ln2"], x)
            return x + L.apply_mlp(ctx, p["ffn"], h), None

        x, _ = L.scan_stack(cfg, _remat(cfg, body), x, params["enc_layers"])
        return L.apply_norm(cfg, params["enc_norm"], x)

    # -- decoder ----------------------------------------------------------------
    def _dec_body(self, enc_out, *, cache_mode: str, cache_index=None):
        cfg, ctx = self.cfg, self.ctx

        def body(x, xs):
            if cache_mode == "none":
                p = xs
                self_cache = cross_cache = None
            else:
                p, (self_cache, cross_cache) = xs
            h = L.apply_norm(cfg, p["ln1"], x)
            if cache_mode == "decode":
                att, new_self = L.apply_attention(
                    ctx, p["self_attn"], h, rope=None,
                    cache=self_cache, cache_index=cache_index,
                )
            else:
                att, new_self = L.apply_attention(
                    ctx, p["self_attn"], h, rope=None,
                    cache={} if cache_mode == "prefill" else None,
                )
            x = x + att
            h = L.apply_norm(cfg, p["ln_x"], x)
            if cache_mode == "decode":
                # cross K/V precomputed at prefill: plain decode attention
                o = L.decode_attention(
                    jnp.einsum("bse,ehd->bshd", h, p["cross_attn"]["wq"]),
                    cross_cache["k"], cross_cache["v"],
                    jnp.int32(cross_cache["k"].shape[1]),
                )
                att = jnp.einsum("bshd,hde->bse", o, p["cross_attn"]["wo"])
                new_cross = cross_cache
            else:
                att, new_cross = L.apply_attention(
                    ctx, p["cross_attn"], h, rope=None, kv=enc_out, causal=False,
                    cache={} if cache_mode == "prefill" else None,
                )
            x = x + att
            h = L.apply_norm(cfg, p["ln2"], x)
            x = x + L.apply_mlp(ctx, p["ffn"], h)
            if cache_mode == "none":
                return x, None
            return x, (new_self, new_cross)

        return body

    def _decode_positions(self, x, offset=0):
        cfg = self.cfg
        S = x.shape[1]
        pos_table = jnp.asarray(_sinusoid(max(S, 1), cfg.d_model))
        return x + pos_table[:S].astype(x.dtype)

    def loss(self, params, batch):
        cfg, ctx = self.cfg, self.ctx
        enc_out = self.encode(params, batch["frames"])
        x = L.apply_embed(ctx, params["embed"], batch["tokens"])
        x = self._decode_positions(x)
        body = self._dec_body(enc_out, cache_mode="none")
        x, _ = L.scan_stack(cfg, _remat(cfg, body), x, params["dec_layers"])
        hn = L.apply_norm(cfg, params["final_norm"], x)
        logits = L.apply_unembed(ctx, params["embed"], hn)
        loss = L.cross_entropy(ctx, logits, batch["labels"])
        return loss, {"ce": loss}

    # -- serving -------------------------------------------------------------
    def cache_specs(self, batch_size: int, max_len: int) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        kv = lambda S: {
            "k": ParamSpec((batch_size, S, cfg.n_kv_heads, cfg.head_dim_),
                           ("batch", "kv_seq", "kv_heads", None), dt, 0.0),
            "v": ParamSpec((batch_size, S, cfg.n_kv_heads, cfg.head_dim_),
                           ("batch", "kv_seq", "kv_heads", None), dt, 0.0),
        }
        return (
            stack_specs(kv(max_len), cfg.n_layers),
            stack_specs(kv(cfg.encoder_frames), cfg.n_layers),
        )

    def prefill(self, params, tokens, max_len: int, frames=None):
        cfg, ctx = self.cfg, self.ctx
        B, S = tokens.shape
        if frames is None:
            frames = jnp.zeros((B, cfg.encoder_frames, cfg.d_model), ctx.compute_dtype)
        enc_out = self.encode(params, frames)
        x = L.apply_embed(ctx, params["embed"], tokens)
        x = self._decode_positions(x)
        body = self._dec_body(enc_out, cache_mode="prefill")

        # prefill has no incoming cache: xs = params only; adapt body
        def body2(x, p):
            return self._dec_body(enc_out, cache_mode="prefill")(x, (p, (None, None)))

        x, (self_c, cross_c) = L.scan_stack(cfg, body2, x, params["dec_layers"])

        def pad(c):
            pad_len = max_len - c.shape[2]
            if pad_len <= 0:
                return c
            w = [(0, 0)] * c.ndim
            w[2] = (0, pad_len)
            return jnp.pad(c, w)

        self_c = jax.tree.map(pad, self_c)
        hn = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
        logits = L.apply_unembed(ctx, params["embed"], hn)
        return logits[:, 0], (self_c, cross_c)

    def decode_step(self, params, cache, tokens, index):
        cfg, ctx = self.cfg, self.ctx
        self_c, cross_c = cache
        x = L.apply_embed(ctx, params["embed"], tokens)
        S_table = jnp.asarray(_sinusoid(self_c["k"].shape[2], cfg.d_model))
        x = x + jax.lax.dynamic_slice_in_dim(S_table, index, 1, 0)[None].astype(x.dtype)
        body = self._dec_body(None, cache_mode="decode", cache_index=index)
        x, (new_self, new_cross) = L.scan_stack(
            cfg, body, x, (params["dec_layers"], (self_c, cross_c))
        )
        hn = L.apply_norm(cfg, params["final_norm"], x)
        logits = L.apply_unembed(ctx, params["embed"], hn)
        return logits[:, 0], (new_self, new_cross)
