"""Mixture-of-Experts layer with shard_map expert parallelism.

Dispatch strategy ("replicated-dispatch EP", chosen for TPU):
activations between blocks are replicated across the ``model`` axis (Megatron
style), so every model shard already holds every local token.  Each shard
therefore *selects* the tokens routed to its local experts (gather), runs
the expert FFNs, scatter-adds weighted outputs, and a single
``psum(model)`` combines expert contributions — the same collective shape
as a TP FFN all-reduce.  No giant one-hot dispatch einsums (which would
dominate HLO FLOPs) and no data-dependent all-to-all.

Capacity: per data-shard ``C = ceil(T_loc · top_k / E · capacity_factor)``;
overflow tokens drop (standard Switch-style behaviour, cf. DESIGN.md).
Router: softmax top-k with normalized gates + load-balance aux loss.
(deepseek-v3's bias-based aux-free routing is replaced by aux-loss routing —
recorded simplification.)
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro._compat.jaxshims import shard_map
from repro.configs.base import ArchConfig
from repro.dist.sharding import ParamSpec
from repro.models.layers import ModelContext


def moe_specs(cfg: ArchConfig) -> dict:
    E, X, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    s = {
        "router": ParamSpec((E, X), (None, None), jnp.float32),
        "wg": ParamSpec((X, E, F), ("expert", "embed", "mlp")),
        "wi": ParamSpec((X, E, F), ("expert", "embed", "mlp")),
        "wo": ParamSpec((X, F, E), ("expert", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        Fs = cfg.moe_d_ff * cfg.n_shared_experts
        s["shared"] = {
            "wg": ParamSpec((E, Fs), ("embed", "mlp")),
            "wi": ParamSpec((E, Fs), ("embed", "mlp")),
            "wo": ParamSpec((Fs, E), ("mlp", "embed")),
        }
    return s


_DUMMY_AXIS = "__no_axis__"  # single-shard fallback (no mesh model axis)


def _local_moe(cfg: ArchConfig, model_axis: str, batch_axes: tuple[str, ...],
               x, router_w, wg, wi, wo):
    """Per-shard body (runs inside shard_map).  x: (T_loc, D) local tokens,
    replicated over the model axis; expert weights: local (X_loc, ·, ·)."""
    T_loc, D = x.shape
    X_loc = wg.shape[0]
    X = cfg.n_experts
    k = cfg.top_k
    C = max(1, math.ceil(T_loc * k / X * cfg.capacity_factor))

    if model_axis == _DUMMY_AXIS:
        lo = 0
    else:
        lo = jax.lax.axis_index(model_axis) * X_loc

    logits = jnp.einsum("td,dx->tx", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)  # (T_loc, X)
    gates, ids = jax.lax.top_k(probs, k)  # (T_loc, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (global over the data axes)
    density = jnp.zeros((X,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (T_loc * k)
    pbar = probs.mean(0)
    if batch_axes:
        density = jax.lax.pmean(density, batch_axes)
        pbar = jax.lax.pmean(pbar, batch_axes)
    aux = X * jnp.sum(density * pbar)

    # --- dispatch: select local-expert tokens into (X_loc, C) slots -------
    flat_ids = ids.reshape(-1)  # (T_loc*k,)
    flat_gates = gates.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(T_loc), k)
    lid = flat_ids - lo
    valid = (lid >= 0) & (lid < X_loc)
    one_hot = jnp.where(valid[:, None], jax.nn.one_hot(lid, X_loc, dtype=jnp.int32), 0)
    pos = jnp.cumsum(one_hot, axis=0) * one_hot  # 1-based position per expert
    pos = (pos.sum(-1) - 1)  # (T_loc*k,) position of this pair in its expert
    keep = valid & (pos >= 0) & (pos < C)
    lid_w = jnp.where(keep, lid, X_loc)  # overflow → scratch row
    pos_w = jnp.where(keep, pos, 0)

    slot_tok = jnp.full((X_loc + 1, C), T_loc, jnp.int32)  # sentinel → zero row
    slot_tok = slot_tok.at[lid_w, pos_w].set(
        jnp.where(keep, tok_idx, T_loc).astype(jnp.int32)
    )
    slot_gate = jnp.zeros((X_loc + 1, C), jnp.float32)
    slot_gate = slot_gate.at[lid_w, pos_w].set(jnp.where(keep, flat_gates, 0.0))
    slot_tok, slot_gate = slot_tok[:X_loc], slot_gate[:X_loc]

    x_pad = jnp.concatenate([x, jnp.zeros((1, D), x.dtype)], 0)
    xin = x_pad[slot_tok]  # (X_loc, C, D)

    h = jax.nn.silu(jnp.einsum("xcd,xdf->xcf", xin, wg)) * jnp.einsum(
        "xcd,xdf->xcf", xin, wi
    )
    y = jnp.einsum("xcf,xfd->xcd", h, wo)  # (X_loc, C, D)
    y = y * slot_gate[..., None].astype(y.dtype)

    out = jnp.zeros((T_loc + 1, D), y.dtype).at[slot_tok.reshape(-1)].add(
        y.reshape(-1, D)
    )[:T_loc]
    if model_axis != _DUMMY_AXIS:
        out = jax.lax.psum(out, model_axis)
    return out, aux


def apply_moe(ctx: ModelContext, params: dict, x: jax.Array):
    """x: (B, S, D) → (out (B, S, D), aux_loss scalar)."""
    cfg = ctx.cfg
    B, S, D = x.shape
    batch_axes = ctx.batch_axes
    mesh = ctx.mesh
    model_axis = "model" if "model" in mesh.shape else None

    xf = x.reshape(B * S, D)

    if model_axis is None or mesh.shape[model_axis] == 1 or cfg.n_experts == 1:
        # single-shard fallback (smoke tests): dense loop over experts
        out, aux = _local_moe(
            cfg, _DUMMY_AXIS, (), xf, params["router"],
            params["wg"], params["wi"], params["wo"],
        )
    else:
        bspec = P(batch_axes if batch_axes else None, None)
        f = shard_map(
            partial(_local_moe, cfg, model_axis, batch_axes),
            mesh=mesh,
            in_specs=(
                bspec,  # x: tokens sharded over batch axes, replicated on model
                P(None, None),  # router: replicated
                P("model", None, None),  # wg
                P("model", None, None),  # wi
                P("model", None, None),  # wo
            ),
            out_specs=(bspec, P()),
            check_vma=False,
        )
        out, aux = f(xf, params["router"], params["wg"], params["wi"], params["wo"])

    out = out.reshape(B, S, D).astype(x.dtype)
    if cfg.n_shared_experts:
        from repro.models.layers import apply_mlp

        out = out + apply_mlp(ctx, params["shared"], x)
    return ctx.constrain(out, ("batch", None, None)), aux
