"""RWKV6 ("Finch") — attention-free LM with data-dependent decay.

Time-mix uses the WKV6 recurrence  S_t = diag(w_t)·S_{t-1} + k_tᵀv_t,
o_t = r_t·(S_{t-1} + diag(u)·k_tᵀv_t), computed *chunkwise-parallel* in log
space for stability (see kernels/wkv6_ref.py for the oracle form; the
Pallas kernel implements the same chunking for TPU).  Decode carries an
O(1) state per layer — no KV cache — which is why this arch runs the
``long_500k`` cell.

Simplified vs. the full release: the data-dependent token-shift (ddlerp)
uses a single learned mix per stream instead of the 5×LoRA stack, and the
decay LoRA is kept (it is the paper's headline feature).  Recorded in
DESIGN.md §7.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import ParamSpec
from repro.models import layers as L
from repro.models.layers import ModelContext


def _chunk_size(S: int, target: int = 128) -> int:
    for c in (target, 64, 32, 16, 8, 4, 2, 1):
        if S % c == 0:
            return c
    return 1


def wkv6_chunked(
    r: jax.Array,  # (B, S, H, K)
    k: jax.Array,  # (B, S, H, K)
    v: jax.Array,  # (B, S, H, V)
    lw: jax.Array,  # (B, S, H, K) log-decay per step (≤ 0)
    u: jax.Array,  # (H, K) bonus for the current token
    state: jax.Array | None = None,  # (B, H, K, V)
    chunk: int = 128,
    unroll: bool = False,
):
    """Chunkwise-parallel WKV6.  Returns (out (B,S,H,V), final state).

    ``unroll=True`` runs the chunk loop as Python (same math) so the
    dry-run's roofline probes see every chunk in cost_analysis.
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    C = _chunk_size(S, chunk)
    N = S // C
    f32 = jnp.float32

    rc = r.reshape(B, N, C, H, K).astype(f32)
    kc = k.reshape(B, N, C, H, K).astype(f32)
    vc = v.reshape(B, N, C, H, V).astype(f32)
    lwc = lw.reshape(B, N, C, H, K).astype(f32)

    s0 = (
        state.astype(f32)
        if state is not None
        else jnp.zeros((B, H, K, V), f32)
    )

    def chunk_step(s, xs):
        rj, kj, vj, lwj = xs  # (B, C, H, K/V)
        la = jnp.cumsum(lwj, axis=1)  # log cumulative decay within chunk
        lam = la - lwj  # exclusive cumulative decay (up to t-1), ≤ 0
        # inter-chunk: o_t += (r_t * exp(lam_t)) @ s
        o_inter = jnp.einsum("bchk,bhkv->bchv", rj * jnp.exp(lam), s)
        # intra-chunk: scores_ts = Σ_k r_t k_s exp(lam_{t,k} - la_{s,k}), s<t.
        # The decay difference is masked BEFORE exp: it is ≤0 in the causal
        # region, so this is overflow-safe for arbitrarily strong decays
        # (a factored exp(lam)·exp(-la) dot-product overflows when |la|≳88).
        mask = jnp.tril(jnp.ones((C, C), bool), k=-1)  # (t, s), strict
        diff = lam[:, :, None] - la[:, None]  # (B, C, C, H, K) [t, s]
        diff = jnp.where(mask[None, :, :, None, None], diff, -jnp.inf)
        scores = jnp.einsum("bchk,bshk,bcshk->bhcs", rj, kj, jnp.exp(diff))
        o_intra = jnp.einsum("bhcs,bshv->bchv", scores, vj)
        # current-token bonus: r_t · (u * k_t) v_t
        bonus = jnp.einsum("bchk,hk,bchk->bch", rj, u.astype(f32), kj)
        o_cur = bonus[..., None] * vj
        # state update: s' = s * exp(la_C) + Σ_s (k_s exp(la_C - la_s)) v_s
        laC = la[:, -1:]  # (B,1,H,K)
        k_dec = kj * jnp.exp(laC - la)
        s_new = s * jnp.exp(laC[:, 0])[..., None] + jnp.einsum(
            "bchk,bchv->bhkv", k_dec, vj
        )
        return s_new, o_inter + o_intra + o_cur

    if unroll:
        s, outs_l = s0, []
        for j in range(N):
            s, oj = chunk_step(s, (rc[:, j], kc[:, j], vc[:, j], lwc[:, j]))
            outs_l.append(oj)
        sF = s
        out = jnp.concatenate(outs_l, axis=1)
    else:
        sF, outs = jax.lax.scan(chunk_step, s0, (
            rc.transpose(1, 0, 2, 3, 4),
            kc.transpose(1, 0, 2, 3, 4),
            vc.transpose(1, 0, 2, 3, 4),
            lwc.transpose(1, 0, 2, 3, 4),
        ))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, V)
    return out.astype(v.dtype), sF


def wkv6_step(r, k, v, lw, u, state):
    """Single-token recurrence for decode.  r,k,lw: (B,H,K); v: (B,H,V);
    state: (B,H,K,V) → (out (B,H,V), new state)."""
    f32 = jnp.float32
    out_dtype = v.dtype
    r, k, v, lw = (x.astype(f32) for x in (r, k, v, lw))
    kv = k[..., None] * v[..., None, :]  # (B,H,K,V)
    s_att = state + u.astype(f32)[None, :, :, None] * kv
    out = jnp.einsum("bhk,bhkv->bhv", r, s_att)
    new_state = jnp.exp(lw)[..., None] * state + kv
    return out.astype(out_dtype), new_state


# ---------------------------------------------------------------------------
# RWKV6 blocks
# ---------------------------------------------------------------------------


def timemix_specs(cfg: ArchConfig) -> dict:
    E = cfg.d_model
    H = E // cfg.rwkv_head_dim
    K = cfg.rwkv_head_dim
    dd = 64  # decay LoRA rank (time_decay_extra_dim)
    return {
        "mix_r": ParamSpec((E,), (None,), jnp.float32, 0.0),
        "mix_k": ParamSpec((E,), (None,), jnp.float32, 0.0),
        "mix_v": ParamSpec((E,), (None,), jnp.float32, 0.0),
        "mix_w": ParamSpec((E,), (None,), jnp.float32, 0.0),
        "mix_g": ParamSpec((E,), (None,), jnp.float32, 0.0),
        "wr": ParamSpec((E, H, K), ("embed", "heads", None)),
        "wk": ParamSpec((E, H, K), ("embed", "heads", None)),
        "wv": ParamSpec((E, H, K), ("embed", "heads", None)),
        "wg": ParamSpec((E, H, K), ("embed", "heads", None)),
        "wo": ParamSpec((H, K, E), ("heads", None, "embed")),
        "decay_base": ParamSpec((H, K), ("heads", None), jnp.float32, 0.02),
        "decay_lora_a": ParamSpec((E, dd), ("embed", None), jnp.float32),
        "decay_lora_b": ParamSpec((dd, H, K), (None, "heads", None), jnp.float32),
        "bonus_u": ParamSpec((H, K), ("heads", None), jnp.float32),
        "ln_x": ParamSpec((E,), (None,), jnp.float32, 1.0),
    }


def channelmix_specs(cfg: ArchConfig) -> dict:
    E, F = cfg.d_model, cfg.d_ff
    return {
        "mix_k": ParamSpec((E,), (None,), jnp.float32, 0.0),
        "wk": ParamSpec((E, F), ("embed", "mlp")),
        "wv": ParamSpec((F, E), ("mlp", "embed")),
        "wr": ParamSpec((E, E), ("embed", "embed2")),
    }


def _shift(x, last):
    """Token shift: x_{t-1} with ``last`` filling position 0.
    x (B,S,E); last (B,1,E)."""
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def apply_timemix(ctx, p, x, last, wkv_state, *, decode: bool):
    cfg = ctx.cfg
    E = cfg.d_model
    H, K = E // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    B, S, _ = x.shape
    xs = _shift(x, last)

    def lerp(mix):
        m = mix.astype(x.dtype)
        return x + (xs - x) * m

    xr, xk, xv, xw, xg = (lerp(p[f"mix_{n}"]) for n in ("r", "k", "v", "w", "g"))
    r = jnp.einsum("bse,ehk->bshk", xr, p["wr"])
    k = jnp.einsum("bse,ehk->bshk", xk, p["wk"])
    v = jnp.einsum("bse,ehk->bshk", xv, p["wv"])
    g = jnp.einsum("bse,ehk->bshk", xg, p["wg"])
    # data-dependent decay (the Finch feature): w = exp(-exp(base + lora(xw)))
    dd = jnp.einsum(
        "bse,ed->bsd", xw.astype(jnp.float32), p["decay_lora_a"]
    )
    dd = jnp.einsum("bsd,dhk->bshk", jnp.tanh(dd), p["decay_lora_b"])
    lw = -jnp.exp(jnp.clip(p["decay_base"] + dd, -8.0, 4.0))  # log decay ≤ 0

    if decode:
        o, new_state = wkv6_step(
            r[:, 0], k[:, 0], v[:, 0], lw[:, 0], p["bonus_u"], wkv_state
        )
        o = o[:, None]  # (B,1,H,V)
    else:
        o, new_state = wkv6_chunked(r, k, v, lw, p["bonus_u"], wkv_state,
                                    unroll=not ctx.cfg.scan_layers)

    # group-norm over heads (ln_x), then output gate
    o = o.reshape(B, S, H, K)
    o = L.rmsnorm_nogain(o) * p["ln_x"].reshape(H, K).astype(o.dtype)
    o = o * jax.nn.silu(g)
    out = jnp.einsum("bshk,hke->bse", o, p["wo"])
    new_last = x[:, -1:]
    return ctx.constrain(out, ("batch", None, None)), new_last, new_state


def apply_channelmix(ctx, p, x, last):
    xs = _shift(x, last)
    xk = x + (xs - x) * p["mix_k"].astype(x.dtype)
    kk = jnp.einsum("bse,ef->bsf", xk, p["wk"])
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("bsf,fe->bse", kk, p["wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bse,ee->bse", x, p["wr"]))
    return ctx.constrain(rr * vv, ("batch", None, None)), x[:, -1:]


def rwkv_block_specs(cfg: ArchConfig) -> dict:
    return {
        "ln1": L.norm_specs(cfg, cfg.d_model),
        "att": timemix_specs(cfg),
        "ln2": L.norm_specs(cfg, cfg.d_model),
        "ffn": channelmix_specs(cfg),
    }


def apply_rwkv_block(ctx, p, x, state, *, decode: bool):
    """state: {"att_last", "ffn_last", "wkv"}."""
    cfg = ctx.cfg
    h = L.apply_norm(cfg, p["ln1"], x)
    att, att_last, wkv = apply_timemix(
        ctx, p["att"], h, state["att_last"], state["wkv"], decode=decode
    )
    x = x + att
    h = L.apply_norm(cfg, p["ln2"], x)
    ffn, ffn_last = apply_channelmix(ctx, p["ffn"], h, state["ffn_last"])
    x = x + ffn
    return x, {"att_last": att_last, "ffn_last": ffn_last, "wkv": wkv}


class RWKV6LM:
    """Attention-free LM; state (not KV) carries decode context."""

    def __init__(self, ctx: ModelContext):
        self.ctx = ctx
        self.cfg = ctx.cfg

    def param_specs(self) -> dict:
        cfg = self.cfg
        from repro.models.transformer import stack_specs

        return {
            "embed": L.embed_specs(cfg),
            "layers": stack_specs(rwkv_block_specs(cfg), cfg.n_layers),
            "final_norm": L.norm_specs(cfg, cfg.d_model),
        }

    def state_specs(self, batch_size: int) -> dict:
        cfg = self.cfg
        E = cfg.d_model
        H, K = E // cfg.rwkv_head_dim, cfg.rwkv_head_dim
        dt = jnp.dtype(cfg.dtype)
        per = {
            "att_last": ParamSpec((batch_size, 1, E), ("batch", None, None), dt, 0.0),
            "ffn_last": ParamSpec((batch_size, 1, E), ("batch", None, None), dt, 0.0),
            "wkv": ParamSpec(
                (batch_size, H, K, K), ("batch", "heads", None, None), jnp.float32, 0.0
            ),
        }
        from repro.models.transformer import stack_specs

        return stack_specs(per, cfg.n_layers)

    def _zero_state(self, B):
        from repro.dist.sharding import materialize_params

        return materialize_params(self.state_specs(B), jax.random.PRNGKey(0))

    def _run(self, params, x, state, *, decode: bool):
        ctx = self.ctx

        def body(x, xs):
            p, st = xs
            out, new_st = apply_rwkv_block(ctx, p, x, st, decode=decode)
            return out, new_st

        from repro.models.transformer import _remat

        x, new_state = L.scan_stack(
            self.cfg, _remat(self.cfg, body), x, (params["layers"], state)
        )
        return x, new_state

    def loss(self, params, batch):
        cfg, ctx = self.cfg, self.ctx
        tokens, labels = batch["tokens"], batch["labels"]
        x = L.apply_embed(ctx, params["embed"], tokens)
        state = self._zero_state(tokens.shape[0])
        h, _ = self._run(params, x, state, decode=False)
        hn = L.apply_norm(cfg, params["final_norm"], h)
        logits = L.apply_unembed(ctx, params["embed"], hn)
        loss = L.cross_entropy(ctx, logits, labels)
        return loss, {"ce": loss}

    def prefill(self, params, tokens, max_len: int = 0):
        cfg, ctx = self.cfg, self.ctx
        x = L.apply_embed(ctx, params["embed"], tokens)
        state = self._zero_state(tokens.shape[0])
        h, state = self._run(params, x, state, decode=False)
        hn = L.apply_norm(cfg, params["final_norm"], h[:, -1:])
        logits = L.apply_unembed(ctx, params["embed"], hn)
        return logits[:, 0], state

    def decode_step(self, params, state, tokens, index=None):
        cfg, ctx = self.cfg, self.ctx
        x = L.apply_embed(ctx, params["embed"], tokens)
        h, new_state = self._run(params, x, state, decode=True)
        hn = L.apply_norm(cfg, params["final_norm"], h)
        logits = L.apply_unembed(ctx, params["embed"], hn)
        return logits[:, 0], new_state

    cache_specs = None  # uses state_specs instead (O(1) decode state)
