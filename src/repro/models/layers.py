"""Shared layer library for the model zoo.

Functional style: each block exposes ``*_specs(cfg) -> pytree[ParamSpec]``
and ``apply_*(ctx, params, ...)``.  Parameters are declared with *logical*
axes (see dist/sharding.py) so the same definitions shard on any mesh.

Attention is computed blockwise (flash-style online softmax in pure jnp) so
32k-token prefill never materializes an (S×S) score matrix; the Pallas
flash-attention kernel (kernels/) is the TPU fast path behind the same API.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.dist.sharding import AxisRules, ParamSpec, shard_constraint


@dataclass
class ModelContext:
    """Everything ``apply_*`` needs besides params."""

    cfg: ArchConfig
    mesh: Mesh
    rules: AxisRules
    use_kernels: bool = False  # Pallas path (TPU); jnp blockwise otherwise

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.shape)

    def constrain(self, x, axes):
        return shard_constraint(x, axes, self.rules, self.mesh)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def scan_stack(cfg: ArchConfig, body, carry, xs):
    """``lax.scan`` over stacked layer params — or a Python unroll when
    ``cfg.scan_layers`` is False.

    Every layer-stack loop in the model zoo must go through this helper:
    the dry-run's roofline probes lower reduced-depth UNROLLED variants
    (``scan_layers=False``) because XLA's cost_analysis visits a while-loop
    body once, not trip-count times.  A path that scans unconditionally
    silently under-reports FLOPs/bytes by ~n_layers×.
    """
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    # stack per-layer outputs exactly like scan would (None-trees stay None)
    stacked = jax.tree.map(lambda *a: jnp.stack(a, 0), *ys) if ys else None
    return carry, stacked


def norm_specs(cfg: ArchConfig, d: int) -> dict:
    s = {"scale": ParamSpec((d,), (None,), jnp.float32, init_scale=1.0)}
    if cfg.norm == "layernorm":
        s["bias"] = ParamSpec((d,), (None,), jnp.float32, init_scale=0.0)
    return s


def apply_norm(cfg: ArchConfig, params: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * params["scale"] + params["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + 1e-6) * params["scale"]
    return out.astype(x.dtype)


def rmsnorm_nogain(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf**2, -1, keepdims=True) + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (incl. partial rotary and Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def rope_cos_sin(positions: jax.Array, dim: int, theta: float):
    """positions (..., S) → cos/sin (..., S, dim/2)."""
    ang = positions[..., None].astype(jnp.float32) * rope_freqs(dim, theta)
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions: jax.Array, dim: int, theta: float, sections):
    """M-RoPE: positions (3, B, S); frequency dims split into ``sections``
    (t, h, w), each rotated by its own position stream (arXiv:2409.12191)."""
    freqs = rope_freqs(dim, theta)  # (dim/2,)
    ang_all = positions[..., None].astype(jnp.float32) * freqs  # (3, B, S, dim/2)
    idx = []
    for i, sec in enumerate(sections):
        idx.extend([i] * sec)
    sel = np.asarray(idx)  # (dim/2,) which position stream each freq uses
    ang = jnp.where(sel == 0, ang_all[0], jnp.where(sel == 1, ang_all[1], ang_all[2]))
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array, rotary_dim: int):
    """x (B, S, H, D); cos/sin (B, S, rotary_dim/2) — rotate first rotary_dim."""
    if rotary_dim == 0:
        return x
    xr, xp = x[..., :rotary_dim], x[..., rotary_dim:]
    x1, x2 = jnp.split(xr, 2, axis=-1)
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    rot = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([rot, xp], axis=-1)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------


def _attn_chunks(seq: int, target: int) -> int:
    """Largest divisor of ``seq`` that is ≤ ``target`` (chunk size).

    A full divisor scan matters for awkward lengths: whisper's 1500-frame
    encoder gets 750 (2 chunks) instead of 4 (375 chunks of 4 — a
    scheduling and MXU-utilization disaster).
    """
    for c in range(min(target, seq), 0, -1):
        if seq % c == 0:
            return c
    return 1


def blockwise_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, Dv)
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    scale: float | None = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    unroll: bool = False,
    causal_skip: bool = False,
) -> jax.Array:
    """Flash-style attention in pure jnp: online softmax over KV chunks,
    outer map over Q chunks.  Never materializes (Sq × Sk).  GQA handled by
    grouped einsum (no KV repetition).

    ``unroll=True`` replaces the chunk scan/map with Python loops (identical
    math) so XLA cost_analysis sees every chunk — required by the dry-run's
    roofline probes, which measure reduced-seq unrolled variants.
    """
    B, Sq, H, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qc = _attn_chunks(Sq, q_chunk)
    kc = _attn_chunks(Sk, kv_chunk)
    nq, nk = Sq // qc, Sk // kc

    qg = q.reshape(B, Sq, Hkv, G, D)
    q_pos_base = jnp.arange(qc)
    k_pos_base = jnp.arange(kc)

    def q_block(carry_i):
        i, = carry_i
        qi = jax.lax.dynamic_slice_in_dim(qg, i * qc, qc, axis=1)  # (B,qc,Hkv,G,D)
        q_pos = q_pos_base + i * qc + q_offset

        def kv_step(state, j):
            m, l, acc = state
            kj = jax.lax.dynamic_slice_in_dim(k, j * kc, kc, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * kc, kc, axis=1)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qi, kj, preferred_element_type=jnp.float32
            ) * scale
            if causal:
                k_pos = k_pos_base + j * kc
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qc, Dv), jnp.float32)
        nk_live = nk
        if causal and causal_skip and isinstance(i, int) and isinstance(q_offset, int):
            # beyond-paper optimization: KV chunks entirely above the causal
            # diagonal contribute nothing — skip them statically.  Halves
            # attention FLOPs for prefill/train (the scanned-over-q version
            # must run every chunk and mask).
            nk_live = min(nk, (i * qc + qc - 1 + q_offset) // kc + 1)
        if unroll:
            st = (m0, l0, a0)
            for j in range(nk_live):
                st, _ = kv_step(st, j)
            m, l, acc = st
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), jnp.arange(nk_live)
            )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(B, qc, H, Dv)

    if nq == 1:
        out = q_block((0,))
    elif unroll or (causal and causal_skip and isinstance(q_offset, int)):
        # static python loop over q blocks: each block sees its own (static)
        # number of live KV chunks; program size grows by nq — acceptable at
        # nq ≤ 32 and required for the causal skip.
        out = jnp.concatenate([q_block((i,)) for i in range(nq)], axis=1)
    else:
        out = jax.lax.map(lambda i: q_block((i,)), jnp.arange(nq))
        out = out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dv)
    return out.astype(v.dtype)


def decode_attention(
    q: jax.Array,  # (B, T, H, D) — T freshly written decode tokens
    k_cache: jax.Array,  # (B, S, Hkv, D)
    v_cache: jax.Array,  # (B, S, Hkv, Dv)
    length: jax.Array,  # (,) valid length through the FIRST query's position
    *,
    scale: float | None = None,
) -> jax.Array:
    """Attention for T ≥ 1 decode tokens over a (possibly sequence-sharded)
    KV cache.  ``length`` counts valid cache entries through the first
    query's own position (``cache_index + 1``); query ``t`` additionally
    sees the ``t`` queries written before it, i.e. attends keys
    ``< length + t``.  T == 1 is the classic single-token decode step;
    T == k+1 is the speculative verify pass."""
    B, T, H, D = q.shape
    _, S, Hkv, Dv = v_cache.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, T, Hkv, G, D)
    s = jnp.einsum(
        "bthgd,bshd->bhgts", qg, k_cache, preferred_element_type=jnp.float32
    )
    s = s * scale
    mask = jnp.arange(S)[None, :] < (length + jnp.arange(T))[:, None]  # (T, S)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgts,bshd->bthgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, T, H, Dv).astype(v_cache.dtype)


def paged_decode_attention(
    q: jax.Array,  # (B, T, H, D) — T freshly written decode tokens
    k_pages: jax.Array,  # (P, page_size, Hkv, D)
    v_pages: jax.Array,  # (P, page_size, Hkv, Dv)
    block_tables: jax.Array,  # (B, n) int32 physical page ids, token order
    lens: jax.Array,  # (B,) valid tokens through each row's FIRST query
    *,
    scale: float | None = None,
) -> jax.Array:
    """jnp reference for the paged decode kernel: gather each sequence's
    pages through its block table into a contiguous view, then attend with
    a per-sequence length mask.  Query ``t`` of row ``b`` sits at absolute
    position ``lens[b] - 1 + t`` and attends keys ``< lens[b] + t`` (T == 1
    is the single-token decode step, T == k+1 the speculative verify).
    ``lens[b] == 0`` rows produce garbage (a uniform average), never NaN —
    idle serving slots are unread anyway."""
    B, T, H, D = q.shape
    P, ps, Hkv, Dv = v_pages.shape
    n = block_tables.shape[1]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bt = jnp.clip(block_tables, 0, P - 1)
    k = k_pages[bt].reshape(B, n * ps, Hkv, k_pages.shape[-1])
    v = v_pages[bt].reshape(B, n * ps, Hkv, Dv)
    qg = q.reshape(B, T, Hkv, G, D)
    s = jnp.einsum("bthgd,bshd->bhgts", qg, k, preferred_element_type=jnp.float32)
    s = s * scale
    mask = (
        jnp.arange(n * ps)[None, None, :]
        < (lens[:, None] + jnp.arange(T)[None, :])[:, :, None]
    )  # (B, T, n*ps)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgts,bshd->bthgd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, T, H, Dv).astype(v.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (dense/MoE/encdec/hybrid families)
# ---------------------------------------------------------------------------


def _decode_attention_core(ctx: "ModelContext", q, k_cache, v_cache, length):
    """Decode-step dispatch: when kernels are enabled, view the dense
    per-slot cache as contiguous pages (an arange block table) and run the
    paged-attention kernel; else the plain masked jnp decode attention.
    Handles T ≥ 1 query tokens (q ``(B, T, H, D)``): both backends mask
    query ``t`` to keys ``< length + t``."""
    B, S, Hkv, Dv = v_cache.shape
    if ctx.use_kernels and q.shape[-1] == Dv and S % 16 == 0:
        from repro.kernels.ops import paged_attention

        ps = 16
        n = S // ps
        kp = k_cache.reshape(B * n, ps, Hkv, k_cache.shape[-1])
        vp = v_cache.reshape(B * n, ps, Hkv, Dv)
        bt = jnp.arange(B * n, dtype=jnp.int32).reshape(B, n)
        lens = jnp.full((B,), length, jnp.int32)
        return paged_attention(q, kp, vp, bt, lens)
    return decode_attention(q, k_cache, v_cache, length)


def _attention_core(ctx: "ModelContext", q, k, v, *, causal: bool,
                    scale: float | None = None):
    """Dispatch: Pallas flash-attention kernel (TPU / interpret) when
    ``ctx.use_kernels`` and shapes allow (uniform head dim, no custom
    scale), else the pure-jnp blockwise path."""
    cfg = ctx.cfg
    if (ctx.use_kernels and scale is None
            and q.shape[-1] == v.shape[-1] and cfg.scan_layers):
        from repro.kernels.ops import flash_attention

        return flash_attention(q, k, v, causal=causal)
    return blockwise_attention(q, k, v, causal=causal, scale=scale,
                               unroll=not cfg.scan_layers,
                               causal_skip=cfg.attn_causal_skip)


def attention_specs(cfg: ArchConfig, *, cross: bool = False) -> dict:
    E, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    return {
        "wq": ParamSpec((E, H, Dh), ("embed", "heads", None)),
        "wk": ParamSpec((E, Hkv, Dh), ("embed", "kv_heads", None)),
        "wv": ParamSpec((E, Hkv, Dh), ("embed", "kv_heads", None)),
        "wo": ParamSpec((H, Dh, E), ("heads", None, "embed")),
    }


def apply_attention(
    ctx: ModelContext,
    params: dict,
    x: jax.Array,  # (B, S, E)
    *,
    rope: tuple | None = None,  # (cos, sin) or None
    kv: jax.Array | None = None,  # cross-attention source (B, Skv, E)
    causal: bool = True,
    cache: dict | None = None,  # {"k","v"} (B, Smax, Hkv, Dh) + decode
    cache_index: jax.Array | None = None,
) -> tuple[jax.Array, dict | None]:
    cfg = ctx.cfg
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    rotary_dim = int(cfg.rotary_pct * Dh) if cfg.rotary_pct else 0
    rotary_dim -= rotary_dim % 2

    src = x if kv is None else kv
    q = jnp.einsum("bse,ehd->bshd", x, params["wq"])
    k = jnp.einsum("bse,ehd->bshd", src, params["wk"])
    v = jnp.einsum("bse,ehd->bshd", src, params["wv"])

    if rope is not None and rotary_dim:
        cos, sin = rope
        if cache_index is not None:
            # decode: rotate q at absolute position cache_index
            q = apply_rope(q, cos, sin, rotary_dim)
            k = apply_rope(k, cos, sin, rotary_dim)
        else:
            q = apply_rope(q, cos, sin, rotary_dim)
            k = apply_rope(k, cos, sin, rotary_dim)

    new_cache = None
    if cache is not None:
        if cache_index is not None:  # decode step: append one token
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_index, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_index, 1)
            new_cache = {"k": k_cache, "v": v_cache}
            o = _decode_attention_core(ctx, q, k_cache, v_cache, cache_index + 1)
        else:  # prefill: fill cache, run blockwise
            new_cache = {"k": k, "v": v}
            o = _attention_core(ctx, q, k, v, causal=causal)
    else:
        o = _attention_core(ctx, q, k, v, causal=causal)

    out = jnp.einsum("bshd,hde->bse", o, params["wo"])
    return ctx.constrain(out, ("batch", "seq", None)), new_cache


# ---------------------------------------------------------------------------
# MLA attention (deepseek-v3)
# ---------------------------------------------------------------------------


def mla_specs(cfg: ArchConfig) -> dict:
    E, H = cfg.d_model, cfg.n_heads
    ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": ParamSpec((E, ql), ("embed", None)),
        "q_norm": ParamSpec((ql,), (None,), jnp.float32, init_scale=1.0),
        "w_uq": ParamSpec((ql, H, dn + dr), (None, "heads", None)),
        "w_dkv": ParamSpec((E, kvl), ("embed", None)),
        "kv_norm": ParamSpec((kvl,), (None,), jnp.float32, init_scale=1.0),
        "w_kr": ParamSpec((E, dr), ("embed", None)),
        "w_uk": ParamSpec((kvl, H, dn), (None, "heads", None)),
        "w_uv": ParamSpec((kvl, H, dv), (None, "heads", None)),
        "wo": ParamSpec((H, dv, E), ("heads", None, "embed")),
    }


def _mla_qkr(ctx, params, x, rope):
    """Shared q / rope-key computation."""
    cfg = ctx.cfg
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rmsnorm_nogain(jnp.einsum("bse,eq->bsq", x, params["w_dq"])) * params[
        "q_norm"
    ].astype(x.dtype)
    q = jnp.einsum("bsq,qhd->bshd", cq, params["w_uq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    k_rope = jnp.einsum("bse,ed->bsd", x, params["w_kr"])[:, :, None, :]  # 1 head
    cos, sin = rope
    q_rope = apply_rope(q_rope, cos, sin, dr)
    k_rope = apply_rope(k_rope, cos, sin, dr)
    return q_nope, q_rope, k_rope


def apply_mla(
    ctx: ModelContext,
    params: dict,
    x: jax.Array,
    *,
    rope: tuple,
    cache: dict | None = None,  # {"ckv": (B,Smax,kvl), "kr": (B,Smax,1,dr)}
    cache_index: jax.Array | None = None,
) -> tuple[jax.Array, dict | None]:
    """Multi-head latent attention.  Cache stores only the compressed
    (c_kv, k_rope) — MLA's memory saving.  Decode uses weight absorption."""
    cfg = ctx.cfg
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(dn + dr)

    q_nope, q_rope, k_rope = _mla_qkr(ctx, params, x, rope)
    ckv = rmsnorm_nogain(jnp.einsum("bse,ek->bsk", x, params["w_dkv"])) * params[
        "kv_norm"
    ].astype(x.dtype)

    new_cache = None
    if cache is not None and cache_index is not None:
        # -- decode: absorbed attention over compressed cache --------------
        ckv_c = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, cache_index, 1)
        kr_c = jax.lax.dynamic_update_slice_in_dim(cache["kr"], k_rope, cache_index, 1)
        new_cache = {"ckv": ckv_c, "kr": kr_c}
        # absorb W_uk into q: q_eff (B,1,H,kvl)
        q_eff = jnp.einsum("bshd,khd->bshk", q_nope, params["w_uk"])
        s = jnp.einsum("bshk,btk->bhst", q_eff, ckv_c, preferred_element_type=jnp.float32)
        s += jnp.einsum(
            "bshd,btod->bhst", q_rope, kr_c, preferred_element_type=jnp.float32
        )
        S = ckv_c.shape[1]
        Sq = x.shape[1]
        # query t (of Sq freshly written tokens) attends keys < index+1+t
        mask = jnp.arange(S)[None, :] < (cache_index + 1 + jnp.arange(Sq))[:, None]
        s = jnp.where(mask[None, None], s * scale, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btk->bshk", p.astype(ckv_c.dtype), ckv_c)
        o = jnp.einsum("bshk,khd->bshd", o_lat, params["w_uv"])
    else:
        # -- train/prefill: expanded attention ------------------------------
        k_nope = jnp.einsum("bsk,khd->bshd", ckv, params["w_uk"])
        v = jnp.einsum("bsk,khd->bshd", ckv, params["w_uv"])
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, k_rope.shape[:2] + (H, dr))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        # MLA has qk-dim 192 ≠ v-dim 128 → always the jnp blockwise path
        # (the Pallas kernel assumes a uniform head dim).
        o = _attention_core(ctx, q, k, v, causal=True, scale=scale)
        if cache is not None:
            new_cache = {"ckv": ckv, "kr": k_rope}

    out = jnp.einsum("bshd,hde->bse", o, params["wo"])
    return ctx.constrain(out, ("batch", "seq", None)), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ArchConfig, d_ff: int | None = None, gated: bool = True) -> dict:
    E, F = cfg.d_model, d_ff or cfg.d_ff
    s = {
        "wi": ParamSpec((E, F), ("embed", "mlp")),
        "wo": ParamSpec((F, E), ("mlp", "embed")),
    }
    if gated:
        s["wg"] = ParamSpec((E, F), ("embed", "mlp"))
    return s


def apply_mlp(ctx: ModelContext, params: dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bse,ef->bsf", x, params["wi"])
    if "wg" in params:
        g = jnp.einsum("bse,ef->bsf", x, params["wg"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    out = jnp.einsum("bsf,fe->bse", h, params["wo"])
    return ctx.constrain(out, ("batch", "seq", None))


# ---------------------------------------------------------------------------
# Embedding / logits / loss
# ---------------------------------------------------------------------------


def embed_specs(cfg: ArchConfig) -> dict:
    s = {
        "embedding": ParamSpec(
            (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), init_scale=0.02
        )
    }
    if not cfg.tie_embeddings:
        s["unembed"] = ParamSpec(
            (cfg.d_model, cfg.padded_vocab), ("embed", "vocab"), init_scale=0.02
        )
    return s


def apply_embed(ctx: ModelContext, params: dict, tokens: jax.Array) -> jax.Array:
    out = jnp.take(params["embedding"], tokens, axis=0)
    return ctx.constrain(out.astype(ctx.compute_dtype), ("batch", "seq", None))


def apply_unembed(ctx: ModelContext, params: dict, x: jax.Array) -> jax.Array:
    if "unembed" in params:
        logits = jnp.einsum("bse,ev->bsv", x, params["unembed"])
    else:
        logits = jnp.einsum("bse,ve->bsv", x, params["embedding"])
    return ctx.constrain(logits, ("batch", None, "vocab"))


def cross_entropy(
    ctx: ModelContext,
    logits: jax.Array,
    labels: jax.Array,
    *,
    z_weight: float = 1e-4,
) -> jax.Array:
    """Next-token CE in fp32 with z-loss; padded-vocab columns masked.

    ``labels < 0`` positions (padding / vision-prefix) are excluded.
    """
    cfg = ctx.cfg
    lg = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        lg = jnp.where(pad_mask, lg, -1e30)
    valid = labels >= 0
    safe_labels = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, safe_labels[..., None], axis=-1)[..., 0]
    per_tok = (lse - gold) + z_weight * jnp.square(lse)
    per_tok = jnp.where(valid, per_tok, 0.0)
    denom = jnp.maximum(valid.sum(), 1)
    return per_tok.sum() / denom
