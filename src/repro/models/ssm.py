"""Mamba2 (SSD) blocks + the Zamba2 hybrid LM.

Zamba2: a Mamba2 backbone with ONE shared attention+MLP transformer block
whose weights are reused every ``shared_attn_every`` layers (arXiv:2411.15242;
per-invocation LoRA omitted — DESIGN.md §7).  Each shared-block *invocation*
keeps its own KV cache at decode time.

The SSD recurrence  h_t = a_t·h_{t-1} + (Δ_t x_t) ⊗ B_t,  y_t = C_t·h_t + D·x_t
(scalar decay per head) is computed chunkwise-parallel in log space — the
same scheme as the Pallas ``ssd`` kernel (kernels/ssd.py); decode is the O(1)
single-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import ParamSpec
from repro.models import layers as L
from repro.models.layers import ModelContext
from repro.models.transformer import _remat, stack_specs


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads, cfg.ssm_head_dim, cfg.ssm_state


def ssd_chunked(x, dt, a_log, Bm, Cm, D, state=None, chunk: int = 128,
                unroll: bool = False):
    """Chunkwise SSD.  x (B,S,H,P); dt (B,S,H) ≥0; a_log (B,S,H) = log decay
    per step (≤0); Bm/Cm (B,S,N); D (H,).  Returns (y, final state (B,H,P,N)).

    ``unroll=True``: Python chunk loop (same math) for roofline probes."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    from repro.models.rwkv import _chunk_size

    C = _chunk_size(S, chunk)
    Nc = S // C

    xc = x.reshape(Bsz, Nc, C, H, P).astype(f32)
    dtc = dt.reshape(Bsz, Nc, C, H).astype(f32)
    lac = a_log.reshape(Bsz, Nc, C, H).astype(f32)
    Bc = Bm.reshape(Bsz, Nc, C, N).astype(f32)
    Cc = Cm.reshape(Bsz, Nc, C, N).astype(f32)

    s0 = state.astype(f32) if state is not None else jnp.zeros((Bsz, H, P, N), f32)

    def step(s, xs):
        xj, dtj, laj, Bj, Cj = xs  # (B,C,H,P) (B,C,H) (B,C,H) (B,C,N) (B,C,N)
        la = jnp.cumsum(laj, axis=1)  # (B,C,H) inclusive cumulative log decay
        # inter-chunk: y_t += C_t · (s * exp(la_t))
        y_inter = jnp.einsum("bcn,bhpn,bch->bchp", Cj, s, jnp.exp(la))
        # intra-chunk: y_t += Σ_{s≤t} exp(la_t-la_s)(C_t·B_s) Δ_s x_s
        cb = jnp.einsum("bcn,bsn->bcs", Cj, Bj)  # (B,C,C)
        mask = jnp.tril(jnp.ones((C, C), bool))
        # decay diff masked BEFORE exp (≤0 in causal region → overflow-safe)
        diff = la[:, :, None, :] - la[:, None, :, :]  # (B,C,C,H) [t,s]
        dec = jnp.exp(jnp.where(mask[None, :, :, None], diff, -jnp.inf))
        m = dec * cb[..., None]
        y_intra = jnp.einsum("bcsh,bsh,bshp->bchp", m, dtj, xj)
        # state update: s' = s·exp(la_C) + Σ_s exp(la_C-la_s) Δ_s x_s ⊗ B_s
        laC = la[:, -1]  # (B,H)
        w = jnp.exp(laC[:, None] - la) * dtj  # (B,C,H)
        s_new = s * jnp.exp(laC)[:, :, None, None] + jnp.einsum(
            "bch,bchp,bcn->bhpn", w, xj, Bj
        )
        return s_new, y_inter + y_intra

    if unroll:
        s, ys_l = s0, []
        for j in range(Nc):
            s, yj = step(s, (xc[:, j], dtc[:, j], lac[:, j], Bc[:, j], Cc[:, j]))
            ys_l.append(yj)
        sF = s
        y = jnp.concatenate(ys_l, axis=1)
    else:
        sF, ys = jax.lax.scan(step, s0, (
            xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
            lac.transpose(1, 0, 2, 3), Bc.transpose(1, 0, 2, 3),
            Cc.transpose(1, 0, 2, 3),
        ))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), sF


def ssd_step(x, dt, a_log, Bm, Cm, D, state):
    """Single-token SSD for decode.  x (B,H,P); dt/a_log (B,H); Bm/Cm (B,N);
    state (B,H,P,N)."""
    f32 = jnp.float32
    x32, dt32 = x.astype(f32), dt.astype(f32)
    s_new = state * jnp.exp(a_log.astype(f32))[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt32, x32, Bm.astype(f32)
    )
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(f32), s_new)
    y = y + x32 * D.astype(f32)[None, :, None]
    return y.astype(x.dtype), s_new


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def mamba2_specs(cfg: ArchConfig) -> dict:
    E = cfg.d_model
    d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N
    return {
        "ln": L.norm_specs(cfg, E),
        "in_proj_z": ParamSpec((E, d_inner), ("embed", "mlp")),
        "in_proj_x": ParamSpec((E, d_inner), ("embed", "mlp")),
        "in_proj_B": ParamSpec((E, N), ("embed", None)),
        "in_proj_C": ParamSpec((E, N), ("embed", None)),
        "in_proj_dt": ParamSpec((E, H), ("embed", "heads")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_dim), (None, "mlp"), jnp.float32),
        "dt_bias": ParamSpec((H,), ("heads",), jnp.float32),
        "a_log": ParamSpec((H,), ("heads",), jnp.float32),
        "D": ParamSpec((H,), ("heads",), jnp.float32),
        "norm_gate": ParamSpec((d_inner,), (None,), jnp.float32, 1.0),
        "out_proj": ParamSpec((d_inner, E), ("mlp", "embed")),
    }


def _causal_conv(u, w, conv_state=None):
    """Depthwise causal conv along S.  u (B,S,Dc); w (K,Dc);
    conv_state (B,K-1,Dc) carries the last K-1 inputs for decode/chunking."""
    K = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([conv_state, u], axis=1)
    out = sum(
        up[:, i : i + u.shape[1]] * w[i].astype(u.dtype) for i in range(K)
    )
    new_state = up[:, -(K - 1) :] if K > 1 else conv_state
    return jax.nn.silu(out), new_state


def apply_mamba2(ctx, p, x, state, *, decode: bool):
    """state: {"conv": (B,K-1,Dc), "ssd": (B,H,P,N)}."""
    cfg = ctx.cfg
    d_inner, H, P, N = _dims(cfg)
    B_, S, E = x.shape
    h = L.apply_norm(cfg, p["ln"], x)
    z = jnp.einsum("bse,ei->bsi", h, p["in_proj_z"])
    xs = jnp.einsum("bse,ei->bsi", h, p["in_proj_x"])
    Bm = jnp.einsum("bse,en->bsn", h, p["in_proj_B"])
    Cm = jnp.einsum("bse,en->bsn", h, p["in_proj_C"])
    dt = jnp.einsum("bse,eh->bsh", h, p["in_proj_dt"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    u = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_in_state = state["conv"] if decode else None
    u, new_conv = _causal_conv(u, p["conv_w"], conv_in_state)
    xs, Bm, Cm = u[..., :d_inner], u[..., d_inner : d_inner + N], u[..., d_inner + N :]
    xh = xs.reshape(B_, S, H, P)

    a = -jnp.exp(jnp.clip(p["a_log"], -8.0, 4.0))  # A < 0
    la = dt * a  # log decay per step (B,S,H)

    if decode:
        y, new_ssd = ssd_step(
            xh[:, 0], dt[:, 0], la[:, 0], Bm[:, 0], Cm[:, 0], p["D"], state["ssd"]
        )
        y = y[:, None]
    else:
        y, new_ssd = ssd_chunked(xh, dt, la, Bm, Cm, p["D"], state.get("ssd"),
                                 unroll=not ctx.cfg.scan_layers)

    y = y.reshape(B_, S, d_inner)
    y = L.rmsnorm_nogain(y * jax.nn.silu(z)) * p["norm_gate"].astype(y.dtype)
    out = jnp.einsum("bsi,ie->bse", y, p["out_proj"])
    out = ctx.constrain(out, ("batch", None, None))
    return x + out, {"conv": new_conv, "ssd": new_ssd}


# ---------------------------------------------------------------------------
# Zamba2 hybrid LM
# ---------------------------------------------------------------------------


class Zamba2LM:
    def __init__(self, ctx: ModelContext):
        self.ctx = ctx
        self.cfg = ctx.cfg
        n, e = ctx.cfg.n_layers, ctx.cfg.shared_attn_every
        # shared block invoked after layers e-1, 2e-1, … (Python-static plan)
        self.shared_points = [i for i in range(n) if i % e == e - 1] if e else []

    def param_specs(self) -> dict:
        cfg = self.cfg
        s = {
            "embed": L.embed_specs(cfg),
            "layers": stack_specs(mamba2_specs(cfg), cfg.n_layers),
            "final_norm": L.norm_specs(cfg, cfg.d_model),
        }
        if self.shared_points:
            s["shared"] = {
                "ln1": L.norm_specs(cfg, cfg.d_model),
                "attn": L.attention_specs(cfg),
                "ln2": L.norm_specs(cfg, cfg.d_model),
                "ffn": L.mlp_specs(cfg),
            }
        return s

    # -- states/caches -----------------------------------------------------
    def mamba_state_specs(self, batch_size: int) -> dict:
        cfg = self.cfg
        d_inner, H, P, N = _dims(cfg)
        conv_dim = d_inner + 2 * N
        dt = jnp.dtype(cfg.dtype)
        per = {
            "conv": ParamSpec(
                (batch_size, cfg.ssm_conv - 1, conv_dim), ("batch", None, "mlp"), dt, 0.0
            ),
            "ssd": ParamSpec(
                (batch_size, H, P, N), ("batch", "heads", None, None), jnp.float32, 0.0
            ),
        }
        return stack_specs(per, cfg.n_layers)

    def attn_cache_specs(self, batch_size: int, max_len: int) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        per = {
            "k": ParamSpec(
                (batch_size, max_len, cfg.n_kv_heads, cfg.head_dim_),
                ("batch", "kv_seq", "kv_heads", None), dt, 0.0,
            ),
            "v": ParamSpec(
                (batch_size, max_len, cfg.n_kv_heads, cfg.head_dim_),
                ("batch", "kv_seq", "kv_heads", None), dt, 0.0,
            ),
        }
        return stack_specs(per, len(self.shared_points))

    def state_specs(self, batch_size: int, max_len: int) -> dict:
        return {
            "mamba": self.mamba_state_specs(batch_size),
            "attn": self.attn_cache_specs(batch_size, max_len),
        }

    def _zero_mamba_state(self, B):
        from repro.dist.sharding import materialize_params

        return materialize_params(self.mamba_state_specs(B), jax.random.PRNGKey(0))

    # -- forward -------------------------------------------------------------
    def _run(self, params, x, mamba_state, attn_cache, rope, *, decode: bool,
             cache_index=None, collect_cache: bool = False):
        """Groups of mamba layers with shared-attn invocations between."""
        ctx, cfg = self.ctx, self.cfg
        e = cfg.shared_attn_every or cfg.n_layers
        n = cfg.n_layers
        new_mamba_chunks, new_attn = [], []
        inv = 0
        for g0 in range(0, n, e):
            g1 = min(g0 + e, n)
            lp = jax.tree.map(lambda a: a[g0:g1], params["layers"])
            st = jax.tree.map(lambda a: a[g0:g1], mamba_state)

            def body(x, xs):
                p, s = xs
                return apply_mamba2(ctx, p, x, s, decode=decode)

            x, new_st = L.scan_stack(cfg, _remat(cfg, body), x, (lp, st))
            new_mamba_chunks.append(new_st)
            if g1 - 1 in self.shared_points and "shared" in params:
                sp = params["shared"]
                h = L.apply_norm(cfg, sp["ln1"], x)
                cache_i = (
                    jax.tree.map(lambda a: a[inv], attn_cache)
                    if attn_cache is not None else None
                )
                if decode:
                    att, nc = L.apply_attention(
                        ctx, sp["attn"], h, rope=rope,
                        cache=cache_i, cache_index=cache_index,
                    )
                else:
                    att, nc = L.apply_attention(
                        ctx, sp["attn"], h, rope=rope,
                        cache={} if collect_cache else None, cache_index=None,
                    )
                x = x + att
                h2 = L.apply_norm(cfg, sp["ln2"], x)
                x = x + L.apply_mlp(ctx, sp["ffn"], h2)
                if nc is not None:
                    new_attn.append(nc)
                inv += 1
        new_mamba = jax.tree.map(
            lambda *cs: jnp.concatenate(cs, 0), *new_mamba_chunks
        )
        new_attn_stacked = (
            jax.tree.map(lambda *cs: jnp.stack(cs, 0), *new_attn) if new_attn else None
        )
        return x, new_mamba, new_attn_stacked

    def _rope(self, B, S, positions=None):
        cfg = self.cfg
        pos = positions if positions is not None else jnp.arange(S)[None]
        pos = jnp.broadcast_to(pos, (B, S))
        return L.rope_cos_sin(pos, cfg.head_dim_, cfg.rope_theta)

    def loss(self, params, batch):
        cfg, ctx = self.cfg, self.ctx
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        x = L.apply_embed(ctx, params["embed"], tokens)
        st = self._zero_mamba_state(B)
        h, _, _ = self._run(
            params, x, st, None, self._rope(B, S), decode=False
        )
        hn = L.apply_norm(cfg, params["final_norm"], h)
        logits = L.apply_unembed(ctx, params["embed"], hn)
        loss = L.cross_entropy(ctx, logits, labels)
        return loss, {"ce": loss}

    def prefill(self, params, tokens, max_len: int):
        cfg, ctx = self.cfg, self.ctx
        B, S = tokens.shape
        x = L.apply_embed(ctx, params["embed"], tokens)
        st = self._zero_mamba_state(B)
        h, new_mamba, new_attn = self._run(
            params, x, st, None, self._rope(B, S), decode=False, collect_cache=True
        )

        def pad(c):
            pad_len = max_len - c.shape[2]
            if pad_len <= 0:
                return c
            w = [(0, 0)] * c.ndim
            w[2] = (0, pad_len)
            return jnp.pad(c, w)

        new_attn = jax.tree.map(pad, new_attn) if new_attn is not None else None
        hn = L.apply_norm(cfg, params["final_norm"], h[:, -1:])
        logits = L.apply_unembed(ctx, params["embed"], hn)
        return logits[:, 0], {"mamba": new_mamba, "attn": new_attn}

    def decode_step(self, params, state, tokens, index):
        cfg, ctx = self.cfg, self.ctx
        B = tokens.shape[0]
        x = L.apply_embed(ctx, params["embed"], tokens)
        rope = self._rope(B, 1, positions=jnp.full((1, 1), index))
        h, new_mamba, new_attn = self._run(
            params, x, state["mamba"], state["attn"], rope,
            decode=True, cache_index=index,
        )
        hn = L.apply_norm(cfg, params["final_norm"], h)
        logits = L.apply_unembed(ctx, params["embed"], hn)
        return logits[:, 0], {"mamba": new_mamba, "attn": new_attn}


class Mamba2LM(Zamba2LM):
    """Pure-Mamba2 LM (shared_attn_every=0 config)."""
