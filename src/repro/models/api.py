"""Unified model API: ``build_model(ctx)`` + ``input_specs(...)``.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation — used by the multi-pod
dry-run and the roofline harness.  Modality frontends are stubs per the
assignment: whisper gets precomputed frame embeddings, qwen2-vl gets
precomputed patch embeddings + (t, h, w) M-RoPE position ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models.encdec import EncDecLM
from repro.models.layers import ModelContext
from repro.models.rwkv import RWKV6LM
from repro.models.ssm import Zamba2LM
from repro.models.transformer import DecoderLM


def build_model(ctx: ModelContext):
    fam = ctx.cfg.family
    if fam in ("dense", "moe", "mla_moe"):
        return DecoderLM(ctx)
    if fam == "encdec":
        return EncDecLM(ctx)
    if fam == "rwkv":
        return RWKV6LM(ctx)
    if fam == "hybrid":
        return Zamba2LM(ctx)
    raise ValueError(f"unknown family {fam!r}")


def train_input_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    i32 = jnp.int32
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq), i32),
    }
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_frames, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if cfg.use_mrope:
        specs["positions"] = jax.ShapeDtypeStruct((3, batch, seq), i32)
    if cfg.vision_embeds:
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision_embeds, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return specs


def train_input_shardings(cfg: ArchConfig, specs: dict, rules, mesh):
    """NamedShardings matching ``train_input_specs`` (batch over data axes)."""
    from jax.sharding import NamedSharding

    from repro.dist.sharding import logical_to_spec

    def spec_for(name, s):
        if name == "positions":
            axes = (None, "batch", None)
        elif name in ("frames", "vision_embeds"):
            axes = ("batch", None, None)
        else:
            axes = ("batch", None)
        return NamedSharding(mesh, logical_to_spec(s.shape, axes, rules, mesh))

    return {k: spec_for(k, v) for k, v in specs.items()}


def decode_input_specs(cfg: ArchConfig, batch: int) -> dict:
    return {
        "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }


def verify_input_specs(cfg: ArchConfig, batch: int, num_tokens: int) -> dict:
    """Abstract inputs for speculative decode's verify pass: ``num_tokens``
    (= spec_k + 1) stacked positions per row, each row at its own length —
    the ``verify_batch`` operand shapes the dry-run lowers against."""
    return {
        "tokens": jax.ShapeDtypeStruct((batch, num_tokens), jnp.int32),
        "lens": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def decode_cache_specs(model, cfg: ArchConfig, batch: int, max_len: int):
    """ParamSpec pytree for the decode-time cache/state of any family."""
    if cfg.family == "rwkv":
        return model.state_specs(batch)
    if cfg.family == "hybrid":
        return model.state_specs(batch, max_len)
    return model.cache_specs(batch, max_len)


def param_counts(model, cfg: ArchConfig) -> tuple[int, int]:
    """(total, active) parameter counts; active discounts routed experts."""
    import math

    from repro.dist.sharding import ParamSpec

    leaves = jax.tree.leaves(
        model.param_specs(), is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    total = active = 0
    for s in leaves:
        n = math.prod(s.shape)
        total += n
        if "expert" in s.axes and cfg.n_experts:
            active += n * cfg.top_k // cfg.n_experts
        else:
            active += n
    return total, active


def synth_batch(cfg: ArchConfig, batch: int, seq: int, rng=None) -> dict:
    """Materialized random batch matching train_input_specs (smoke tests)."""
    import numpy as np

    r = np.random.default_rng(0 if rng is None else rng)
    out = {
        "tokens": r.integers(0, cfg.vocab, (batch, seq)).astype(np.int32),
        "labels": r.integers(0, cfg.vocab, (batch, seq)).astype(np.int32),
    }
    if cfg.family == "encdec":
        out["frames"] = r.normal(size=(batch, cfg.encoder_frames, cfg.d_model)).astype(
            np.float32
        )
    if cfg.use_mrope:
        p = np.broadcast_to(np.arange(seq, dtype=np.int32), (batch, seq))
        out["positions"] = np.stack([p, p, p]).astype(np.int32)
    if cfg.vision_embeds:
        out["vision_embeds"] = r.normal(
            size=(batch, cfg.vision_embeds, cfg.d_model)
        ).astype(np.float32)
        out["labels"][:, : cfg.vision_embeds] = -1
    return out


def input_specs(cfg: ArchConfig, shape) -> dict | tuple:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    The assignment-level entry point: dispatches on the cell kind
    (train/prefill/decode) and returns weak-type-correct, shardable,
    allocation-free abstract inputs (the dry-run's lowering operands).
    """
    if shape.kind == "train":
        return train_input_specs(cfg, shape.global_batch, shape.seq_len)
    if shape.kind == "prefill":
        import jax, jax.numpy as jnp
        specs = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.encoder_frames, cfg.d_model),
                jnp.dtype(cfg.dtype))
        return specs
    return decode_input_specs(cfg, shape.global_batch)
