"""Logical-axis sharding: ParamSpec trees → mesh PartitionSpecs + init.

Model code declares every parameter as a :class:`ParamSpec` carrying
*logical* axis names (``embed``, ``heads``, ``mlp``, ``vocab``, ``batch``,
…).  An :class:`AxisRules` profile maps each logical axis to zero or more
mesh axes; :func:`logical_to_spec` resolves a concrete shape against a mesh
**shape-aware**:

- a mesh axis that is not present on the mesh is dropped (the same model
  definition runs on the 1-device smoke mesh, the 16×16 pod, and the
  2×16×16 multi-pod mesh);
- a mesh axis that does not divide the dimension is dropped (smollm's 9
  heads stay replicated on a 16-way model axis while mlp/vocab keep TP);
- a mesh axis already consumed by an earlier dimension of the same tensor
  is dropped (a PartitionSpec may use each mesh axis once).

Materialization (:func:`materialize_params`) folds the root PRNG key with a
hash of each leaf's tree path, so init is deterministic per-leaf and
completely independent of mesh shape — the property the elastic re-mesh and
checkpoint-restore paths rely on (same seed ⇒ bitwise-identical logical
arrays on any mesh).
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# ---------------------------------------------------------------------------
# ParamSpec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    """Abstract parameter leaf: shape + logical axes + dtype + init.

    The default dtype is bfloat16 — the model zoo's compute dtype — so a
    weight einsum never promotes activations out of it (scan carries must
    keep one dtype end-to-end); norm scales, router logits, moments and
    other precision-critical leaves opt into float32 explicitly.

    ``init_scale`` semantics (see :func:`materialize_params`):

    - ``None`` (default): fan-in-scaled normal, std = 1/√prod(shape[:-1]).
    - scalar, ndim ≤ 1: constant fill (norm scales ``1.0``, biases ``0.0``).
    - scalar ``0.0``, ndim ≥ 2: zeros (decode caches / recurrent states).
    - other scalar, ndim ≥ 2: normal with that std (embeddings ``0.02``).
    """

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    init_scale: float | None = None

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(self.shape))
        object.__setattr__(self, "axes", tuple(self.axes))
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"ParamSpec rank mismatch: shape {self.shape} vs axes {self.axes}"
            )

    @property
    def size(self) -> int:
        return math.prod(self.shape)


def _is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


# ---------------------------------------------------------------------------
# AxisRules profiles
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AxisRules:
    """Immutable logical-axis → mesh-axes table.

    Values are tuples of mesh axis names tried in order; unknown logical
    axes resolve to replicated.  Profiles derive from one another with
    :meth:`with_`.
    """

    name: str
    table: Mapping[str, tuple[str, ...]] = field(default_factory=dict)

    def __post_init__(self):
        norm = {}
        for k, v in dict(self.table).items():
            if v is None:
                v = ()
            elif isinstance(v, str):
                v = (v,)
            norm[k] = tuple(v)
        object.__setattr__(self, "table", norm)

    def get(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        return self.table.get(logical, ())

    def with_(self, name: str | None = None, **updates) -> "AxisRules":
        table = dict(self.table)
        table.update(updates)
        return AxisRules(name or self.name, table)

    def __repr__(self):
        return f"AxisRules({self.name!r})"


# Megatron-style TP over heads/mlp/vocab + DP over batch; embed replicated
# (activations are replicated across the model axis between blocks — the
# MoE dispatch in models/moe.py assumes exactly this).
DEFAULT_RULES = AxisRules(
    "default",
    {
        "batch": ("data",),
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "expert": ("model",),
    },
)

# 2-pod mesh: batch shards over (pod, data); TP stays intra-pod (ICI) so the
# only DCN collective is the gradient all-reduce over ``pod``.
MULTIPOD_RULES = DEFAULT_RULES.with_("multipod", batch=("pod", "data"))

# Pure data parallelism: batch over every mesh axis, params replicated.
FLAT_DP_RULES = AxisRules("flat_dp", {"batch": ("data", "model")})
FLAT_DP_MULTIPOD_RULES = AxisRules(
    "flat_dp_multipod", {"batch": ("pod", "data", "model")}
)

# Sequence parallelism: activations additionally shard their seq axis.
SP_RULES = DEFAULT_RULES.with_("sp", seq=("model",))
SP_MULTIPOD_RULES = MULTIPOD_RULES.with_("sp_multipod", seq=("model",))

# Serving: decode is KV-bound, so the cache shards its sequence axis over
# the model axis (kv_seq wins the model axis; kv_heads then replicates —
# the per-tensor dedup in logical_to_spec resolves the conflict).
SERVE_RULES = DEFAULT_RULES.with_("serve", kv_seq=("model",))
SERVE_MULTIPOD_RULES = MULTIPOD_RULES.with_("serve_multipod", kv_seq=("model",))

# profile → (single-pod rules, multi-pod rules); launch.mesh.rules_for picks
# by mesh axis names, launch.dryrun --rules picks the profile.
RULE_PROFILES: dict[str, tuple[AxisRules, AxisRules]] = {
    "default": (DEFAULT_RULES, MULTIPOD_RULES),
    "flat_dp": (FLAT_DP_RULES, FLAT_DP_MULTIPOD_RULES),
    "sp": (SP_RULES, SP_MULTIPOD_RULES),
    "serve": (SERVE_RULES, SERVE_MULTIPOD_RULES),
}


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------


def _mesh_sizes(mesh) -> Mapping[str, int]:
    """Mesh | {axis: size} → {axis: size} (dict form eases unit testing)."""
    if isinstance(mesh, Mapping):
        return mesh
    return mesh.shape


def logical_to_spec(
    shape: tuple[int, ...],
    axes: tuple[str | None, ...],
    rules: AxisRules,
    mesh,
) -> PartitionSpec:
    """Resolve logical axes to a PartitionSpec valid for ``mesh``.

    Invariant (pinned by tests/test_property.py): every mesh axis kept in
    the result divides its dimension, and no mesh axis appears twice.
    """
    sizes = _mesh_sizes(mesh)
    used: set[str] = set()
    entries: list[Any] = []
    for dim, logical in zip(shape, axes):
        keep: list[str] = []
        part = 1
        for name in rules.get(logical):
            size = sizes.get(name)
            if size is None or name in used:
                continue
            if dim % (part * size) != 0:
                continue
            keep.append(name)
            part *= size
        used.update(keep)
        if not keep:
            entries.append(None)
        elif len(keep) == 1:
            entries.append(keep[0])
        else:
            entries.append(tuple(keep))
    return PartitionSpec(*entries)


_noop_constraint_warned = False


def shard_constraint(x, axes, rules: AxisRules, mesh: Mesh):
    """``with_sharding_constraint`` via logical axes.

    On a 1-device mesh the constraint is deliberately dropped (smoke tests
    and CPU examples stay constraint-free HLO) — announced once per
    process, so a "why is nothing sharded" investigation finds the cause
    in the warning log rather than in this source file.  On real meshes
    the resolved :func:`logical_to_spec` constraint is always placed.
    """
    if mesh.size <= 1:
        global _noop_constraint_warned
        if not _noop_constraint_warned:
            _noop_constraint_warned = True
            import warnings

            warnings.warn(
                "shard_constraint is a no-op on a 1-device mesh: activation "
                "constraints are dropped (further drops are silent)",
                stacklevel=2,
            )
        return x
    spec = logical_to_spec(x.shape, axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_tree(specs, rules: AxisRules, mesh: Mesh):
    """ParamSpec pytree → matching NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, logical_to_spec(s.shape, s.axes, rules, mesh)
        ),
        specs,
        is_leaf=_is_spec,
    )


def abstract_params(specs):
    """ParamSpec pytree → ShapeDtypeStruct pytree (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        specs,
        is_leaf=_is_spec,
    )


def count_params(specs) -> int:
    """Total element count over every ParamSpec leaf."""
    return sum(
        s.size for s in jax.tree.leaves(specs, is_leaf=_is_spec)
    )


# ---------------------------------------------------------------------------
# Materialization
# ---------------------------------------------------------------------------


def _path_fold(path) -> int:
    """Stable 31-bit hash of a tree path (crc32 — NOT builtin hash, which is
    randomized per process and would break cross-run determinism)."""
    return zlib.crc32(jax.tree_util.keystr(path).encode()) & 0x7FFFFFFF


def _init_leaf(spec: ParamSpec, key) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    shape = spec.shape
    if spec.init_scale is not None:
        s = float(spec.init_scale)
        if len(shape) <= 1 or s == 0.0:
            return jnp.full(shape, s, dtype)
        return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)
    fan_in = max(1, math.prod(shape[:-1]))
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def materialize_params(specs, key):
    """Materialize a ParamSpec pytree with deterministic per-leaf init.

    The root key is folded with a hash of each leaf's tree path, so leaf
    values depend only on (seed, path, shape, dtype, init_scale) — never on
    traversal order, mesh shape, or process.  Arrays are created unsharded;
    callers ``device_put`` with :func:`sharding_tree` (or rely on the jit'd
    step's in_shardings) to place them.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_is_spec)
    leaves = [
        _init_leaf(spec, jax.random.fold_in(key, _path_fold(path)))
        for path, spec in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, leaves)
