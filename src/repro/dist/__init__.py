"""Distributed substrate: logical-axis sharding + elastic fault tolerance.

Two modules pair the paper's proxy patterns with an actual data plane:

- :mod:`repro.dist.sharding` — ``ParamSpec`` trees with *logical* axis names
  resolved through ``AxisRules`` profiles into mesh ``PartitionSpec``s, plus
  deterministic parameter materialization (mesh-shape independent init).
- :mod:`repro.dist.fault` — heartbeat leases over a Store (mediated channel),
  straggler policy, and elastic mesh re-planning after capacity loss.
- :mod:`repro.dist.lease` — the cross-process lease service behind the
  heartbeats: CAS generation claims (fencing tokens), CAS-append registry,
  notification-driven membership watch.

Every model/optimizer/trainer/server layer consumes this package; keep the
contract here stable (see ROADMAP.md §repro.dist).
"""
from repro.dist.fault import (  # noqa: F401
    HeartbeatMonitor,
    MeshPlan,
    StragglerPolicy,
    elastic_plan,
)
from repro.dist.lease import (  # noqa: F401
    Lease,
    LeaseError,
    LeaseExpired,
    LeaseLost,
    LeaseService,
    MembershipSnapshot,
)
from repro.dist.sharding import (  # noqa: F401
    DEFAULT_RULES,
    FLAT_DP_RULES,
    MULTIPOD_RULES,
    RULE_PROFILES,
    AxisRules,
    ParamSpec,
    abstract_params,
    count_params,
    logical_to_spec,
    materialize_params,
    shard_constraint,
    sharding_tree,
)
