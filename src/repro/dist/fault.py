"""Elastic fault-tolerance primitives: heartbeats, stragglers, re-planning.

Scaled to this container but written for the production mesh:

- :class:`HeartbeatMonitor` keeps worker *leases* in a Store (the paper's
  mediated channel), so the monitor and the workers need not share a
  process: a worker that misses its TTL is dead until it re-registers —
  exactly the lease protocol a 1000-node deployment runs over etcd.  The
  implementation is :class:`repro.dist.lease.LeaseService` (PR 4): CAS
  generation claims, CAS-append registry, fenced renewals.
- :class:`StragglerPolicy` grades step durations against a trailing median:
  ``warn`` (log + count) below ``redispatch`` (re-issue the work elsewhere).
  The Trainer's watchdog and the data layer's shard dispatcher delegate
  here (``DispatchingDataLoader`` re-issues a shard on a "redispatch"
  grade).
- :func:`elastic_plan` re-plans the (pod, data, model) mesh after capacity
  loss: model parallelism is pinned (weights are sharded that way), data
  parallelism degrades to the largest power of two that still fits — the
  path ``Trainer.remesh`` takes when a pod drops
  (``launch.mesh.ElasticMeshDriver`` drives it from lease membership).
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field

from repro.dist.lease import LeaseService


# ---------------------------------------------------------------------------
# Heartbeats
# ---------------------------------------------------------------------------


class HeartbeatMonitor:
    """Lease-based liveness over a Store.

    ``register`` grants a lease of ``ttl`` seconds; ``heartbeat`` renews it.
    A lease that expires makes the worker *dead*: further heartbeats raise
    ``TimeoutError`` until the worker re-registers (so a partitioned node
    cannot silently rejoin with stale state).

    Thin adapter keeping the PR 1 API; the protocol lives in
    :class:`repro.dist.lease.LeaseService` — atomic generation claims
    instead of the old read-modify-write registry, so concurrent
    registrations can't lose updates and a fenced-out stale worker can't
    silently resurrect (``LeaseLost``).
    """

    def __init__(self, store, ttl: float = 5.0):
        self.leases = LeaseService(store, ttl=ttl)

    @property
    def store(self):
        return self.leases.store

    @property
    def ttl(self) -> float:
        return self.leases.ttl

    def register(self, worker: str) -> None:
        self.leases.register(worker)

    def heartbeat(self, worker: str) -> None:
        # raises LeaseExpired (a TimeoutError — the PR 1 contract) on a
        # missed TTL and LeaseLost when a newer registration fenced us out
        self.leases.renew(worker)

    def live_workers(self) -> list[str]:
        return self.leases.live()

    def dead_workers(self) -> list[str]:
        return self.leases.dead()


# ---------------------------------------------------------------------------
# Stragglers
# ---------------------------------------------------------------------------


@dataclass
class StragglerPolicy:
    """Grade step durations against the trailing median.

    ``observe`` returns ``None`` | ``"warn"`` | ``"redispatch"``.  No
    judgment is made until ``min_samples`` observations exist (cold-start
    compile steps must not poison the baseline).
    """

    warn_factor: float = 2.0
    redispatch_factor: float = 4.0
    window: int = 20
    min_samples: int = 5
    durations: list[float] = field(default_factory=list)
    warnings: int = 0
    redispatches: int = 0

    def grade(self, dt: float) -> str | None:
        """Judge ``dt`` against the current baseline WITHOUT recording it.

        The dispatcher's supervisor grades *in-flight* elapsed times with
        this — an unfinished shard's partial duration must not poison the
        trailing median that completed shards build.
        """
        if len(self.durations) < self.min_samples:
            return None
        med = statistics.median(self.durations[-self.window :])
        if dt > self.redispatch_factor * med:
            return "redispatch"
        if dt > self.warn_factor * med:
            return "warn"
        return None

    def observe(self, dt: float) -> str | None:
        decision = self.grade(dt)
        if decision == "redispatch":
            self.redispatches += 1
        elif decision == "warn":
            self.warnings += 1
        self.durations.append(dt)
        return decision

    @property
    def stragglers(self) -> int:
        return self.warnings + self.redispatches


# ---------------------------------------------------------------------------
# Elastic re-planning
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshPlan:
    """A (pod, data, model) mesh assignment; ``data`` is per-pod."""

    pods: int
    data: int
    model: int

    @property
    def chips(self) -> int:
        return self.pods * self.data * self.model

    def as_mesh_spec(self) -> tuple[tuple[int, ...], tuple[str, ...]]:
        """(shape, axis_names) for ``jax.make_mesh``; pod axis only when >1."""
        if self.pods > 1:
            return (self.pods, self.data, self.model), ("pod", "data", "model")
        return (self.data, self.model), ("data", "model")

    def __str__(self):
        shape, names = self.as_mesh_spec()
        return "x".join(f"{n}:{s}" for n, s in zip(names, shape))


def _pow2_floor(n: int) -> int:
    return 1 << (n.bit_length() - 1) if n > 0 else 0


def elastic_plan(
    available_chips: int,
    *,
    model_parallel: int,
    chips_per_pod: int = 256,
) -> MeshPlan:
    """Largest mesh that fits the surviving chips, model parallelism pinned.

    Whole pods first (TP stays on ICI), then per-pod data parallelism at the
    largest power of two of full model-parallel groups — a partially-dead
    pod is dropped rather than straddled, since a DP group spanning the DCN
    would gate every gradient all-reduce on the slow hop.
    """
    if available_chips < 1 or model_parallel < 1:
        raise ValueError("need at least one chip and model_parallel ≥ 1")
    pods = max(1, available_chips // chips_per_pod)
    per_pod = min(available_chips // pods, chips_per_pod)
    data = _pow2_floor(per_pod // model_parallel)
    if data < 1:
        raise ValueError(
            f"{available_chips} chips cannot host one model-parallel group "
            f"of {model_parallel}"
        )
    return MeshPlan(pods=pods, data=data, model=model_parallel)
