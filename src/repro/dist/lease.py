"""Cross-process lease service over a Store (the multi-host fault path).

This is the membership substrate behind :class:`repro.dist.fault.
HeartbeatMonitor`: worker liveness as *leases* in a mediated channel, so the
monitor and the workers never share a process — File/SharedMemory connectors
carry it cross-process today, an etcd/network connector would carry it
cross-host with zero changes here.

Design (every mutation is either a CAS or fenced by one):

- **Generations** — a worker's identity is claimed per *generation*: cell
  ``{prefix}-gen-{worker}-{g}`` is written with an atomic put-if-absent
  (``put_parts_new``: dict setdefault / ``link(2)`` / shm ``O_EXCL``), so
  exactly one process owns generation ``g`` of a worker name.  A partitioned
  node that re-registers claims ``g+1`` and *fences out* the old owner: the
  stale process's next renewal sees a newer head generation and raises
  :class:`LeaseLost` instead of silently resurrecting (the fencing-token
  protocol etcd/Chubby leases run).
- **Registry** — membership is a chain of immutable versioned cells
  ``{prefix}-reg-{n}``, each holding the full member list.  Appending is a
  CAS retry loop on ``put_if_absent`` at ``n+1`` (the loser re-reads and
  retries), replacing the read-modify-write list the single-host stub used
  — concurrent registrations can no longer lose updates.  Cells are
  write-once, so plain (cached) reads are safe; readers discover the head
  by probing forward from their last known version.
- **Renewals** — the generation claim doubles as the initial lease (it
  carries ``expires``); renewals overwrite a per-generation renewal cell.
  That cell has exactly one legal writer — the process that won the
  generation CAS — so the overwrite is race-free *by construction*, and
  every renewal first validates the fence (head generation unchanged) and
  the TTL (an expired lease raises :class:`LeaseExpired`; the worker must
  re-register, claiming a fresh generation).
- **Watch** — :meth:`watch` blocks on the connector's notification-based
  ``wait_for_any`` over the *next* registry cell and the *next* generation
  cell of every known member (registrations and re-registrations are key
  creations → native wake-ups), with the deadline capped at the earliest
  live-lease expiry (deaths are the absence of writes — only time reveals
  them).  No polling loop; one blocking wait per round.

Wall clock, not monotonic: expiries cross processes, and monotonic epochs
are only meaningful locally (same rationale as the PR 1 stub).  Renewal
cells are mutable keys, so every renewal read is ``fresh=True`` (the
resolve cache is in-process only — ROADMAP §Store hot path).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

from repro.core.connectors import wait_for_any


class LeaseError(RuntimeError):
    """Base class for lease-protocol violations."""


class LeaseLost(LeaseError):
    """A newer generation claimed this worker: the caller has been fenced
    out (its writes must stop; a split-brain node cannot keep renewing)."""


class LeaseExpired(TimeoutError):
    """The lease's TTL passed before the renewal: the worker is dead until
    it re-registers.  Subclasses ``TimeoutError`` — the exception the
    original ``HeartbeatMonitor.heartbeat`` contract promised."""


@dataclass(frozen=True)
class Lease:
    """A worker's current lease: fencing generation + wall-clock expiry."""

    worker: str
    generation: int
    expires: float

    def live(self, now: float | None = None) -> bool:
        return (now if now is not None else time.time()) <= self.expires


@dataclass(frozen=True)
class MembershipSnapshot:
    """Comparable point-in-time view of the cluster (the watch currency)."""

    version: int  # registry head version
    members: tuple[str, ...]
    live: tuple[str, ...]
    generations: tuple[tuple[str, int], ...]

    @property
    def dead(self) -> tuple[str, ...]:
        alive = set(self.live)
        return tuple(w for w in self.members if w not in alive)


class LeaseService:
    """Lease table over any Store connector (see module docstring).

    One instance per process side (worker or monitor); instances sharing a
    connector see one membership.  ``prefix`` namespaces the cells so
    several services can share a channel.
    """

    def __init__(self, store, ttl: float = 5.0, *, prefix: str = "hb"):
        self.store = store
        self.ttl = float(ttl)
        self.prefix = prefix
        self._lock = threading.Lock()
        self._reg_head = 0  # last registry version this instance has seen
        self._gen_heads: dict[str, int] = {}  # worker → last seen generation
        self._owned: dict[str, int] = {}  # worker → generation won *here*

    # -- keys -----------------------------------------------------------------
    def _reg_key(self, n: int) -> str:
        return f"{self.prefix}-reg-{n:08d}"

    def _gen_key(self, worker: str, g: int) -> str:
        return f"{self.prefix}-gen-{worker}-{g:08d}"

    def _renew_key(self, worker: str, g: int) -> str:
        return f"{self.prefix}-rn-{worker}-{g:08d}"

    # -- head discovery (probe forward; cells are write-once) -----------------
    def _registry_head(self) -> tuple[int, list[str]]:
        with self._lock:
            n = self._reg_head
        while self.store.exists(self._reg_key(n + 1)):
            n += 1
        with self._lock:
            self._reg_head = max(self._reg_head, n)
        if n == 0:
            return 0, []
        members = self.store.get(self._reg_key(n))
        # a concurrent chain GC is impossible (cells are never evicted), so
        # a missing head cell means the probe raced a slow writer: settle on
        # the newest cell that is actually readable
        while members is None and n > 1:
            n -= 1
            members = self.store.get(self._reg_key(n))
        return n, list(members or [])

    def _generation_head(self, worker: str) -> int:
        with self._lock:
            g = self._gen_heads.get(worker, 0)
        while self.store.exists(self._gen_key(worker, g + 1)):
            g += 1
        with self._lock:
            prev = self._gen_heads.get(worker, 0)
            self._gen_heads[worker] = max(prev, g)
        return g

    # -- membership (CAS-append registry) --------------------------------------
    def members(self) -> list[str]:
        return self._registry_head()[1]

    def _ensure_member(self, worker: str) -> None:
        while True:
            n, members = self._registry_head()
            if worker in members:
                return
            proposed = sorted(members + [worker])
            if self.store.put_if_absent(proposed, self._reg_key(n + 1)):
                with self._lock:
                    self._reg_head = max(self._reg_head, n + 1)
                return
            # lost the CAS: someone else appended first — re-read, retry

    # -- registration / renewal -------------------------------------------------
    def register(self, worker: str) -> int:
        """Claim the next generation of ``worker``; returns the fencing token.

        Exactly one racing registrant wins each generation (connector-level
        put-if-absent); the loser retries at the next one, fencing the
        winner out in turn — last registrant holds the lease.
        """
        while True:
            g = self._generation_head(worker) + 1
            claim = {"expires": time.time() + self.ttl}
            if self.store.put_if_absent(claim, self._gen_key(worker, g)):
                with self._lock:
                    self._gen_heads[worker] = max(
                        self._gen_heads.get(worker, 0), g
                    )
                    self._owned[worker] = g
                self._ensure_member(worker)
                return g

    def renew(self, worker: str, generation: int | None = None) -> None:
        """Extend the lease by ``ttl``; the heartbeat.

        Raises :class:`LeaseLost` when a newer generation exists (this
        caller was fenced out) and :class:`LeaseExpired` when the TTL
        already passed (dead until re-register).
        """
        g = generation if generation is not None else self._owned.get(worker)
        head = self._generation_head(worker)
        if g is None:
            g = head  # monitor-side renewal: act on the current lease
        if head == 0:
            raise LeaseError(f"worker {worker!r} was never registered")
        if g < head:
            raise LeaseLost(
                f"worker {worker!r} generation {g} fenced out by {head}"
            )
        now = time.time()
        lease = self._lease_at(worker, g)
        if lease is None or now > lease.expires:
            # No evict: the renewal cell's only legal writer is the
            # generation owner, and a monitor-side renew (generation=None)
            # may be acting on a lease it does not own — with wall-clock
            # skew, evicting here could delete an owner's just-landed
            # renewal.  Liveness reads validate expiry anyway.
            raise LeaseExpired(
                f"worker {worker!r} lease expired (ttl={self.ttl}s); re-register"
            )
        self.store.put({"expires": now + self.ttl}, key=self._renew_key(worker, g))

    # -- reads ------------------------------------------------------------------
    def _lease_at(self, worker: str, g: int) -> Lease | None:
        # renewal cell is mutable → fresh read; the claim cell is write-once
        renewal = self.store.get(self._renew_key(worker, g), fresh=True)
        if renewal is not None:
            return Lease(worker, g, float(renewal["expires"]))
        claim = self.store.get(self._gen_key(worker, g))
        if claim is None:
            return None
        return Lease(worker, g, float(claim["expires"]))

    def lease(self, worker: str) -> Lease | None:
        g = self._generation_head(worker)
        return None if g == 0 else self._lease_at(worker, g)

    def is_live(self, worker: str) -> bool:
        lease = self.lease(worker)
        return lease is not None and lease.live()

    def live(self) -> list[str]:
        return sorted(w for w in self.members() if self.is_live(w))

    def dead(self) -> list[str]:
        return sorted(w for w in self.members() if not self.is_live(w))

    def snapshot(self) -> MembershipSnapshot:
        version, members = self._registry_head()
        leases = {w: self.lease(w) for w in members}
        now = time.time()
        return MembershipSnapshot(
            version=version,
            members=tuple(members),
            live=tuple(
                sorted(w for w, l in leases.items() if l is not None and l.live(now))
            ),
            generations=tuple(
                sorted((w, l.generation if l else 0) for w, l in leases.items())
            ),
        )

    # -- subscription -------------------------------------------------------------
    def _next_event_keys(self, snap: MembershipSnapshot) -> list[str]:
        keys = [self._reg_key(snap.version + 1)]  # next membership append
        gens = dict(snap.generations)
        keys += [
            self._gen_key(w, gens.get(w, 0) + 1) for w in snap.members
        ]  # next re-registration of any known member
        return keys

    def _earliest_expiry(self, snap: MembershipSnapshot) -> float | None:
        expiries = []
        for w in snap.live:
            lease = self.lease(w)
            if lease is not None:
                expiries.append(lease.expires)
        return min(expiries) if expiries else None

    def watch(
        self,
        known: MembershipSnapshot | None = None,
        timeout: float | None = None,
    ) -> MembershipSnapshot:
        """Block until membership *may* differ from ``known``; return the
        fresh snapshot (the caller compares — an unchanged return is a
        heartbeat-shaped wake, loop again).

        One ``wait_for_any`` round over the next registry/generation cells,
        deadline-capped at the earliest live-lease expiry: registrations
        wake us by notification, deaths by the TTL clock.  Never a poll
        loop.
        """
        snap = self.snapshot()
        if known is None or snap != known:
            return snap
        wait = timeout
        expiry = self._earliest_expiry(snap)
        if expiry is not None:
            # +5% ttl slack so we wake just *after* the lease dies, not just
            # before it (an on-time renewal moves the next deadline anyway)
            until_death = max(0.0, expiry - time.time()) + 0.05 * self.ttl
            wait = until_death if wait is None else min(wait, until_death)
        try:
            wait_for_any(self.store.connector, self._next_event_keys(snap), wait)
        except TimeoutError:
            pass  # deadline wake: a lease may have expired — re-snapshot
        return self.snapshot()
