"""Fault-tolerant training loop.

Large-scale runnability features, scaled to this container but written for
the production mesh:

- **checkpoint/restart**: async proxy-backed checkpoints every
  ``ckpt_every`` steps; on any step failure the trainer restores the last
  durable checkpoint and resumes (``max_failures`` budget).
- **elastic re-mesh**: ``Trainer.remesh(new_mesh)`` re-jits the step and
  re-device_puts the state onto the new mesh's shardings from the live
  state (or from the checkpoint after a crash) — the path a 1000-node
  deployment takes when a pod drops.
- **straggler mitigation**: a watchdog thread flags steps exceeding
  ``straggle_factor ×`` the trailing-median step time (on real multi-host
  it would trigger re-dispatch; here it records + logs, and the hook is
  test-injectable).
- **data via ProxyStream**, checkpoints via ProxyFutures + ownership — the
  paper's patterns are the trainer's data/control plane.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.core.proxy import Proxy, extract
from repro.dist.fault import StragglerPolicy
from repro.dist.sharding import materialize_params, sharding_tree
from repro.models.layers import ModelContext
from repro.optim.adamw import AdamWConfig, build_optimizer
from repro.train.step import make_train_step


@dataclass
class TrainerConfig:
    optimizer: str = "adamw"
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    microbatch: int = 0
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro-ckpt"
    keep_ckpts: int = 3
    max_failures: int = 3
    straggle_factor: float = 3.0
    log_every: int = 10


class StepWatchdog:
    """Flags steps that exceed straggle_factor × trailing median.

    Thin adapter over :class:`repro.dist.fault.StragglerPolicy` — the same
    policy object a multi-host deployment would feed from per-worker
    heartbeat timings; here it grades local step durations.  A step past
    ``2×straggle_factor`` grades "redispatch" (on real multi-host it would
    re-issue the batch; locally it is recorded like a warn).
    """

    def __init__(self, factor: float, window: int = 20):
        self.policy = StragglerPolicy(
            warn_factor=factor, redispatch_factor=2 * factor, window=window
        )

    def observe(self, dt: float) -> bool:
        return self.policy.observe(dt) is not None

    @property
    def stragglers(self) -> int:
        return self.policy.stragglers


class Trainer:
    def __init__(self, ctx: ModelContext, tc: TrainerConfig):
        self.ctx = ctx
        self.tc = tc
        self.bundle = make_train_step(
            ctx, optimizer=tc.optimizer, opt_cfg=tc.opt, microbatch=tc.microbatch
        )
        self.ckpt = CheckpointManager(tc.ckpt_dir, keep=tc.keep_ckpts)
        self.watchdog = StepWatchdog(tc.straggle_factor)
        self.state: Any = None
        self.step_num = 0
        self.failures = 0
        self.history: list[dict] = []
        self.remeshes: list[dict] = []
        self._remesh_lock = threading.Lock()
        self._pending_remesh: tuple[ModelContext, Any] | None = None

    # -- state ------------------------------------------------------------
    def init_state(self, seed: int = 0):
        model = self.bundle.model
        opt = build_optimizer(self.tc.optimizer, self.tc.opt)
        with self.ctx.mesh:
            params = materialize_params(model.param_specs(), jax.random.PRNGKey(seed))
            self.state = {"params": params, "opt": opt.init(params)}
        return self.state

    def try_restore(self) -> bool:
        step = self.ckpt.latest_step()
        if step is None:
            return False
        shardings = self.bundle.state_shardings
        self.state, self.step_num = self.ckpt.restore(
            self.state if self.state is not None else self._abstract_state(),
            shardings=shardings,
        )
        return True

    def _abstract_state(self):
        from repro.dist.sharding import abstract_params

        model = self.bundle.model
        opt = build_optimizer(self.tc.optimizer, self.tc.opt)
        return {
            "params": abstract_params(model.param_specs()),
            "opt": abstract_params(opt.state_specs(model.param_specs())),
        }

    # -- elastic ------------------------------------------------------------
    def request_remesh(self, new_ctx: ModelContext, *, plan=None) -> None:
        """Queue an elastic re-mesh (thread-safe; e.g. from the
        ``ElasticMeshDriver`` watch thread).

        Applied at the next step *boundary* — a remesh re-device_puts the
        live state, which must never race the jit'd step that is consuming
        (and donating) those buffers.  Last request wins: membership may
        change again before the boundary, and only the newest plan matters.
        """
        with self._remesh_lock:
            self._pending_remesh = (new_ctx, plan)

    def _apply_pending_remesh(self, log: Callable[[str], None]) -> None:
        with self._remesh_lock:
            pending, self._pending_remesh = self._pending_remesh, None
        if pending is None:
            return
        new_ctx, plan = pending
        self.remesh(new_ctx)
        self.remeshes.append(
            {"step": self.step_num, "plan": None if plan is None else str(plan),
             "mesh_axes": tuple(new_ctx.mesh.axis_names)}
        )
        log(f"[trainer] remesh at step {self.step_num} → "
            f"{plan if plan is not None else new_ctx.mesh}")

    def remesh(self, new_ctx: ModelContext):
        """Re-shard live state onto a new mesh and re-jit (elastic scaling)."""
        host_state = jax.tree.map(np.asarray, self.state)  # device→host
        self.ctx = new_ctx
        self.bundle = make_train_step(
            new_ctx, optimizer=self.tc.optimizer, opt_cfg=self.tc.opt,
            microbatch=self.tc.microbatch,
        )
        sh = self.bundle.state_shardings
        with new_ctx.mesh:
            self.state = jax.tree.map(
                lambda a, s: jax.device_put(a, s), host_state, sh
            )

    # -- loop ----------------------------------------------------------------
    def train(
        self,
        data_iter,
        num_steps: int,
        *,
        fail_hook: Callable[[int], None] | None = None,
        log: Callable[[str], None] = print,
    ) -> list[dict]:
        if self.state is None:
            if not self.try_restore():
                self.init_state()
        data_iter = iter(data_iter)
        while self.step_num < num_steps:
            self._apply_pending_remesh(log)  # elastic: apply at step boundary
            batch_proxy = next(data_iter)
            batch = (
                extract(batch_proxy) if isinstance(batch_proxy, Proxy) else batch_proxy
            )
            t0 = time.perf_counter()
            try:
                if fail_hook is not None:
                    fail_hook(self.step_num)  # test-injected failures
                with self.ctx.mesh:
                    self.state, metrics = self.bundle.fn(self.state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {self.step_num}")
            except Exception as e:  # noqa: BLE001 - fault tolerance boundary
                self.failures += 1
                log(f"[trainer] step {self.step_num} FAILED ({e!r}); "
                    f"restoring last checkpoint ({self.failures}/{self.tc.max_failures})")
                if self.failures > self.tc.max_failures:
                    raise
                self.ckpt.wait()
                if not self.try_restore():
                    self.init_state()
                continue
            dt = time.perf_counter() - t0
            straggled = self.watchdog.observe(dt)
            self.step_num += 1
            rec = {
                "step": self.step_num,
                "loss": loss,
                "sec": dt,
                "straggler": straggled,
                "grad_norm": float(metrics.get("grad_norm", np.nan)),
            }
            self.history.append(rec)
            if self.step_num % self.tc.log_every == 0:
                log(f"[trainer] step {self.step_num} loss {loss:.4f} "
                    f"({dt*1e3:.0f} ms{' STRAGGLER' if straggled else ''})")
            if self.step_num % self.tc.ckpt_every == 0:
                self.ckpt.save_async(self.state, self.step_num)
        self.ckpt.save(self.state, self.step_num)
        return self.history
