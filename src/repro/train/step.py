"""Train/serve step factories: jit'd, sharded, donated.

Buffer donation of the training state is the ownership pattern at the XLA
level — the caller *yields ownership* of the previous state's buffers to the
step (paper §IV-C maps directly onto ``donate_argnums``).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import (
    AxisRules,
    ParamSpec,
    abstract_params,
    logical_to_spec,
    sharding_tree,
)
from repro.models.api import (
    build_model,
    decode_cache_specs,
    train_input_shardings,
    train_input_specs,
)
from repro.models.layers import ModelContext
from repro.optim.adamw import AdamWConfig, build_optimizer


@dataclass
class StepBundle:
    """Everything needed to lower/compile/run one step kind."""

    fn: Any  # the jit'd function
    in_specs: Any  # abstract inputs (ShapeDtypeStructs) for AOT lowering
    state_shardings: Any
    model: Any
    ctx: ModelContext


def make_train_step(
    ctx: ModelContext,
    *,
    optimizer: str = "adamw",
    opt_cfg: AdamWConfig | None = None,
    microbatch: int = 0,
    donate: bool = True,
) -> StepBundle:
    """Build the jit'd train step for (cfg, mesh, rules).

    ``microbatch > 0`` enables gradient accumulation: the global batch is
    split into ``microbatch`` sequential slices scanned with accumulated
    grads (activation memory ÷ microbatch; the FSDP all-gathers repeat).
    """
    cfg, mesh, rules = ctx.cfg, ctx.mesh, ctx.rules
    model = build_model(ctx)
    opt = build_optimizer(optimizer, opt_cfg or AdamWConfig())

    pspecs = model.param_specs()
    ospecs = opt.state_specs(pspecs)
    param_sh = sharding_tree(pspecs, rules, mesh)
    opt_sh = sharding_tree(ospecs, rules, mesh)
    state_sh = {"params": param_sh, "opt": opt_sh}

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def step(state, batch):
        params = state["params"]
        if microbatch and microbatch > 1:
            B = batch["tokens"].shape[0]

            def micro(acc, mb):
                (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return acc, (loss, metrics)

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def split(x):
                if hasattr(x, "ndim") and x.ndim >= 1 and x.shape[0] == B:
                    return x.reshape((microbatch, B // microbatch) + x.shape[1:])
                if hasattr(x, "ndim") and x.ndim >= 2 and x.shape[1] == B:
                    # (3, B, S) position ids: microbatch along axis 1
                    y = x.reshape(
                        (x.shape[0], microbatch, B // microbatch) + x.shape[2:]
                    )
                    return jnp.moveaxis(y, 1, 0)
                return jnp.broadcast_to(x, (microbatch,) + x.shape)

            mbs = jax.tree.map(split, batch)
            grads, (losses, metricses) = jax.lax.scan(micro, zeros, mbs)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            metrics = jax.tree.map(lambda m: m.mean(0), metricses)
            loss = losses.mean()
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        new_params, new_opt, opt_metrics = opt.update(grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt}
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_state, metrics

    batch_specs = train_input_specs(cfg, 0, 0)  # placeholder; caller sizes it
    fn = jax.jit(
        step,
        donate_argnums=(0,) if donate else (),
    )
    return StepBundle(fn=fn, in_specs=None, state_shardings=state_sh, model=model, ctx=ctx)


def abstract_train_args(ctx: ModelContext, bundle: StepBundle, batch: int, seq: int,
                        optimizer: str = "adamw", opt_cfg: AdamWConfig | None = None):
    """(state, batch) ShapeDtypeStructs + shardings for AOT lowering."""
    cfg, mesh, rules = ctx.cfg, ctx.mesh, ctx.rules
    model = bundle.model
    opt = build_optimizer(optimizer, opt_cfg or AdamWConfig())
    pspecs = model.param_specs()
    ospecs = opt.state_specs(pspecs)
    state_abs = {"params": abstract_params(pspecs), "opt": abstract_params(ospecs)}
    batch_abs = train_input_specs(cfg, batch, seq)
    state_sh = {
        "params": sharding_tree(pspecs, rules, mesh),
        "opt": sharding_tree(ospecs, rules, mesh),
    }
    batch_sh = train_input_shardings(cfg, batch_abs, rules, mesh)
    return state_abs, batch_abs, state_sh, batch_sh


def make_decode_step(ctx: ModelContext) -> StepBundle:
    """jit'd single-token decode (serve_step) with donated cache."""
    model = build_model(ctx)

    def step(params, cache, tokens, index):
        return model.decode_step(params, cache, tokens, index)

    fn = jax.jit(step, donate_argnums=(1,))
    return StepBundle(fn=fn, in_specs=None, state_shardings=None, model=model, ctx=ctx)


def abstract_decode_args(ctx: ModelContext, bundle: StepBundle, batch: int, max_len: int):
    cfg, mesh, rules = ctx.cfg, ctx.mesh, ctx.rules
    model = bundle.model
    pspecs = model.param_specs()
    cspecs = decode_cache_specs(model, cfg, batch, max_len)
    params_abs = abstract_params(pspecs)
    cache_abs = abstract_params(cspecs)
    tokens_abs = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    index_abs = jax.ShapeDtypeStruct((), jnp.int32)
    params_sh = sharding_tree(pspecs, rules, mesh)
    cache_sh = sharding_tree(cspecs, rules, mesh)
    tok_sh = NamedSharding(
        mesh, logical_to_spec((batch, 1), ("batch", None), rules, mesh)
    )
    idx_sh = NamedSharding(mesh, P())
    return (params_abs, cache_abs, tokens_abs, index_abs), (
        params_sh, cache_sh, tok_sh, idx_sh,
    )


def make_prefill_step(ctx: ModelContext, max_len: int) -> StepBundle:
    model = build_model(ctx)

    if ctx.cfg.family == "encdec":
        def step(params, tokens, frames):
            return model.prefill(params, tokens, max_len, frames=frames)
    else:
        def step(params, tokens):
            return model.prefill(params, tokens, max_len)

    fn = jax.jit(step, static_argnums=())
    return StepBundle(fn=fn, in_specs=None, state_shardings=None, model=model, ctx=ctx)


def abstract_prefill_args(ctx: ModelContext, bundle: StepBundle, batch: int, seq: int):
    """ShapeDtypeStructs + shardings for AOT-lowering the prefill step."""
    cfg, mesh, rules = ctx.cfg, ctx.mesh, ctx.rules
    model = bundle.model
    pspecs = model.param_specs()
    params_abs = abstract_params(pspecs)
    params_sh = sharding_tree(pspecs, rules, mesh)
    tokens_abs = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    tok_sh = NamedSharding(
        mesh, logical_to_spec((batch, seq), ("batch", None), rules, mesh)
    )
    args_abs = [params_abs, tokens_abs]
    args_sh = [params_sh, tok_sh]
    if cfg.family == "encdec":
        fshape = (batch, cfg.encoder_frames, cfg.d_model)
        args_abs.append(jax.ShapeDtypeStruct(fshape, jnp.dtype(cfg.dtype)))
        args_sh.append(
            NamedSharding(
                mesh, logical_to_spec(fshape, ("batch", None, None), rules, mesh)
            )
        )
    return tuple(args_abs), tuple(args_sh)
