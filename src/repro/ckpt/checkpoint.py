"""Checkpointing through the proxy substrate.

The paper's three patterns each carry a piece of this subsystem:

- **Async save (ProxyFutures, §IV-A):** ``save_async`` snapshots device
  arrays to host, hands the writer thread a ProxyFuture, and returns
  immediately — training's next step overlaps the serialization/write
  (startup-overhead pipelining, applied to the save path).  ``wait()`` or a
  later save joins the future.
- **Bulk via Store (§III):** every leaf is written through a Store/
  Connector (filesystem connector in this container; object stores on a
  real cluster), so checkpoints inherit the mediated-channel property —
  writer and restorer need not coexist.
- **Retention via ownership (§IV-C):** each checkpoint is an OwnedProxy of
  its manifest; keep-last-k drops old owners, which frees every leaf
  deterministically — no leaked shards (the paper's Fig 10 behaviour).

Restore is *elastic*: leaves are written mesh-agnostic (full logical
arrays, chunked along axis 0) and re-device_put with the target mesh's
NamedShardings, so a checkpoint saved on one mesh restores onto any other
(node-failure → re-mesh → resume).
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.connectors import FileConnector
from repro.core.futures import ProxyFuture
from repro.core.ownership import OwnedProxy, free, owned_proxy
from repro.core.store import Store


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], treedef


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    _store: Store = field(init=False)
    _owners: dict[int, OwnedProxy] = field(default_factory=dict)
    _pending: ProxyFuture | None = None
    _thread: threading.Thread | None = None

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._store = Store(
            f"ckpt-{os.path.basename(self.directory)}-{id(self)}",
            FileConnector(os.path.join(self.directory, "objects")),
        )

    # -- save ------------------------------------------------------------------
    def save_async(self, state, step: int) -> ProxyFuture:
        """Snapshot to host, then write in a background thread.

        Returns the ProxyFuture of the manifest; resolution ⇒ durable.
        """
        self.wait()  # at most one in-flight save
        flat, _ = _flatten_with_paths(state)
        # device→host snapshot happens NOW (consistent point-in-time copy)
        host_leaves = [(p, np.asarray(leaf)) for p, leaf in flat]
        fut: ProxyFuture = self._store.future()

        def writer():
            manifest = {"step": step, "leaves": {}, "time": time.time()}
            for path, arr in host_leaves:
                key = f"s{step}-{abs(hash(path)) % 10**12}"
                self._store.put(arr, key=key)
                manifest["leaves"][path] = {
                    "key": key,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                }
            with open(self._manifest_path(step), "w") as f:
                json.dump(manifest, f)
            fut.set_result(manifest)

        self._thread = threading.Thread(target=writer, daemon=True)
        self._thread.start()
        self._pending = fut
        return fut

    def save(self, state, step: int) -> None:
        self.save_async(state, step)
        self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._pending is not None and self._pending.done():
            manifest = self._pending.result()
            step = manifest["step"]
            # ownership: the manifest proxy owns its checkpoint's lifetime
            self._owners[step] = owned_proxy(
                self._store, manifest, key=f"manifest-{step}"
            )
            self._pending = None
            self._enforce_retention()

    def _enforce_retention(self):
        steps = sorted(self._owners)
        while len(steps) > self.keep:
            victim = steps.pop(0)
            owner = self._owners.pop(victim)
            manifest = dict(owner)  # resolve before freeing
            for meta in manifest["leaves"].values():
                self._store.evict(meta["key"])
            free(owner)
            try:
                os.remove(self._manifest_path(victim))
            except FileNotFoundError:
                pass

    def _manifest_path(self, step: int) -> str:
        return os.path.join(self.directory, f"manifest-{step}.json")

    # -- restore -----------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = [
            int(f.split("-")[1].split(".")[0])
            for f in os.listdir(self.directory)
            if f.startswith("manifest-")
        ]
        return max(steps) if steps else None

    def restore(self, state_template, step: int | None = None, shardings=None):
        """Restore into the template's structure.

        ``state_template``: pytree of arrays or ShapeDtypeStructs.
        ``shardings``: optional matching pytree of NamedShardings → elastic
        re-device_put onto the current mesh.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        with open(self._manifest_path(step)) as f:
            manifest = json.load(f)
        flat, treedef = _flatten_with_paths(state_template)
        sh_flat = (
            jax.tree.leaves(shardings) if shardings is not None else [None] * len(flat)
        )
        leaves = []
        for (path, tmpl), sh in zip(flat, sh_flat):
            meta = manifest["leaves"][path]
            arr = self._store.get(meta["key"])
            if arr is None:
                raise KeyError(f"checkpoint leaf missing: {path} ({meta['key']})")
            arr = np.asarray(arr).astype(meta["dtype"]).reshape(meta["shape"])
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.device_put(arr))
        import jax.tree_util as jtu

        return jtu.tree_unflatten(treedef, leaves), step

    def close(self):
        self.wait()
        self._store.close()
