"""Checkpointing through the proxy substrate.

The paper's three patterns each carry a piece of this subsystem:

- **Async save (ProxyFutures, §IV-A):** ``save_async`` snapshots device
  arrays to host, hands the writer thread a ProxyFuture, and returns
  immediately — training's next step overlaps the serialization/write
  (startup-overhead pipelining, applied to the save path).  ``wait()`` or a
  later save joins the future.
- **Bulk via Store (§III):** every leaf is written through a Store/
  Connector (filesystem connector in this container; object stores on a
  real cluster), so checkpoints inherit the mediated-channel property —
  writer and restorer need not coexist.
- **Retention via ownership (§IV-C):** each checkpoint is an OwnedProxy of
  its manifest; keep-last-k drops old owners, which frees every leaf
  deterministically — no leaked shards (the paper's Fig 10 behaviour).

Restore is *elastic and resharded* (PR 4): leaves are written mesh-agnostic
as per-shard slices chunked along axis 0 (``leaf_shards`` pieces, one store
object each), and a restore onto a sharded target assembles each device's
shard through ``jax.make_array_from_callback`` — fetching **only the chunks
that overlap that device's index**, never materializing the full logical
array on any single host.  A checkpoint saved on one mesh therefore
restores onto any other (node-failure → ``elastic_plan`` → re-mesh →
resume), and the restore traffic scales with the *local* shard, not the
logical array — the property a 671B-param restore lives or dies by.
"""
from __future__ import annotations

import json
import os
import threading
import time
import zlib
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.connectors import FileConnector
from repro.core.futures import ProxyFuture
from repro.core.ownership import OwnedProxy, free, owned_proxy
from repro.core.store import Store


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], treedef


def _leaf_tag(path: str) -> int:
    # crc32, not builtin hash: stable across processes (a restorer never
    # recomputes keys — the manifest records them — but debuggability wins)
    return zlib.crc32(path.encode()) & 0xFFFFFFFF


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    leaf_shards: int = 4  # max axis-0 chunks per leaf (1 ⇒ legacy whole-leaf)
    _store: Store = field(init=False)
    _owners: dict[int, OwnedProxy] = field(default_factory=dict)
    _pending: ProxyFuture | None = None
    _thread: threading.Thread | None = None

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        # sanitize=False: retained checkpoints are durable artifacts a
        # later process restores from — residency here is the product,
        # and ProxySan would report every kept chunk as a leak.
        self._store = Store(
            f"ckpt-{os.path.basename(self.directory)}-{id(self)}",
            FileConnector(os.path.join(self.directory, "objects")),
            sanitize=False,
        )

    # -- save ------------------------------------------------------------------
    def save_async(self, state, step: int) -> ProxyFuture:
        """Snapshot to host, then write in a background thread.

        Returns the ProxyFuture of the manifest; resolution ⇒ durable.
        """
        self.wait()  # at most one in-flight save
        flat, _ = _flatten_with_paths(state)
        # device→host snapshot happens NOW (consistent point-in-time copy)
        host_leaves = [(p, np.asarray(leaf)) for p, leaf in flat]
        fut: ProxyFuture = self._store.future()

        def writer():
            manifest = {"step": step, "leaves": {}, "time": time.time()}
            for ordinal, (path, arr) in enumerate(host_leaves):
                n_chunks = (
                    min(self.leaf_shards, arr.shape[0])
                    if arr.ndim >= 1 and arr.shape[0] > 1 and self.leaf_shards > 1
                    else 1
                )
                chunks = (
                    np.array_split(arr, n_chunks, axis=0) if n_chunks > 1 else [arr]
                )
                # ordinal guarantees uniqueness (a 32-bit path hash alone
                # could collide across leaves); the crc tag is debuggability
                keys = [
                    f"s{step}-l{ordinal:04d}-{_leaf_tag(path):08x}-p{i}"
                    for i in range(len(chunks))
                ]
                # one amortized connector round for the whole leaf (PR 2)
                self._store.put_batch(
                    [np.ascontiguousarray(c) for c in chunks], keys=keys
                )
                bounds = [0]
                for c in chunks:
                    bounds.append(bounds[-1] + (c.shape[0] if arr.ndim else 1))
                manifest["leaves"][path] = {
                    "keys": keys,
                    "bounds": bounds,  # axis-0 chunk boundaries (prefix sums)
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                }
            with open(self._manifest_path(step), "w") as f:
                json.dump(manifest, f)
            fut.set_result(manifest)

        self._thread = threading.Thread(target=writer, daemon=True)
        self._thread.start()
        self._pending = fut
        return fut

    def save(self, state, step: int) -> None:
        self.save_async(state, step)
        self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._pending is not None and self._pending.done():
            manifest = self._pending.result()
            step = manifest["step"]
            # ownership: the manifest proxy owns its checkpoint's lifetime
            self._owners[step] = owned_proxy(
                self._store, manifest, key=f"manifest-{step}"
            )
            self._pending = None
            self._enforce_retention()

    def _enforce_retention(self):
        steps = sorted(self._owners)
        while len(steps) > self.keep:
            victim = steps.pop(0)
            owner = self._owners.pop(victim)
            manifest = dict(owner)  # resolve before freeing
            for meta in manifest["leaves"].values():
                for key in meta.get("keys", [meta.get("key")]):
                    self._store.evict(key)
            free(owner)
            try:
                os.remove(self._manifest_path(victim))
            except FileNotFoundError:
                pass

    def _manifest_path(self, step: int) -> str:
        return os.path.join(self.directory, f"manifest-{step}.json")

    # -- restore -----------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = [
            int(f.split("-")[1].split(".")[0])
            for f in os.listdir(self.directory)
            if f.startswith("manifest-")
        ]
        return max(steps) if steps else None

    def _fetch_chunk(self, key: str, path: str) -> np.ndarray:
        arr = self._store.get(key)
        if arr is None:
            raise KeyError(f"checkpoint leaf missing: {path} ({key})")
        return np.asarray(arr)

    def _fetch_rows(self, meta: dict, start: int, stop: int, path: str) -> np.ndarray:
        """Rows ``[start, stop)`` of a leaf, touching only overlapping chunks.

        This is the resharded-restore primitive: a device whose shard index
        covers rows [start, stop) pays for exactly the chunk objects that
        intersect it — never the full logical array.
        """
        keys, bounds = meta["keys"], meta["bounds"]
        tail = tuple(meta["shape"][1:])
        picked = [
            (i, max(start, bounds[i]), min(stop, bounds[i + 1]))
            for i in range(len(keys))
            if bounds[i] < stop and bounds[i + 1] > start
        ]
        if not picked:  # empty row range (zero-length leaf or empty index)
            return np.zeros((max(0, stop - start),) + tail, dtype=meta["dtype"])
        blocks = []
        for i, lo, hi in picked:
            chunk = self._fetch_chunk(keys[i], path)
            blocks.append(chunk[lo - bounds[i] : hi - bounds[i]])
        if len(blocks) == 1:
            out = blocks[0]
        else:
            out = np.concatenate(blocks, axis=0)
        return out.astype(meta["dtype"]).reshape((stop - start,) + tail)

    def _fetch_full(self, meta: dict, path: str) -> np.ndarray:
        if "key" in meta:  # pre-PR4 manifest: one whole-leaf object
            arr = self._fetch_chunk(meta["key"], path)
            return arr.astype(meta["dtype"]).reshape(meta["shape"])
        shape = tuple(meta["shape"])
        if not shape:  # 0-d leaf: single chunk
            return (
                self._fetch_chunk(meta["keys"][0], path)
                .astype(meta["dtype"]).reshape(shape)
            )
        return self._fetch_rows(meta, 0, shape[0], path)

    def _restore_leaf_sharded(self, meta: dict, sharding, path: str):
        """Assemble a leaf on the target mesh from per-shard slices.

        ``make_array_from_callback`` invokes the callback once per
        addressable-device index; each call reads only the chunk objects
        overlapping that index's axis-0 range (no full-logical-array
        materialization on any host).
        """
        shape = tuple(meta["shape"])
        if not shape:
            scalar = self._fetch_full(meta, path)
            return jax.make_array_from_callback(shape, sharding, lambda idx: scalar)

        def fetch_shard(index):
            sl0 = index[0] if index else slice(None)
            start = sl0.start if sl0.start is not None else 0
            stop = sl0.stop if sl0.stop is not None else shape[0]
            block = self._fetch_rows(meta, start, stop, path)
            rest = (slice(None),) + tuple(index[1:])
            return block[rest]

        return jax.make_array_from_callback(shape, sharding, fetch_shard)

    def restore(self, state_template, step: int | None = None, shardings=None):
        """Restore into the template's structure.

        ``state_template``: pytree of arrays or ShapeDtypeStructs.
        ``shardings``: optional matching pytree of NamedShardings → elastic
        *resharded* restore onto the current mesh: each leaf is assembled
        per-device from its overlapping chunk objects (see
        :meth:`_restore_leaf_sharded`).  Without shardings, leaves are
        assembled whole and ``device_put`` (smoke/CPU path).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        with open(self._manifest_path(step)) as f:
            manifest = json.load(f)
        flat, treedef = _flatten_with_paths(state_template)
        sh_flat = (
            jax.tree.leaves(shardings) if shardings is not None else [None] * len(flat)
        )
        leaves = []
        for (path, tmpl), sh in zip(flat, sh_flat):
            meta = manifest["leaves"][path]
            if sh is not None and "keys" in meta:
                leaves.append(self._restore_leaf_sharded(meta, sh, path))
            else:
                arr = self._fetch_full(meta, path)
                if sh is not None:
                    leaves.append(jax.device_put(arr, sh))
                else:
                    leaves.append(jax.device_put(arr))
        import jax.tree_util as jtu

        return jtu.tree_unflatten(treedef, leaves), step

    def close(self):
        self.wait()
        self._store.close()
