"""Optimizers: AdamW (fp32 moments) and Adafactor (sub-linear memory).

Functional, pytree-based, sharding-transparent: optimizer state mirrors the
parameter tree, so the same NamedShardings (plus ZeRO-style extra sharding
for moments) apply leaf-wise.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


class AdamW:
    def __init__(self, cfg: AdamWConfig):
        self.cfg = cfg

    def init(self, params):
        f32zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(f32zeros, params),
            "v": jax.tree.map(f32zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def state_specs(self, param_specs):
        """ParamSpec tree for optimizer state (fp32 moments, same axes)."""
        from repro.dist.sharding import ParamSpec

        f32 = lambda s: ParamSpec(s.shape, s.axes, jnp.float32, 0.0)
        mk = lambda: jax.tree.map(
            f32, param_specs, is_leaf=lambda x: isinstance(x, ParamSpec)
        )
        return {
            "m": mk(),
            "v": mk(),
            "step": ParamSpec((), (), jnp.int32, 0.0),
        }

    def update(self, grads, state, params):
        cfg = self.cfg
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        b1, b2 = cfg.b1, cfg.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = lr_at(cfg, step)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            # decoupled weight decay on matrices only (ndim ≥ 2)
            if p.ndim >= 2:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"m": new_m, "v": new_v, "step": step}
        return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


class Adafactor:
    """Factored second moments (Shazeer & Stern) — sub-linear optimizer
    memory for the 671B-scale cells; used by the memory hillclimb."""

    def __init__(self, cfg: AdamWConfig):
        self.cfg = cfg

    def _factored(self, shape) -> bool:
        return len(shape) >= 2

    def init(self, params):
        def leaf(p):
            if self._factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "v": jax.tree.map(leaf, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def state_specs(self, param_specs):
        from repro.dist.sharding import ParamSpec

        def leaf(s):
            if self._factored(s.shape):
                return {
                    "vr": ParamSpec(s.shape[:-1], s.axes[:-1], jnp.float32, 0.0),
                    "vc": ParamSpec(
                        s.shape[:-2] + s.shape[-1:], s.axes[:-2] + s.axes[-1:],
                        jnp.float32, 0.0,
                    ),
                }
            return {"v": ParamSpec(s.shape, s.axes, jnp.float32, 0.0)}

        return {
            "v": jax.tree.map(
                leaf, param_specs, is_leaf=lambda x: isinstance(x, ParamSpec)
            ),
            "step": ParamSpec((), (), jnp.int32, 0.0),
        }

    def update(self, grads, state, params):
        cfg = self.cfg
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        lr = lr_at(cfg, step)
        decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

        def upd(p, g, v):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + 1e-30
            if self._factored(p.shape):
                vr = decay * v["vr"] + (1 - decay) * g2.mean(-1)
                vc = decay * v["vc"] + (1 - decay) * g2.mean(-2)
                denom = (
                    vr[..., None] * vc[..., None, :] / jnp.maximum(
                        vr.mean(-1, keepdims=True)[..., None], 1e-30
                    )
                )
                delta = g32 * jax.lax.rsqrt(denom + 1e-30)
                nv = {"vr": vr, "vc": vc}
            else:
                nvv = decay * v["v"] + (1 - decay) * g2
                delta = g32 * jax.lax.rsqrt(nvv + 1e-30)
                nv = {"v": nvv}
            # update clipping (RMS ≤ 1) per Adafactor
            rms = jnp.sqrt(jnp.mean(jnp.square(delta)) + 1e-30)
            delta = delta / jnp.maximum(1.0, rms)
            if p.ndim >= 2:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), nv

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        outs = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_v = tdef.unflatten([o[1] for o in outs])
        return new_params, {"v": new_v, "step": step}, {"lr": lr, "grad_norm": gnorm}


def build_optimizer(name: str, cfg: AdamWConfig):
    return {"adamw": AdamW, "adafactor": Adafactor}[name](cfg)
