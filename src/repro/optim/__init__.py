from repro.optim.adamw import AdamW, AdamWConfig, Adafactor, build_optimizer, lr_at
from repro.optim.grad_compress import (
    compress_with_feedback,
    compressed_psum,
    dequantize_int8,
    quantize_int8,
    tree_compressed_pmean,
)
