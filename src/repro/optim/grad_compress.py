"""Gradient compression for cross-pod (DCN) all-reduce.

int8 symmetric quantization with error feedback (1-bit-Adam-style residual
carry).  On the multi-pod mesh the ``pod`` axis crosses DCN — its gradient
all-reduce is the slowest collective — so compressing that hop 4×
(bf16→int8 including scales) is the standard distributed-optimization
trick.  ``compressed_psum`` is a shard_map building block: quantize →
psum(int32) → dequantize, with the quantization error fed back into the
next step's gradient.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro._compat.jaxshims  # noqa: F401 — installs jax.shard_map on 0.4.x


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g: jax.Array, err: jax.Array):
    """Quantize (g + carried error); return (q, scale, new_error)."""
    g32 = g.astype(jnp.float32) + err
    q, scale = quantize_int8(g32)
    new_err = g32 - dequantize_int8(q, scale)
    return q, scale, new_err


def compressed_psum(g: jax.Array, err: jax.Array, axis: str):
    """int8 all-reduce over ``axis`` with error feedback.

    Must be called inside shard_map with ``axis`` in scope.  The wire format
    is int32 (XLA psum of int8 accumulates exactly in int32 for ≤ 2^23
    shards) + one f32 scale per shard (psum'd — equivalent to max-scale
    broadcast for symmetric quant when combined linearly per-shard).
    """
    # Quantize directly at the SHARED scale s_max = max_i s_i (one pmax of a
    # scalar), so the error feedback carries exactly what this shard failed
    # to contribute — quantizing at a local scale and re-rescaling would
    # leave the re-rescale error out of the residual.
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    s_max = jax.lax.pmax(scale, axis)
    q = jnp.clip(jnp.round(g32 / s_max), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * s_max
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    mean = total.astype(jnp.float32) * s_max / n
    return mean.astype(g.dtype), new_err


def tree_compressed_pmean(grads, errs, axis: str):
    """Apply compressed_psum leaf-wise over a gradient pytree."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errs)
    out, new_errs = [], []
    for g, e in zip(flat_g, flat_e):
        m, ne = compressed_psum(g, e, axis)
        out.append(m)
        new_errs.append(ne)
    return tdef.unflatten(out), tdef.unflatten(new_errs)
