"""Serving driver: continuous-batching engine fed by a ProxyStream.

Runs the reduced (smoke) config of any assigned arch on CPU under the
``serve`` rules profile: a client thread publishes prompt requests
(metadata → broker, bulk prompt → store) under a backpressure window, the
engine admits them into slots, decodes greedily, and streams *token deltas*
plus final completions back; a :class:`repro.serve.client.ServeClient`
assembles them and reports time-to-first-token.

The client's send window is bounded by completions (in-flight ≤ 2×slots)
and every blocking edge has a deadline, so a wedged engine or a full store
surfaces as a loud error instead of a silently deadlocked driver.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --requests 8 --slots 4 --max-new 12
"""
from __future__ import annotations

import argparse
import sys
import threading
import time

import jax
import numpy as np

from repro.configs import arch_names, get_smoke_config
from repro.core.store import Store
from repro.core.streaming import (
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)
from repro.dist.sharding import materialize_params, sharding_tree
from repro.models.api import build_model
from repro.serve.client import ServeClient
from repro.serve.engine import ServeEngine, serve_context


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m", choices=arch_names(True))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--send-timeout", type=float, default=60.0,
                    help="client-side bound on one admission-window wait")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page granularity (paged decode needs "
                         "max-len % page-size == 0; else dense fallback)")
    ap.add_argument("--use-kernels", action="store_true",
                    help="dispatch attention through the Pallas kernel ops "
                         "(paged attention on the decode path)")
    ap.add_argument("--no-paged", dest="paged", action="store_false",
                    help="dense (L, B, max_len) KV layout instead of the "
                         "paged pool (the benchmark baseline)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decode: draft-proposed tokens per "
                         "slot per step (0 = off); emitted tokens stay "
                         "bit-identical to plain greedy decode")
    ap.add_argument("--draft-config", default=None, choices=arch_names(True),
                    help="smoke config for the draft model (--spec-k > 0); "
                         "defaults to --arch (self-draft)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    # serve rules profile: kv_seq over model axis
    ctx = serve_context(cfg, use_kernels=args.use_kernels)
    model = build_model(ctx)
    with ctx.mesh:
        params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))
        if ctx.mesh.size > 1:
            params = jax.device_put(
                params, sharding_tree(model.param_specs(), ctx.rules, ctx.mesh)
            )

    from repro.core.connectors import new_key

    ns = f"serve-demo-{new_key()}"  # unique per run: re-entrant in-process
    store = Store(f"{ns}-requests")
    producer = StreamProducer(QueuePublisher(ns), {"requests": store})
    consumer = StreamConsumer(QueueSubscriber("requests", ns), timeout=30.0)
    resp_store = Store(f"{ns}-responses")
    resp_producer = StreamProducer(QueuePublisher(ns), {"responses": resp_store})
    resp_consumer = StreamConsumer(QueueSubscriber("responses", ns), timeout=30.0)

    rng = np.random.default_rng(0)
    # Backpressure window: a send blocks once 2×slots requests are in
    # flight and is released per completion — the client can never run the
    # store/broker arbitrarily ahead of the engine (a blocked client used
    # to deadlock the driver: run() never returned, t.join() never ran).
    window = threading.Semaphore(2 * args.slots)
    client = ServeClient(resp_consumer, on_done=lambda *_: window.release())
    sent_at: dict[str, float] = {}
    client_err: list[BaseException] = []

    def send_requests():
        try:
            for i in range(args.requests):
                if not window.acquire(timeout=args.send_timeout):
                    raise TimeoutError(
                        f"admission window stalled for {args.send_timeout}s "
                        f"(engine wedged?)"
                    )
                prompt = rng.integers(
                    1, cfg.vocab, args.prompt_len
                ).astype(np.int32)
                sent_at[f"r{i}"] = time.perf_counter()
                producer.send(
                    "requests",
                    {"prompt": prompt},
                    metadata={"req_id": f"r{i}", "max_new_tokens": args.max_new},
                )
                producer.flush_topic("requests")
            producer.close_topic("requests")
        except BaseException as e:  # pragma: no cover - error path
            client_err.append(e)
            producer.close_topic("requests")

    def collect_responses():
        try:
            client.collect()  # until the engine closes the response topic
        except BaseException as e:  # pragma: no cover - error path
            client_err.append(e)

    sender = threading.Thread(target=send_requests, daemon=True)
    collector = threading.Thread(target=collect_responses, daemon=True)
    sender.start()
    collector.start()

    draft_model = draft_params = None
    if args.spec_k > 0:
        if args.draft_config is None or args.draft_config == args.arch:
            # self-draft: reuses the target's params (the degenerate case
            # that maximizes acceptance; a real deployment would pass a
            # smaller --draft-config)
            draft_model, draft_params = model, params
        else:
            dcfg = get_smoke_config(args.draft_config)
            dctx = serve_context(dcfg, use_kernels=args.use_kernels)
            draft_model = build_model(dctx)
            with dctx.mesh:
                draft_params = materialize_params(
                    draft_model.param_specs(), jax.random.PRNGKey(1)
                )

    engine = ServeEngine(
        ctx, params, slots=args.slots, max_len=args.max_len,
        page_size=args.page_size, eos_id=-1, paged=args.paged,
        spec_k=args.spec_k, draft_model=draft_model, draft_params=draft_params,
    )
    t0 = time.perf_counter()
    completed = engine.run(consumer, resp_producer)
    wall = time.perf_counter() - t0
    # Bounded joins: the engine is done, so a still-blocked client is a bug
    # worth failing loudly on, not waiting forever for.
    sender.join(timeout=30)
    collector.join(timeout=30)
    if sender.is_alive() or collector.is_alive():
        raise RuntimeError("client threads did not drain after engine exit")
    if client_err:
        raise client_err[0]

    lat = [c["latency"] for c in completed.values()]
    ttfts = list(client.ttft_s(sent_at).values())
    spec_note = ""
    if args.spec_k > 0 and engine.metrics["spec_slot_steps"]:
        rate = (
            engine.metrics["spec_accepted_tokens"]
            / engine.metrics["spec_slot_steps"]
        )
        spec_note = f" accepted/slot-step {rate:.2f} (spec_k={args.spec_k});"
    print(
        f"[serve] {args.arch} (smoke): {len(completed)}/{args.requests} requests, "
        f"{engine.metrics['tokens']} tokens in {wall:.1f}s "
        f"({engine.metrics['tokens']/wall:.1f} tok/s); "
        f"mean latency {np.mean(lat):.2f}s; "
        f"mean ttft {np.mean(ttfts):.3f}s (streamed deltas);{spec_note} "
        f"pages in use at exit: {engine.pages.pages_in_use()}"
    )
    streamed_ok = all(
        r.stream_tokens == r.result["tokens"]
        for r in client.results.values()
        if r.result is not None
    )
    ok = (
        len(completed) == args.requests
        and engine.pages.pages_in_use() == 0
        and (engine.draft_pages is None
             or engine.draft_pages.pages_in_use() == 0)
        and len(client.results) == args.requests
        and streamed_ok
    )
    engine.close()
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
