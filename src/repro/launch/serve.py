"""Serving driver: continuous-batching engine fed by a ProxyStream.

Runs the reduced (smoke) config of any assigned arch on CPU: a client thread
publishes prompt requests (metadata → broker, bulk prompt → store), the
engine admits them into slots, decodes greedily, and streams responses back.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --requests 8 --slots 4 --max-new 12
"""
from __future__ import annotations

import argparse
import sys
import threading
import time

import jax
import numpy as np

from repro.configs import arch_names, get_smoke_config
from repro.core.store import Store
from repro.core.streaming import (
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)
from repro.dist.sharding import materialize_params
from repro.launch.mesh import make_host_mesh, rules_for
from repro.models.api import build_model
from repro.models.layers import ModelContext
from repro.serve.engine import ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m", choices=arch_names(True))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    mesh = make_host_mesh()
    ctx = ModelContext(cfg, mesh, rules_for(mesh))
    model = build_model(ctx)
    with mesh:
        params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))

    ns = "serve-demo"
    store = Store("requests")
    producer = StreamProducer(QueuePublisher(ns), {"requests": store})
    consumer = StreamConsumer(QueueSubscriber("requests", ns), timeout=0.05)
    resp_store = Store("responses")
    resp_producer = StreamProducer(QueuePublisher(ns), {"responses": resp_store})

    rng = np.random.default_rng(0)

    def client():
        for i in range(args.requests):
            prompt = rng.integers(1, cfg.vocab, args.prompt_len).astype(np.int32)
            producer.send(
                "requests",
                {"prompt": prompt},
                metadata={"req_id": f"r{i}", "max_new_tokens": args.max_new},
            )
            producer.flush_topic("requests")
            time.sleep(0.01)
        producer.close_topic("requests")

    t = threading.Thread(target=client, daemon=True)
    t.start()

    engine = ServeEngine(
        ctx, params, slots=args.slots, max_len=args.max_len, eos_id=-1
    )
    t0 = time.perf_counter()
    completed = engine.run(consumer, resp_producer)
    wall = time.perf_counter() - t0
    t.join()

    lat = [c["latency"] for c in completed.values()]
    print(
        f"[serve] {args.arch} (smoke): {len(completed)}/{args.requests} requests, "
        f"{engine.metrics['tokens']} tokens in {wall:.1f}s "
        f"({engine.metrics['tokens']/wall:.1f} tok/s); "
        f"mean latency {np.mean(lat):.2f}s; "
        f"pages in use at exit: {engine.pages.pages_in_use()}"
    )
    ok = len(completed) == args.requests and engine.pages.pages_in_use() == 0
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
