"""Launch a standalone TCP store server (``repro.core.connectors_net``).

One process serves one backing connector over the PSF1 wire protocol;
any number of ``StoreServerConnector`` clients across hosts/processes
share it as a single channel.  Prints a machine-parsable ready line::

    PSRV READY <host> <port>

to stdout (flushed) once the listener is bound, so wrappers can spawn it
with ``--port 0`` and scrape the OS-assigned port.

Usage::

    PYTHONPATH=src python -m repro.launch.store_server                  # memory backing
    PYTHONPATH=src python -m repro.launch.store_server --port 7777
    PYTHONPATH=src python -m repro.launch.store_server --backing file:/tmp/psrv
    PYTHONPATH=src python -m repro.launch.store_server --backing shm:myns
"""
import argparse
import signal
import sys

from repro.core.connectors import (
    FileConnector,
    InMemoryConnector,
    SharedMemoryConnector,
)
from repro.core.connectors_net import StoreServer


def make_backing(spec: str):
    """``memory[:NS]`` | ``file:DIR`` | ``shm[:NS]`` → connector."""
    kind, _, arg = spec.partition(":")
    if kind == "memory":
        return InMemoryConnector(arg or "srv")
    if kind == "file":
        if not arg:
            raise ValueError("file backing needs a directory: --backing file:DIR")
        return FileConnector(arg)
    if kind == "shm":
        return SharedMemoryConnector(arg or "srv")
    raise ValueError(f"unknown backing {spec!r} (memory[:NS] | file:DIR | shm[:NS])")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 (default): let the OS pick; scrape the READY line")
    ap.add_argument("--backing", default="memory",
                    help="memory[:NS] | file:DIR | shm[:NS] (default: memory)")
    args = ap.parse_args(argv)

    server = StoreServer(
        backing=make_backing(args.backing), host=args.host, port=args.port
    )
    server.start()
    print(f"PSRV READY {server.host} {server.port}", flush=True)

    def _stop(signum, frame):
        server.stop()
        raise SystemExit(0)

    signal.signal(signal.SIGTERM, _stop)
    signal.signal(signal.SIGINT, _stop)
    try:
        server.serve_forever()
    finally:
        server.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
