"""End-to-end training driver.

``--arch <id>`` selects any assigned architecture; on this CPU container the
smoke (reduced) config trains for real, while full configs are exercised via
the dry-run.  The loop is the full production stack: ProxyStream input
pipeline → fault-tolerant Trainer (async proxy checkpoints, watchdog,
restart) on a named mesh.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 100 --batch 8 --seq 128 --smoke
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax

from repro.configs import arch_names, get_config, get_smoke_config
from repro.data.pipeline import (
    DispatchingDataLoader,
    StreamingDataLoader,
    SyntheticCorpus,
)
from repro.launch.mesh import make_host_mesh, rules_for
from repro.models.layers import ModelContext
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m", choices=arch_names(True))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train-ckpt")
    ap.add_argument("--dispatch-workers", type=int, default=0,
                    help="feed via the shard-dispatching loader (redispatch "
                         "on straggle/death) instead of the plain stream")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default=None, help="write history JSON here")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    ctx = ModelContext(cfg, mesh, rules_for(mesh))

    tc = TrainerConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1)),
        microbatch=args.microbatch,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
    )
    trainer = Trainer(ctx, tc)
    if not args.resume:
        trainer.init_state()

    corpus = SyntheticCorpus(cfg, args.batch, args.seq)
    if args.dispatch_workers > 0:
        loader = DispatchingDataLoader(
            corpus.next_batch, num_steps=args.steps + 8,
            workers=args.dispatch_workers, prefetch=2,
        )
    else:
        loader = StreamingDataLoader(
            corpus.next_batch, num_steps=args.steps + 8, prefetch=2
        )
    t0 = time.perf_counter()
    history = trainer.train(loader, args.steps)
    wall = time.perf_counter() - t0
    loader.stop()

    first = history[0]["loss"] if history else float("nan")
    last = history[-1]["loss"] if history else float("nan")
    print(
        f"[train] {args.arch}{' (smoke)' if args.smoke else ''}: "
        f"{len(history)} steps in {wall:.1f}s; loss {first:.3f} → {last:.3f}; "
        f"stragglers {trainer.watchdog.stragglers}; failures {trainer.failures}"
    )
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"history": history, "wall_s": wall}, f)
    return 0 if (history and last < first) else 1


if __name__ == "__main__":
    sys.exit(main())
