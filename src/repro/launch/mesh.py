"""Production mesh factories + the elastic MeshPlan → Mesh driver.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, while smoke tests and benchmarks must keep seeing 1 device.

:class:`ElasticMeshDriver` (PR 4) closes the fault loop the PR 1 stub left
open: lease membership (``dist.lease``) → :func:`repro.dist.fault.
elastic_plan` → :func:`plan_to_mesh` → ``Trainer.request_remesh``.  The
driver *subscribes* to membership through ``LeaseService.watch`` (one
notification-based ``wait_for_any`` per round, deadline-capped at the next
lease expiry) — never a poll loop — and relies on the ``materialize_params``
determinism invariant: params re-placed on the new mesh are bitwise the
logical arrays the old mesh held.
"""
from __future__ import annotations

import math
import threading
import time

import jax
from jax.sharding import Mesh

from repro.dist.fault import MeshPlan, elastic_plan
from repro.dist.lease import LeaseService, MembershipSnapshot
from repro.dist.sharding import AxisRules, DEFAULT_RULES, MULTIPOD_RULES, RULE_PROFILES


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single-pod (16 data × 16 model) = 256 chips or 2-pod = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    devices = jax.devices()[: math.prod(shape)]
    return jax.make_mesh(shape, axes, devices=devices)


def rules_for(mesh: Mesh, profile: str = "default") -> AxisRules:
    pod_rules, multipod_rules = RULE_PROFILES[profile]
    return multipod_rules if "pod" in mesh.shape else pod_rules


def make_host_mesh() -> Mesh:
    """1-device mesh for smoke tests / CPU examples (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def plan_to_mesh(plan: MeshPlan, *, devices=None) -> Mesh:
    """Realize a :class:`MeshPlan` as a ``jax.Mesh``.

    Uses the plan's ``as_mesh_spec`` (pod axis only when >1); raises when
    the plan wants more devices than the runtime has — an elastic re-plan
    must never silently oversubscribe.
    """
    shape, names = plan.as_mesh_spec()
    devices = list(jax.devices()) if devices is None else list(devices)
    need = math.prod(shape)
    if len(devices) < need:
        raise ValueError(
            f"plan {plan} needs {need} devices; runtime has {len(devices)}"
        )
    return jax.make_mesh(shape, names, devices=devices[:need])


class ElasticMeshDriver:
    """Watch lease membership; re-plan and re-mesh the trainer on change.

    ``trainer`` is duck-typed: anything with ``request_remesh(ctx,
    plan=...)`` (the Trainer applies it at the next step boundary — a
    remesh must not race a running step).  ``mesh_factory(plan)`` defaults
    to :func:`plan_to_mesh`; tests inject a smoke factory that maps any
    plan onto the 1-device mesh (same axis names, so the rules profile
    still switches between pod/multipod resolution).

    Capacity model: each live lease contributes ``chips_per_worker`` chips
    (a worker is a host owning a fixed slice of the pod); ``elastic_plan``
    pins model parallelism and degrades data parallelism to a power of two.
    """

    def __init__(
        self,
        leases: LeaseService,
        trainer,
        cfg,
        *,
        chips_per_worker: int,
        model_parallel: int,
        chips_per_pod: int = 256,
        profile: str = "default",
        mesh_factory=None,
        use_kernels: bool = False,
    ):
        self.leases = leases
        self.trainer = trainer
        self.cfg = cfg
        self.chips_per_worker = chips_per_worker
        self.model_parallel = model_parallel
        self.chips_per_pod = chips_per_pod
        self.profile = profile
        self.mesh_factory = mesh_factory or plan_to_mesh
        self.use_kernels = use_kernels
        self.events: list[dict] = []
        self.snap: MembershipSnapshot = leases.snapshot()
        self.plan: MeshPlan | None = self._plan_for(len(self.snap.live))
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    def _plan_for(self, live_workers: int) -> MeshPlan | None:
        try:
            return elastic_plan(
                live_workers * self.chips_per_worker,
                model_parallel=self.model_parallel,
                chips_per_pod=self.chips_per_pod,
            )
        except ValueError:
            return None  # below one model-parallel group: no viable mesh

    def _context_for(self, plan: MeshPlan):
        from repro.models.layers import ModelContext

        mesh = self.mesh_factory(plan)
        return ModelContext(
            self.cfg, mesh, rules_for(mesh, self.profile), self.use_kernels
        )

    def check(self, timeout: float | None = 1.0) -> MeshPlan | None:
        """One subscription round: block until membership may have changed
        (or ``timeout``), re-plan, and request a remesh when the plan moved.

        Returns the new plan when a remesh was requested, else ``None``.
        """
        snap = self.leases.watch(self.snap, timeout=timeout)
        if snap == self.snap:
            return None
        self.snap = snap
        plan = self._plan_for(len(snap.live))
        if plan is None:
            self.events.append(
                {"kind": "no-capacity", "live": list(snap.live), "t": time.time()}
            )
            return None
        if plan == self.plan:
            return None
        old, self.plan = self.plan, plan
        self.events.append(
            {"kind": "replan", "live": list(snap.live), "from": str(old),
             "to": str(plan), "t": time.time()}
        )
        self.trainer.request_remesh(self._context_for(plan), plan=plan)
        return plan

    # -- background loop ----------------------------------------------------------
    def run(self, stop: threading.Event | None = None, poll: float = 1.0) -> None:
        stop = stop or self._stop
        while not stop.is_set():
            try:
                self.check(timeout=poll)
            except Exception as e:  # noqa: BLE001 - the watch must survive
                # e.g. plan_to_mesh on a box with too few devices: record
                # and keep watching — a dead watch thread is silent loss of
                # all fault tolerance, strictly worse than a failed remesh
                self.events.append(
                    {"kind": "error", "error": repr(e), "t": time.time()}
                )
                # don't hot-loop on a persistent failure
                time.sleep(poll)  # proxylint: disable=no-sleep-poll

    def start(self, poll: float = 1.0) -> None:
        self._stop.clear()
        self._thread = threading.Thread(
            target=self.run, kwargs={"poll": poll}, daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
