"""Production mesh factories.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, while smoke tests and benchmarks must keep seeing 1 device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.dist.sharding import AxisRules, DEFAULT_RULES, MULTIPOD_RULES, RULE_PROFILES


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single-pod (16 data × 16 model) = 256 chips or 2-pod = 512 chips."""
    import math

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    devices = jax.devices()[: math.prod(shape)]
    return jax.make_mesh(shape, axes, devices=devices)


def rules_for(mesh: Mesh, profile: str = "default") -> AxisRules:
    pod_rules, multipod_rules = RULE_PROFILES[profile]
    return multipod_rules if "pod" in mesh.shape else pod_rules


def make_host_mesh() -> Mesh:
    """1-device mesh for smoke tests / CPU examples (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))
