"""Serve fleet launcher: N engine processes behind one failover router.

Topology (one driver process + one store server + N engines)::

    client ──requests──▶ Router ──requests-eI──▶ ServeEngine (proc eI)
       ▲                   │  ▲                        │
       └──responses◀───────┘  └─lease watch    responses-eI / load-eI

- Bulk payloads (prompts, completions) live on a TCP ``StoreServer``;
  the FileLog broker carries only metadata events, so the router stays a
  metadata-only hop (it never resolves a proxy).
- Engines register under a :class:`~repro.dist.lease.LeaseService` on the
  control namespace and renew at ``ttl/4``; the router redispatches a dead
  engine's in-flight requests to survivors (see ``repro.serve.router``).
- Prompts are published with ``evict_on_resolve=False`` and completions
  are committed via ``send_committed`` at ``done-{req_id}``, so a request
  re-served after a SIGKILL resolves the same prompt bytes and twin
  completions share one payload cell — no request is lost or double-
  delivered.

Subcommands::

    python -m repro.launch.fleet engine --name e0 --addr H:P --dir LOG \\
        --prefix fleet-x --toy ...        # one fleet engine (subprocess)
    python -m repro.launch.fleet demo --engines 2 --requests 8   # local demo
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import threading
import time

from repro.core.connectors import new_key
from repro.core.connectors_net import StoreServer, StoreServerConnector
from repro.core.store import Store
from repro.core.streaming import (
    FileLogPublisher,
    FileLogSubscriber,
    StreamConsumer,
    StreamProducer,
)

READY_LINE = "FLEET ENGINE READY"
LEASE_PREFIX = "fleet"


def _env_with_src() -> dict:
    """Subprocess env whose PYTHONPATH reaches this ``repro`` package."""
    import repro

    env = dict(os.environ)
    # namespace-package tolerant: __file__ may be None, __path__ is not
    pkg_dir = (
        os.path.dirname(os.path.abspath(repro.__file__))
        if getattr(repro, "__file__", None)
        else os.path.abspath(next(iter(repro.__path__)))
    )
    src = os.path.dirname(pkg_dir)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


# ---------------------------------------------------------------------------
# Engine subprocess entry point
# ---------------------------------------------------------------------------


def _engine_main(args) -> int:
    """One fleet engine: lease heartbeat + serve loop over the fleet topics.

    Prints ``FLEET ENGINE READY <name>`` (flushed) once the lease is held
    and the initial load cell is published, so the spawner can scrape it.
    """
    from repro.configs import get_smoke_config
    from repro.dist.lease import LeaseLost, LeaseService
    from repro.serve.engine import ServeEngine, serve_context

    cfg = get_smoke_config(args.arch)
    ctx = serve_context(cfg)
    if args.toy:
        from repro.serve.toy import CountingModel

        model, params = CountingModel(cfg), {}
    else:
        import jax

        from repro.dist.sharding import materialize_params
        from repro.models.api import build_model

        model = build_model(ctx)
        with ctx.mesh:
            params = materialize_params(
                model.param_specs(), jax.random.PRNGKey(0)
            )

    name = args.name
    ctl_store = Store(
        f"{args.prefix}-ctl",
        StoreServerConnector(args.addr, namespace="ctl"),
        register=False,
    )
    resp_store = Store(
        f"{args.prefix}-resp",
        StoreServerConnector(args.addr, namespace="resp"),
        register=False,
    )

    engine = ServeEngine(
        ctx,
        params,
        model=model,
        slots=args.slots,
        max_len=args.max_len,
        page_size=args.page_size,
        eos_id=args.eos_id,
        # fleet hooks: least-loaded routing + exactly-once completions
        on_load_change=lambda pages: ctl_store.put(
            pages, key=f"load-{name}"
        ),
        done_commit_prefix="done-",
    )

    lease = LeaseService(ctl_store, ttl=args.ttl, prefix=LEASE_PREFIX)
    gen = [lease.register(name)]
    ctl_store.put(engine.pages.pages_available(), key=f"load-{name}")
    print(f"{READY_LINE} {name}", flush=True)

    stop = threading.Event()
    beat_errors = [0]

    def heartbeat():
        while not stop.wait(args.ttl / 4):
            try:
                lease.renew(name, gen[0])
            except LeaseLost:
                # fenced out: a newer incarnation owns this name — this
                # process must stop serving rather than split-brain
                os._exit(17)
            except TimeoutError:  # LeaseExpired: dead until re-registered
                try:
                    gen[0] = lease.register(name)
                except Exception:
                    beat_errors[0] += 1
            except Exception:
                beat_errors[0] += 1  # transient channel error: keep beating

    hb = threading.Thread(target=heartbeat, name="fleet-heartbeat", daemon=True)
    hb.start()

    if args.hold_key:
        # chaos hook: hold BEFORE the serve loop — the engine is a lease-
        # holding, load-publishing member that never admits anything
        ctl_store.wait_for(args.hold_key, timeout=600.0)

    consumer = StreamConsumer(
        FileLogSubscriber(f"requests-{name}", args.dir), timeout=120.0
    )
    producer = StreamProducer(FileLogPublisher(args.dir), {"*": resp_store})
    try:
        engine.run(consumer, producer, response_topic=f"responses-{name}")
    finally:
        stop.set()
        # completion bulks stay resident for lagging clients (their
        # one-shot resolves reclaim them); prompts are reclaimed here
        engine.close(reclaim_responses=False)
    return 0


# ---------------------------------------------------------------------------
# Driver-side process handle + fleet harness
# ---------------------------------------------------------------------------


class EngineProc:
    """Spawn/scrape/kill handle for one ``fleet engine`` subprocess."""

    def __init__(
        self,
        name: str,
        addr: str,
        logdir: str,
        prefix: str,
        *,
        arch: str = "smollm-135m",
        toy: bool = True,
        slots: int = 2,
        max_len: int = 32,
        page_size: int = 4,
        ttl: float = 3.0,
        hold_key: str | None = None,
    ):
        self.name = name
        cmd = [
            sys.executable, "-m", "repro.launch.fleet", "engine",
            "--name", name, "--addr", addr, "--dir", logdir,
            "--prefix", prefix, "--arch", arch,
            "--slots", str(slots), "--max-len", str(max_len),
            "--page-size", str(page_size), "--ttl", str(ttl),
        ]
        if toy:
            cmd.append("--toy")
        if hold_key:
            cmd += ["--hold-key", hold_key]
        self._errpath = os.path.join(logdir, f"{name}.stderr")
        self._errfile = open(self._errpath, "wb")
        self.proc = subprocess.Popen(
            cmd,
            env=_env_with_src(),
            stdout=subprocess.PIPE,
            stderr=self._errfile,
        )

    def wait_ready(self) -> None:
        """Block until the READY line (EOF ⇒ startup crash, stderr shown)."""
        while True:
            line = self.proc.stdout.readline()
            if not line:
                err = ""
                try:
                    with open(self._errpath, "rb") as f:
                        err = f.read().decode(errors="replace")[-4000:]
                except OSError:
                    pass
                raise RuntimeError(
                    f"fleet engine {self.name} exited before READY "
                    f"(rc={self.proc.poll()}):\n{err}"
                )
            if line.decode(errors="replace").startswith(READY_LINE):
                break
        # drain further stdout so the pipe can never fill and block the
        # engine's prints
        threading.Thread(
            target=lambda: [None for _ in iter(self.proc.stdout.readline, b"")],
            name=f"drain-{self.name}",
            daemon=True,
        ).start()

    def kill(self) -> None:
        """SIGKILL — the chaos primitive: no cleanup, no lease release."""
        if self.proc.poll() is None:
            self.proc.kill()
        self.proc.wait(timeout=30)
        self._errfile.close()

    def alive(self) -> bool:
        return self.proc.poll() is None

    def stop(self) -> None:
        if self.proc.poll() is None:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait(timeout=30)
        self._errfile.close()


class Fleet:
    """An N-engine serve fleet in one object (driver-process side).

    Owns the store server, the FileLog directory, the engine subprocesses,
    the router, and the client-side producer/consumer pair.  Tests drive
    chaos through :meth:`kill_engine` / router hooks; the benchmark drives
    throughput through :func:`run_fleet`.
    """

    def __init__(
        self,
        n_engines: int,
        *,
        arch: str = "smollm-135m",
        toy: bool = True,
        slots: int = 2,
        max_len: int = 32,
        page_size: int = 4,
        ttl: float = 3.0,
        tick: float = 0.05,
        hold: tuple = (),
        logdir: str | None = None,
        consumer_timeout: float = 300.0,
        on_done=None,
    ):
        from repro.configs import get_smoke_config
        from repro.dist.lease import LeaseService
        from repro.serve.client import ServeClient
        from repro.serve.router import Router

        self.cfg = get_smoke_config(arch)
        self.names = [f"e{i}" for i in range(n_engines)]
        self.logdir = logdir or tempfile.mkdtemp(prefix="fleet-log-")
        self.prefix = f"fleet-{new_key()}"
        self.server = StoreServer().start()
        addr = self.server.address
        self.ctl_store = Store(
            f"{self.prefix}-ctl",
            StoreServerConnector(addr, namespace="ctl"),
            register=False,
        )
        req_store = Store(
            f"{self.prefix}-req",
            StoreServerConnector(addr, namespace="req"),
            register=False,
        )
        self.procs = {
            name: EngineProc(
                name, addr, self.logdir, self.prefix,
                arch=arch, toy=toy, slots=slots, max_len=max_len,
                page_size=page_size, ttl=ttl,
                hold_key=f"hold-{name}" if name in hold else None,
            )
            for name in self.names
        }
        for proc in self.procs.values():
            proc.wait_ready()
        self.lease = LeaseService(self.ctl_store, ttl=ttl, prefix=LEASE_PREFIX)
        self.router = Router(
            self.names,
            subscriber=FileLogSubscriber("requests", self.logdir),
            publisher=FileLogPublisher(self.logdir),
            make_engine_subscriber=lambda n: FileLogSubscriber(
                f"responses-{n}", self.logdir
            ),
            lease=self.lease,
            control_store=self.ctl_store,
            tick=tick,
        ).start()
        # persistent prompt bulks: a redispatched request's survivor engine
        # must be able to re-resolve the same key
        self.producer = StreamProducer(
            FileLogPublisher(self.logdir),
            {"requests": req_store},
            evict_on_resolve=False,
        )
        self.client = ServeClient(
            StreamConsumer(
                FileLogSubscriber("responses", self.logdir),
                timeout=consumer_timeout,
            ),
            on_done=on_done,
        )
        self.sent_at: dict[str, float] = {}

    # -- client side ---------------------------------------------------------
    def send(self, req_id: str, prompt, max_new: int) -> None:
        self.sent_at[req_id] = time.perf_counter()
        self.producer.send(
            "requests",
            {"prompt": prompt},
            metadata={"req_id": req_id, "max_new_tokens": max_new},
        )
        self.producer.flush_topic("requests")

    def close_intake(self) -> None:
        self.producer.close_topic("requests")

    # -- chaos ---------------------------------------------------------------
    def kill_engine(self, name: str) -> None:
        self.procs[name].kill()

    def release_hold(self, name: str) -> None:
        self.ctl_store.put(True, key=f"hold-{name}")

    # -- teardown ------------------------------------------------------------
    def stop(self) -> None:
        self.router.close()
        for proc in self.procs.values():
            proc.stop()
        self.server.stop()


def run_fleet(
    n_engines: int,
    *,
    requests: int,
    max_new: int = 16,
    prompt_len: int = 5,
    slots: int = 2,
    max_len: int = 64,
    page_size: int = 4,
    ttl: float = 5.0,
    warmup: int | None = None,
    seed: int = 0,
) -> dict:
    """One measured fleet run: warmup round, then a timed request batch.

    Returns aggregate tokens/s over the measured batch, the per-request
    TTFT distribution, the final per-engine assignment counts, and the
    router metrics — the numbers the ``fleet_scaling`` benchmark gates.
    """
    import numpy as np

    fleet = Fleet(
        n_engines,
        slots=slots,
        max_len=max_len,
        page_size=page_size,
        ttl=ttl,
    )
    rng = np.random.default_rng(seed)

    def prompt():
        return rng.integers(1, fleet.cfg.vocab, prompt_len).astype(np.int32)

    try:
        n_warm = n_engines * slots if warmup is None else warmup
        for i in range(n_warm):
            fleet.send(f"w{i}", prompt(), max_new)
        if n_warm:
            fleet.client.collect(n_warm, deadline=300.0)
        t0 = time.perf_counter()
        for i in range(requests):
            fleet.send(f"r{i}", prompt(), max_new)
        fleet.close_intake()
        fleet.client.collect(deadline=300.0)  # until the router closes
        measured = {
            rid: rec
            for rid, rec in fleet.client.results.items()
            if rid.startswith("r") and rec.result is not None
        }
        if len(measured) != requests:
            raise RuntimeError(
                f"fleet run incomplete: {len(measured)}/{requests} measured "
                f"requests finished (router: {fleet.router.metrics})"
            )
        wall = max(rec.done_at for rec in measured.values()) - t0
        tokens = sum(len(rec.result["tokens"]) for rec in measured.values())
        ttfts = sorted(
            rec.first_delta_at - fleet.sent_at[rid]
            for rid, rec in measured.items()
            if rec.first_delta_at is not None
        )
        assignment = fleet.router.snapshot()
        per_engine: dict[str, int] = {n: 0 for n in fleet.names}
        for rid in measured:
            per_engine[assignment[rid][0]] += 1
        return {
            "n_engines": n_engines,
            "requests": requests,
            "wall_s": wall,
            "tokens": tokens,
            "tokens_per_s": tokens / wall,
            "p50_ttft_s": ttfts[len(ttfts) // 2] if ttfts else 0.0,
            "p99_ttft_s": ttfts[min(len(ttfts) - 1, int(0.99 * len(ttfts)))]
            if ttfts
            else 0.0,
            "per_engine": per_engine,
            "router_metrics": dict(fleet.router.metrics),
        }
    finally:
        fleet.stop()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    eng = sub.add_parser("engine", help="run one fleet engine (subprocess)")
    eng.add_argument("--name", required=True)
    eng.add_argument("--addr", required=True, help="store server host:port")
    eng.add_argument("--dir", required=True, help="FileLog broker directory")
    eng.add_argument("--prefix", required=True, help="run-unique store prefix")
    eng.add_argument("--arch", default="smollm-135m")
    eng.add_argument("--toy", action="store_true",
                     help="CountingModel instead of the real arch")
    eng.add_argument("--slots", type=int, default=2)
    eng.add_argument("--max-len", type=int, default=32)
    eng.add_argument("--page-size", type=int, default=4)
    eng.add_argument("--eos-id", type=int, default=-1)
    eng.add_argument("--ttl", type=float, default=3.0)
    eng.add_argument("--hold-key", default=None,
                     help="wait on this control-store key before serving "
                     "(chaos hook: lease-live but never admitting)")

    demo = sub.add_parser("demo", help="run a local N-engine fleet demo")
    demo.add_argument("--engines", type=int, default=2)
    demo.add_argument("--requests", type=int, default=8)
    demo.add_argument("--max-new", type=int, default=16)
    demo.add_argument("--slots", type=int, default=2)

    args = ap.parse_args(argv)
    if args.cmd == "engine":
        return _engine_main(args)
    stats = run_fleet(
        args.engines,
        requests=args.requests,
        max_new=args.max_new,
        slots=args.slots,
    )
    print(
        f"[fleet] {stats['n_engines']} engines: {stats['requests']} requests, "
        f"{stats['tokens']} tokens in {stats['wall_s']:.2f}s "
        f"({stats['tokens_per_s']:.1f} tok/s); "
        f"p99 ttft {stats['p99_ttft_s'] * 1e3:.1f}ms; "
        f"per-engine {stats['per_engine']}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
