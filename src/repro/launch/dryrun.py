import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count on first init): the dry-run — and only the dry-run — needs 512
placeholder host devices so ``jax.make_mesh`` can build the production
meshes (16×16 single-pod, 2×16×16 multi-pod).

For each cell we AOT-lower the appropriate step (train_step for ``train_*``,
prefill for ``prefill_*``, serve_step for ``decode_*``/``long_*``) with
ShapeDtypeStruct stand-ins carrying the production NamedShardings, compile
it, and record ``memory_analysis()`` + ``cost_analysis()`` + the collective
schedule parsed from the post-optimization HLO — the inputs to
EXPERIMENTS.md §Dry-run and §Roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --list
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.analysis import roofline as RL
from repro.configs import SHAPES, arch_names, cell_applicable, get_config
from repro.dist.sharding import sharding_tree
from repro.launch.mesh import make_production_mesh, rules_for
from repro.models.api import param_counts, train_input_specs
from repro.models.layers import ModelContext
from repro.train.step import (
    abstract_decode_args,
    abstract_prefill_args,
    abstract_train_args,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results")


def _with_sharding(abs_tree, shard_tree):
    """Attach NamedShardings to a ShapeDtypeStruct pytree (AOT in_shardings)."""
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abs_tree,
        shard_tree,
    )


# ---------------------------------------------------------------------------
# Probe-and-extrapolate cost accounting.
#
# XLA's cost_analysis visits a while-loop body ONCE — it does not multiply by
# the trip count — so the scanned production program under-reports FLOPs,
# bytes, and collectives by ~n_layers×, and chunked attention/SSM scans over
# the sequence under-report by another ~n_chunks×.  Unrolling the full
# program instead is exact but compiles for hours on this box for the
# 60–80-layer archs at 32k sequence.
#
# We therefore lower a small design of FULLY-UNROLLED probe variants per
# cell — reduced depth (1–4 layers) × reduced sequence (256–1024, at which
# every chunk loop has trip count small enough to unroll in Python; see
# ``scan_stack`` / ``blockwise_attention(unroll=)``) — and fit, per metric,
# the exact polynomial
#
#   cost(L_t, S) = α0 + α1·s + Σ_type L_t·(β0_t + β1_t·s + β2_t·s²),  s=S/1024
#
# (embedding/head terms are affine in S; per-layer terms are quadratic in S
# because of attention; SSM/RWKV chunked forms are linear in S so their β2
# fits ≈0).  Full cost is the reconstruction at the production depth and
# sequence.  cost_analysis is deterministic arithmetic, so the fit is exact
# up to cross-compile optimization differences; the reconstruction is
# sanity-checked against the analytic 6·N·D bound (``useful_ratio``).
# Decode cells have no sequence loops (single-token flash-decode over the
# full cache), so they keep a depth-only design at the production cache
# length.  The scanned production program is still what we compile for the
# fits-in-memory proof and the multi-pod check.
# ---------------------------------------------------------------------------

# Probe window: XLA:CPU flop counts at S=256 are anomalously low for the
# very-wide archs (measured 256→512 growth of 2.37× for a token-linear
# layer), so the window starts at 512; verified 512→1024→2048 doublings are
# clean (2.03×, 2.05×).
PROBE_SEQS = (512, 1024, 2048)


def _layer_variants(cfg):
    """Per-family (variant-config, layer-count dict) pairs at reduced depth."""
    base = dict(scan_layers=False)
    fam = cfg.family
    if fam == "mla_moe" and cfg.first_k_dense:
        variants = [
            (cfg.with_(n_layers=2, first_k_dense=1, **base), {"dense": 1, "moe": 1}),
            (cfg.with_(n_layers=3, first_k_dense=2, **base), {"dense": 2, "moe": 1}),
            (cfg.with_(n_layers=3, first_k_dense=1, **base), {"dense": 1, "moe": 2}),
        ]
        full = {"dense": cfg.first_k_dense, "moe": cfg.n_layers - cfg.first_k_dense}
    elif fam == "moe":
        variants = [
            (cfg.with_(n_layers=1, **base), {"moe": 1}),
            (cfg.with_(n_layers=2, **base), {"moe": 2}),
        ]
        full = {"moe": cfg.n_layers}
    elif fam == "encdec":
        # encoder and decoder scale together (both 24 in whisper-medium)
        assert cfg.encoder_layers == cfg.n_layers
        variants = [
            (cfg.with_(n_layers=1, encoder_layers=1, **base), {"pair": 1}),
            (cfg.with_(n_layers=2, encoder_layers=2, **base), {"pair": 2}),
        ]
        full = {"pair": cfg.n_layers}
    elif fam == "hybrid":
        e = cfg.shared_attn_every
        variants = [
            (cfg.with_(n_layers=2, shared_attn_every=0, **base), {"mamba": 2}),
            (cfg.with_(n_layers=4, shared_attn_every=0, **base), {"mamba": 4}),
            (cfg.with_(n_layers=2, shared_attn_every=2, **base),
             {"mamba": 2, "attn": 1}),
        ]
        n_attn = len([i for i in range(cfg.n_layers) if e and i % e == e - 1])
        full = {"mamba": cfg.n_layers, "attn": n_attn}
    else:  # dense / rwkv / vlm — homogeneous stack
        variants = [
            (cfg.with_(n_layers=1, **base), {"layer": 1}),
            (cfg.with_(n_layers=2, **base), {"layer": 2}),
        ]
        full = {"layer": cfg.n_layers}
    return variants, full


def _design_row(layers: dict, seq: int | None) -> dict:
    """Feature row: const/S affine + per-layer-type quadratic in s=S/1024."""
    if seq is None:  # decode cells: depth-only design
        return {"const": 1.0, **{t: float(n) for t, n in layers.items()}}
    s = seq / 1024.0
    row = {"const": 1.0, "S": s}
    for t, n in layers.items():
        row[t] = float(n)
        row[f"{t}*S"] = n * s
        row[f"{t}*S2"] = n * s * s
    return row


def _probe_plan(cfg, shape):
    """(probe list [(cfg, shape, design-row)], full-reconstruction row)."""
    import dataclasses

    variants, full_layers = _layer_variants(cfg)
    if shape.kind == "decode":
        probes = [(v, shape, _design_row(lay, None)) for v, lay in variants]
        return probes, _design_row(full_layers, None)

    # Larger probe seqs for long cells: the S² coefficient is extrapolated
    # by (S_full/S_probe)², so cap the amplification at ~64× while keeping
    # every chunk loop small enough to unroll (≤4096 → ≤4×4 attention
    # chunks, ≤32 rwkv/ssd chunks per layer).
    if shape.seq_len > 8192:
        seqs = [1024, 2048, 4096]
    else:
        seqs = [s for s in PROBE_SEQS if s < shape.seq_len] or [shape.seq_len]
    if len(seqs) < 3 and shape.seq_len not in seqs:
        seqs = sorted(set(seqs) | {shape.seq_len})  # e.g. train at seq 1024
    probes = [
        (v, dataclasses.replace(shape, seq_len=s), _design_row(lay, s))
        for s in seqs
        for v, lay in variants
    ]
    return probes, _design_row(full_layers, shape.seq_len)


def _measure(compiled, chips: int, pod_group: int) -> dict:
    """Flat metric dict for one compiled program."""
    cost = RL.cost_analysis_dict(compiled)
    coll = RL.parse_collectives(
        compiled.as_text(), n_devices=chips, pod_group=pod_group
    )
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": coll.total_wire_bytes,
        "operand": float(coll.total_operand_bytes),
        "dcn": coll.dcn_wire_bytes,
    }
    for op, rec in coll.ops.items():
        out[f"op:{op}:count"] = float(rec["count"])
        out[f"op:{op}:wire"] = float(rec["wire_bytes"])
    return out


def _nnls(A, y):
    """Non-negative least squares via a simple active-set heuristic.

    Every physical cost coefficient (per-layer FLOPs, bytes, wire …) is
    ≥ 0; an unconstrained OLS fit can return sign-oscillating coefficients
    whose errors are amplified ~(S_full/S_probe)² ≈ 64× by the sequence
    extrapolation.  Solve OLS on a shrinking support, zeroing the most
    negative coordinate until all remaining coefficients are non-negative.
    """
    import numpy as np

    n = A.shape[1]
    support = list(range(n))
    beta = np.zeros(n)
    while support:
        b, *_ = np.linalg.lstsq(A[:, support], y, rcond=None)
        if (b >= -1e-12).all():
            beta[:] = 0.0
            beta[support] = np.maximum(b, 0.0)
            return beta
        support.pop(int(np.argmin(b)))
    return beta


def _extrapolate(measures: list[dict], design: list[dict], full: dict) -> dict:
    """Fit the cost polynomial per metric (NNLS) and reconstruct full size."""
    import numpy as np

    comps = sorted({c for row in design for c in row})
    A = np.array([[row.get(c, 0.0) for c in comps] for row in design], float)
    keys = sorted({k for m in measures for k in m})
    fvec = np.array([full.get(c, 0.0) for c in comps], float)
    out = {}
    for k in keys:
        y = np.array([m.get(k, 0.0) for m in measures], float)
        beta = _nnls(A, y)
        out[k] = float(max(fvec @ beta, 0.0))
    return out


def _lower_one(cfg, shape, mesh, rules, *, microbatch: int = 0):
    """Lower+compile one config/shape; returns (compiled, model, lower_s, compile_s)."""
    ctx = ModelContext(cfg, mesh, rules)
    t0 = time.perf_counter()
    if shape.kind == "train":
        bundle = make_train_step(ctx, microbatch=microbatch)
        state_abs, batch_abs, state_sh, batch_sh = abstract_train_args(
            ctx, bundle, shape.global_batch, shape.seq_len
        )
        lowered = bundle.fn.lower(
            _with_sharding(state_abs, state_sh), _with_sharding(batch_abs, batch_sh)
        )
    elif shape.kind == "prefill":
        bundle = make_prefill_step(ctx, max_len=shape.seq_len)
        args_abs, args_sh = abstract_prefill_args(
            ctx, bundle, shape.global_batch, shape.seq_len
        )
        lowered = bundle.fn.lower(*_with_sharding(args_abs, args_sh))
    else:
        bundle = make_decode_step(ctx)
        args_abs, args_sh = abstract_decode_args(
            ctx, bundle, shape.global_batch, shape.seq_len
        )
        lowered = bundle.fn.lower(*_with_sharding(args_abs, args_sh))
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    return compiled, bundle.model, t1 - t0, t2 - t1


def _roofline_from_est(arch, shape_name, mesh_desc, chips, pod_group, est,
                       model_flops, mem, extra_notes=""):
    """Assemble the RooflineReport from an (extrapolated) metric dict."""
    collective_ops = {
        op: {
            "count": est.get(f"op:{op}:count", 0.0),
            "wire_bytes": est.get(f"op:{op}:wire", 0.0),
        }
        for op in sorted(
            {k.split(":")[1] for k in est if k.startswith("op:")}
        )
    }
    report = RL.analyze(
        arch=arch,
        shape=shape_name,
        mesh_desc=mesh_desc,
        chips=chips,
        cost={"flops": est["flops"], "bytes accessed": est["bytes"]},
        hlo_text="",  # collectives already extrapolated below
        model_flops=model_flops,
        memory_stats=mem,
        pod_group=pod_group,
        notes=extra_notes,
    )
    # overwrite collective fields with the extrapolated values
    report.collective_wire_bytes = est["wire"]
    report.collective_operand_bytes = int(est["operand"])
    report.collective_ops = collective_ops
    report.t_collective = est["wire"] / RL.LINK_BW
    report.t_dcn = est["dcn"] / RL.DCN_BW
    terms = {
        "compute": report.t_compute,
        "memory": report.t_memory,
        "collective": report.t_collective,
    }
    report.dominant = max(terms, key=terms.get)
    report.step_time = max(max(terms.values()), report.t_dcn)
    report.mfu_bound = (
        model_flops / (chips * RL.PEAK_FLOPS * report.step_time)
        if report.step_time else 0.0
    )
    report.useful_ratio = (
        model_flops / (est["flops"] * chips) if est["flops"] else 0.0
    )
    return report


def refit_results(path: str) -> int:
    """Re-derive every roofline from the stored probe measures (no compiles).

    Used after improving the extrapolation (e.g. the NNLS change): the
    probes in the JSON are raw per-variant cost_analysis measures, so the
    fit can be redone offline.
    """
    with open(path) as f:
        recs = json.load(f)
    n = 0
    for rec in recs:
        if rec.get("status") != "ok" or not rec.get("probes"):
            continue
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        _, full = _probe_plan(cfg, shape)
        design = [p["design"] for p in rec["probes"]]
        measures = [p["measure"] for p in rec["probes"]]
        est = _extrapolate(measures, design, full)
        rl = rec["roofline"]
        chips = rec["chips"]
        pod_group = 0  # probe records exist only for the single-pod mesh
        report = _roofline_from_est(
            rec["arch"], rec["shape"], rl["mesh"], chips, pod_group, est,
            rl["model_flops"], rec.get("memory_analysis"), rl.get("notes", ""),
        )
        rec["roofline"] = report.to_json()
        rec["cost_flops_per_device"] = report.flops_per_device
        rec["cost_bytes_per_device"] = report.bytes_per_device
        n += 1
    with open(path, "w") as f:
        json.dump(recs, f, indent=1)
    print(f"[dryrun] refit {n} records in {path}")
    return 0


def lower_cell(arch: str, shape_name: str, mesh, rules, *, microbatch: int = 0,
               extra_notes: str = "", probe: bool = True, cfg=None):
    """Lower + compile one (arch × shape) on a mesh; return result record."""
    cfg = cfg if cfg is not None else get_config(arch)
    shape = SHAPES[shape_name]
    ok, skip = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skip", "reason": skip}

    chips = mesh.size
    pod_group = chips // mesh.shape["pod"] if "pod" in mesh.shape else 0
    mesh_desc = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)

    # 1) the PRODUCTION (scanned) program: the compile/fits proof
    compiled, model, t_lower, t_compile = _lower_one(
        cfg, shape, mesh, rules, microbatch=microbatch
    )
    mem = RL.memory_analysis_dict(compiled)
    n_total, n_active = param_counts(model, cfg)
    if shape.kind == "train":
        model_flops = RL.model_flops_train(
            n_active, shape.global_batch * shape.seq_len
        )
    elif shape.kind == "prefill":
        model_flops = RL.model_flops_decode(
            n_active, shape.global_batch * shape.seq_len
        )
    else:
        model_flops = RL.model_flops_decode(n_active, shape.global_batch)

    # 2) probe variants → extrapolated full-depth/full-seq cost (see header)
    probes = []
    if probe:
        plan, full = _probe_plan(cfg, shape)
        design, measures = [], []
        for v, vshape, row in plan:
            c, _, _, p_compile = _lower_one(v, vshape, mesh, rules,
                                            microbatch=microbatch)
            m = _measure(c, chips, pod_group)
            m["compile_s"] = round(p_compile, 2)
            design.append(row)
            measures.append(m)
            del c
        est = _extrapolate(measures, design, full)
        probes = [
            {"design": d, "measure": m} for d, m in zip(design, measures)
        ]
    else:
        est = _measure(compiled, chips, pod_group)
        est["extrapolated"] = False

    report = _roofline_from_est(
        arch, shape_name, mesh_desc, chips, pod_group, est, model_flops, mem,
        extra_notes,
    )
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_desc,
        "status": "ok",
        "chips": chips,
        "params_total": n_total,
        "params_active": n_active,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "cost_flops_per_device": report.flops_per_device,
        "cost_bytes_per_device": report.bytes_per_device,
        "probes": probes,
        "roofline": report.to_json(),
    }


def run_cells(archs, shapes, meshes, *, microbatch: int = 0, out_path: str | None = None,
              verbose: bool = True, rules_profile: str = "default",
              cfg_overrides: dict | None = None, probe: bool = True):
    results = []
    for mesh_kind in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
        rules = rules_for(mesh, rules_profile)
        with mesh:
            for arch in archs:
                for shape_name in shapes:
                    key = f"{arch} × {shape_name} × {mesh_kind}"
                    try:
                        cfg = get_config(arch)
                        if cfg_overrides:
                            cfg = cfg.with_(**cfg_overrides)
                        # roofline table is single-pod; multipod pass is the
                        # sharding-coherence proof → skip the probe compiles
                        rec = lower_cell(arch, shape_name, mesh, rules,
                                         microbatch=microbatch,
                                         probe=probe and (mesh_kind == "pod"),
                                         cfg=cfg)
                        rec["mesh_kind"] = mesh_kind
                        rec["rules_profile"] = rules_profile
                        if cfg_overrides:
                            rec["cfg_overrides"] = cfg_overrides
                        if verbose:
                            if rec["status"] == "skip":
                                print(f"[dryrun] SKIP {key}: {rec['reason']}")
                            else:
                                r = rec["roofline"]
                                print(
                                    f"[dryrun] OK   {key}: compile {rec['compile_s']}s "
                                    f"compute {RL.fmt_seconds(r['t_compute'])} "
                                    f"memory {RL.fmt_seconds(r['t_memory'])} "
                                    f"collective {RL.fmt_seconds(r['t_collective'])} "
                                    f"dominant={r['dominant']} MFU≤{r['mfu_bound']:.1%}"
                                )
                                ma = rec["memory_analysis"]
                                if ma:
                                    gb = (
                                        ma.get("argument_size_in_bytes", 0)
                                        + ma.get("output_size_in_bytes", 0)
                                        + ma.get("temp_size_in_bytes", 0)
                                    ) / 1e9
                                    print(f"         bytes/device {gb:.2f} GB "
                                          f"(args+out+temp; v5e HBM = 16 GB)")
                    except Exception as e:  # noqa: BLE001 — record, keep going
                        rec = {
                            "arch": arch, "shape": shape_name, "mesh_kind": mesh_kind,
                            "status": "error", "error": repr(e),
                            "traceback": traceback.format_exc(),
                        }
                        if verbose:
                            print(f"[dryrun] FAIL {key}: {e!r}")
                    results.append(rec)
                    if out_path:
                        with open(out_path, "w") as f:
                            json.dump(results, f, indent=1)
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", help="arch id (repeatable); default all")
    ap.add_argument("--shape", action="append", choices=sorted(SHAPES),
                    help="shape cell (repeatable); default all")
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"), default="both")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--out", default=None, help="JSON results path")
    ap.add_argument("--list", action="store_true")
    # hillclimb levers (EXPERIMENTS.md §Perf); defaults = paper-faithful
    ap.add_argument("--rules", choices=("default", "flat_dp", "sp", "serve"),
                    default="default", help="sharding rule profile")
    ap.add_argument("--causal-skip", action="store_true",
                    help="enable attn_causal_skip (skip masked KV chunks)")
    ap.add_argument("--remat", choices=("none", "full", "dots"), default=None,
                    help="override activation-checkpoint policy")
    ap.add_argument("--refit", metavar="JSON",
                    help="re-derive rooflines from stored probes (no compiles)")
    ap.add_argument("--no-probe", action="store_true",
                    help="production compile only (memory_analysis evidence; "
                         "roofline terms from the scanned program are "
                         "under-counted — use for fit checks, not §Roofline)")
    args = ap.parse_args(argv)

    if args.refit:
        return refit_results(args.refit)

    archs = args.arch or arch_names()
    shapes = args.shape or list(SHAPES)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    if args.list:
        for a in archs:
            cfg = get_config(a)
            for s in shapes:
                ok, why = cell_applicable(cfg, SHAPES[s])
                print(f"{a:<24}{s:<14}{'RUN' if ok else 'SKIP: ' + why}")
        return 0

    overrides = {}
    if args.causal_skip:
        overrides["attn_causal_skip"] = True
    if args.remat:
        overrides["remat"] = args.remat
    results = run_cells(archs, shapes, meshes, microbatch=args.microbatch,
                        out_path=args.out, rules_profile=args.rules,
                        cfg_overrides=overrides or None,
                        probe=not args.no_probe)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_err} error")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
