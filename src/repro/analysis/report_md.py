"""Generate EXPERIMENTS.md tables from dry-run result JSONs.

    PYTHONPATH=src python -m repro.analysis.report_md results/dryrun_baseline.json

Emits §Dry-run and §Roofline markdown tables (stdout) from the records
written by ``repro.launch.dryrun --out``.
"""
from __future__ import annotations

import json
import sys

from repro.analysis.roofline import fmt_seconds


def _gb(b: float) -> str:
    return f"{b/1e9:.2f}"


def dryrun_table(recs: list[dict]) -> str:
    """§Dry-run: compile proof + memory_analysis + collective schedule."""
    out = [
        "| arch | shape | mesh | status | compile s | bytes/device GB | "
        "collectives (count × kind) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skip":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r.get('mesh_kind','-')} | "
                f"SKIP ({r['reason'].split(':')[0]}) | – | – | – |"
            )
            continue
        if r["status"] == "error":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r.get('mesh_kind','-')} | "
                f"ERROR | – | – | {r['error'][:60]} |"
            )
            continue
        ma = r.get("memory_analysis", {})
        gb = (
            ma.get("argument_size_in_bytes", 0)
            + ma.get("output_size_in_bytes", 0)
            + ma.get("temp_size_in_bytes", 0)
        )
        rl = r["roofline"]
        colls = ", ".join(
            f"{int(v['count'])}×{k}" for k, v in sorted(rl["collective_ops"].items())
            if v["count"]
        ) or "none"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('mesh_kind','-')} | OK | "
            f"{r['compile_s']} | {_gb(gb)} | {colls} |"
        )
    return "\n".join(out)


def roofline_table(recs: list[dict], mesh_kind: str = "pod") -> str:
    """§Roofline: three terms + dominant + useful ratio + MFU bound."""
    out = [
        "| arch | shape | compute | memory (min…hlo) | collective | DCN | "
        "dominant (hlo / fused) | useful 6ND/HLO | MFU≤ (hlo / fused) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok" or r.get("mesh_kind") != mesh_kind:
            continue
        rl = r["roofline"]
        # fused view: memory at its lower bound (what XLA:TPU fusion pays)
        fused = {
            "compute": rl["t_compute"],
            "memory": rl.get("t_memory_min", 0.0),
            "collective": rl["t_collective"],
        }
        fdom = max(fused, key=fused.get)
        fstep = max(max(fused.values()), rl["t_dcn"])
        fmfu = rl["model_flops"] / (r["chips"] * 197e12 * fstep) if fstep else 0.0
        out.append(
            "| {arch} | {shape} | {c} | {mn}…{m} | {co} | {d} | "
            "**{dom}** / {fdom} | {u:.2f} | {mfu:.1%} / {fmfu:.1%} |".format(
                arch=r["arch"], shape=r["shape"],
                c=fmt_seconds(rl["t_compute"]),
                mn=fmt_seconds(rl.get("t_memory_min", 0.0)),
                m=fmt_seconds(rl["t_memory"]),
                co=fmt_seconds(rl["t_collective"]),
                d=fmt_seconds(rl["t_dcn"]),
                dom=rl["dominant"], fdom=fdom,
                u=rl["useful_ratio"], mfu=rl["mfu_bound"], fmfu=fmfu,
            )
        )
    return "\n".join(out)


def summary_counts(recs: list[dict]) -> str:
    ok = sum(r["status"] == "ok" for r in recs)
    skip = sum(r["status"] == "skip" for r in recs)
    err = sum(r["status"] == "error" for r in recs)
    return f"{ok} ok / {skip} skip / {err} error"


def main(argv=None) -> int:
    paths = (argv or sys.argv[1:]) or ["results/dryrun_baseline.json"]
    for p in paths:
        with open(p) as f:
            recs = json.load(f)
        print(f"## {p} — {summary_counts(recs)}\n")
        print("### Dry-run\n")
        print(dryrun_table(recs))
        print("\n### Roofline (single-pod; multipod records are the "
              "compile/sharding proof only — no probe extrapolation)\n")
        print(roofline_table(recs, "pod"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
