"""Static analysis tooling for the proxy patterns (ProxyLint)."""
