"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Three terms per (arch × shape × mesh), all in seconds:

- ``compute``    = HLO_FLOPs / (chips × peak_FLOP/s)
- ``memory``     = HLO_bytes / (chips × HBM_bw)
- ``collective`` = collective_bytes / (chips × link_bw)

``compiled.cost_analysis()`` supplies FLOPs and bytes-accessed for the SPMD
(per-device) module; collective bytes are NOT in cost_analysis, so we parse
the post-optimization HLO (``compiled.as_text()``) and sum *wire* bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, using ring-algorithm wire multipliers and the op's
``replica_groups`` size.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment; term formulas are used verbatim).
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

# -- hardware model (TPU v5e) -------------------------------------------------
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9       # bytes/s per chip
LINK_BW = 50e9       # bytes/s per ICI link
DCN_BW = 25e9        # bytes/s per host for cross-pod (pod axis) traffic

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# shape token, e.g. bf16[256,4096]{1,0} or f32[] — capture dtype + dims
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")
# explicit groups: replica_groups={{0,1,...},{...},...}
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
# iota v2 form: replica_groups=[num_groups,group_size]<=[...]
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        g = [t for t in m.group(1).split(",") if t.strip() != ""]
        return max(len(g), 1)
    return default


def _wire_multiplier(op: str, n: int) -> float:
    """Ring-algorithm bytes-on-wire per device, per *result* byte.

    Post-optimization HLO prints operands without shapes, so we account from
    the result shape: all-gather result is the gathered buffer (operand×n),
    reduce-scatter result is the shard (operand = result×n), all-reduce
    result == operand.
    """
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n       # reduce-scatter + all-gather phases
    if op == "all-gather":
        return (n - 1) / n             # each device receives (n-1)/n of result
    if op == "reduce-scatter":
        return float(n - 1)            # operand = n×result; wire = (n-1)×result
    if op == "all-to-all":
        return (n - 1) / n
    if op == "collective-permute":
        return 1.0
    return 1.0


def _operand_multiplier(op: str, n: int) -> float:
    """Result bytes → operand bytes (for the reported operand-size column)."""
    if op == "all-gather":
        return 1.0 / max(n, 1)
    if op == "reduce-scatter":
        return float(n)
    return 1.0


@dataclass
class CollectiveStats:
    """Per-op-kind operand + wire bytes (per device, one step)."""

    ops: dict = field(default_factory=dict)  # op -> {count, operand_bytes, wire_bytes}
    total_operand_bytes: int = 0
    total_wire_bytes: float = 0.0
    dcn_wire_bytes: float = 0.0  # share crossing the pod axis (group > pod size)


def parse_collectives(hlo_text: str, *, n_devices: int, pod_group: int = 0) -> CollectiveStats:
    """Sum operand sizes of every collective in post-optimization HLO.

    ``pod_group``: if nonzero, collectives whose replica-group size exceeds
    this (i.e. span pods) have their wire bytes also accounted as DCN bytes.
    """
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # match `<result-shape(s)> <op>(` — accounting from the RESULT shape
        # (operands print without shapes); -done ops skipped (the -start op
        # already carries the buffer).
        m = None
        for op in _COLLECTIVES:
            for tok in (f" {op}(", f" {op}-start("):
                idx = stripped.find(tok)
                if idx > 0:
                    m = (op, idx, tok)
                    break
            if m:
                break
        if not m:
            continue
        op, idx, tok = m
        lhs = stripped[:idx]
        if "=" not in lhs:
            continue
        lhs = lhs.split("=", 1)[1]  # result shape(s) between '=' and op name
        shapes = _SHAPE_RE.findall(lhs)
        if not shapes:
            continue
        # async -start ops return (operand, result, ...): take the last shape
        if tok.endswith("-start("):
            shapes = shapes[-1:]
        rb = sum(_shape_bytes(d, dims) for d, dims in shapes)
        if rb == 0:
            continue
        n = _group_size(stripped, n_devices)
        ob = int(rb * _operand_multiplier(op, n))
        wire = rb * _wire_multiplier(op, n)
        rec = st.ops.setdefault(op, {"count": 0, "operand_bytes": 0, "wire_bytes": 0.0})
        rec["count"] += 1
        rec["operand_bytes"] += ob
        rec["wire_bytes"] += wire
        st.total_operand_bytes += ob
        st.total_wire_bytes += wire
        if pod_group and n > pod_group:
            st.dcn_wire_bytes += wire
    return st


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw
    flops_per_device: float
    bytes_per_device: float
    collective_operand_bytes: int  # per device
    collective_wire_bytes: float   # per device, ring-adjusted
    collective_ops: dict
    hbm_bytes_per_device: float    # from memory_analysis (argument+output+temp)
    # terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    t_dcn: float
    dominant: str
    # diagnostic: HBM-traffic LOWER bound (working set touched once).  The
    # primary t_memory uses cost_analysis "bytes accessed", which counts
    # every unfused elementwise operand — an upper bound that XLA:TPU's much
    # more aggressive fusion would not pay.  True HBM time lies in
    # [t_memory_min, t_memory].
    t_memory_min: float
    # usefulness
    model_flops: float             # 6·N(_active)·D global
    useful_ratio: float            # model_flops / global HLO flops
    step_time: float               # max of terms (no-overlap lower bound)
    mfu_bound: float               # model_flops / (chips·peak·step_time)
    notes: str = ""

    def to_json(self) -> dict:
        return asdict(self)


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_desc: str,
    chips: int,
    cost: dict,
    hlo_text: str,
    model_flops: float,
    memory_stats: dict | None = None,
    pod_group: int = 0,
    notes: str = "",
) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", cost.get("bytes_accessed", 0.0)))
    coll = parse_collectives(hlo_text, n_devices=chips, pod_group=pod_group)

    # terms per assignment formulas: global quantity / (chips × rate).
    # cost_analysis of the SPMD module is per-device, so global = ×chips and
    # the terms reduce to per-device work / per-chip rate.
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_collective = coll.total_wire_bytes / LINK_BW
    t_dcn = coll.dcn_wire_bytes / DCN_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    dominant = max(terms, key=terms.get) if any(terms.values()) else "compute"
    step_time = max(max(terms.values()), t_dcn) if any(terms.values()) else 0.0

    global_flops = flops * chips
    useful = model_flops / global_flops if global_flops else 0.0
    mfu = model_flops / (chips * PEAK_FLOPS * step_time) if step_time else 0.0

    hbm = 0.0
    if memory_stats:
        hbm = float(
            memory_stats.get("argument_size_in_bytes", 0)
            + memory_stats.get("output_size_in_bytes", 0)
            + memory_stats.get("temp_size_in_bytes", 0)
        )
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_desc,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        collective_operand_bytes=coll.total_operand_bytes,
        collective_wire_bytes=coll.total_wire_bytes,
        collective_ops=coll.ops,
        hbm_bytes_per_device=hbm,
        t_compute=t_compute,
        t_memory=t_memory,
        t_collective=t_collective,
        t_dcn=t_dcn,
        dominant=dominant,
        t_memory_min=hbm / HBM_BW,
        model_flops=model_flops,
        useful_ratio=useful,
        step_time=step_time,
        mfu_bound=mfu,
        notes=notes,
    )


def memory_analysis_dict(compiled) -> dict:
    """compiled.memory_analysis() → plain dict (backend-portable)."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def model_flops_train(n_params_active: float, n_tokens: float) -> float:
    """6·N·D — fwd 2ND + bwd 4ND."""
    return 6.0 * n_params_active * n_tokens


def model_flops_decode(n_params_active: float, n_tokens: float) -> float:
    """2·N per generated token (fwd only)."""
    return 2.0 * n_params_active * n_tokens


def fmt_seconds(s: float) -> str:
    if s == 0:
        return "0"
    if s < 1e-3:
        return f"{s*1e6:.1f}µs"
    if s < 1:
        return f"{s*1e3:.2f}ms"
    return f"{s:.3f}s"


def report_table(reports: list[RooflineReport]) -> str:
    hdr = (
        f"{'arch':<22}{'shape':<13}{'mesh':<10}{'compute':>10}{'mem_min':>10}"
        f"{'memory':>10}{'collect':>10}{'dcn':>9}{'dominant':>11}"
        f"{'useful':>8}{'MFU≤':>7}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        lines.append(
            f"{r.arch:<22}{r.shape:<13}{r.mesh:<10}"
            f"{fmt_seconds(r.t_compute):>10}{fmt_seconds(r.t_memory_min):>10}"
            f"{fmt_seconds(r.t_memory):>10}"
            f"{fmt_seconds(r.t_collective):>10}{fmt_seconds(r.t_dcn):>9}"
            f"{r.dominant:>11}{r.useful_ratio:>8.2f}{r.mfu_bound:>7.1%}"
        )
    return "\n".join(lines)


def save_reports(reports: list[RooflineReport], path: str) -> None:
    with open(path, "w") as f:
        json.dump([r.to_json() for r in reports], f, indent=1)


def load_reports(path: str) -> list[RooflineReport]:
    with open(path) as f:
        return [RooflineReport(**d) for d in json.load(f)]
