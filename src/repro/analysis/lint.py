"""ProxyLint — static lint pass for proxy-lifecycle rules.

The proxy patterns come with contracts the type system cannot see:
notification-driven paths must not poll, mutable keys must be read
fresh, donated jit buffers die at the call, and every Owned mint needs
a reachable free.  ProxyLint walks the AST of ``src/``,
``benchmarks/``, and ``examples/`` and enforces them mechanically.

Run it::

    python scripts/proxy_lint.py                 # human output, exit != 0 on hits
    python scripts/proxy_lint.py --json          # machine output
    python scripts/proxy_lint.py src/repro/serve # explicit paths
    python scripts/proxy_lint.py --select no-sleep-poll,swallowed-error
    python scripts/proxy_lint.py --list-rules

Rules
-----
``no-sleep-poll``
    ``time.sleep`` inside any loop, anywhere — and *any* ``time.sleep``
    at all in the notification-driven hot-path modules (serve engine,
    streaming, futures, store, connectors, executor, serve client).
    Blocking must ride a condition variable or the connector
    ``wait_for`` protocol; documented backoff sites carry a pragma.

``connector-wait-protocol``
    A ``while`` loop that waits for channel state — a negated existence
    test (``while not store.exists(k)`` / ``while not f.done()``), or a
    positive one with a sleep in the body — is a busy-wait; route it
    through ``connectors.wait_for`` / ``wait_for_any`` (or
    ``Store.wait_for``), which use native notification waits (inotify,
    broker conditions).  Positive probes that walk a chain of cells
    (``while store.exists(next_cell)``) terminate on their own and are
    not flagged.

``mutable-key-fresh``
    In cross-process modules (``dist/``, ``data/``, ``ckpt/``): a key
    expression that is ever written with a plain overwrite
    (``store.put(obj, key=K)``) is *mutable*; reading the same key
    expression via ``.get(K)`` / ``.resolve(K)`` without
    ``fresh=True`` (or ``writable=True``) can serve a stale cached
    value — cache invalidation is in-process only.  Write-once cells
    (``put_if_absent``) are exempt.

``donated-reuse``
    For ``f = jax.jit(fn, donate_argnums=(i, ...))``: an argument
    passed at a donated position is dead after the call — its buffer
    is aliased to the output.  The rule flags a later read of the same
    name/attribute in the function unless it is reassigned first
    (``self._cache, logits = self._decode(self.params, self._cache, …)``
    is the sanctioned shape).

``owned-lifetime``
    Every ownership mint (``owned_proxy(...)``, ``pages.allocate(...)``)
    must have a *reachable* free: the mint's result must not be
    discarded, and a module that mints owners must reference a
    ``free``/``free_sequence``/``Lifetime`` somewhere (returning the
    mint — transferring ownership to the caller — satisfies the rule
    via the caller's module).  The discarded-result check applies only
    to ``owned_proxy`` mints: ``allocate(...)`` mutates the pool it is
    called on, so a bare ``pages.allocate(n)`` statement is a
    legitimate reservation, not a dropped owner.

``swallowed-error``
    Bare ``except:``, and broad ``except Exception/BaseException``
    handlers whose whole body is ``pass``/``continue``: in puller and
    watch threads these turn real failures into silent hangs.
    ``__del__`` bodies are exempt (exceptions there never propagate
    anyway).

Suppression
-----------
End-of-line pragma, one or more comma-separated rules::

    time.sleep(delay)  # proxylint: disable=no-sleep-poll
    except Exception:  # proxylint: disable=swallowed-error,no-sleep-poll

A pragma on the line where the violation is *reported* suppresses it.
There is deliberately no file-level disable: every allowlisted site is
visible and justified inline.

ProxySan (the runtime half)
---------------------------
Static rules can't see dynamic misuse (double-free across call chains,
stale cross-store reads).  For that, run the suite under the runtime
sanitizer::

    REPRO_PROXYSAN=1 PYTHONPATH=src python -m pytest -q
    REPRO_PROXYSAN=1 PYTHONPATH=src python -m repro.launch.serve ...

or opt in per store with ``Store(name, sanitize=True)`` — see
:mod:`repro.core.sanitize`.  ``scripts/check.sh`` runs both layers.
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field

# Modules where *any* time.sleep is a violation (notification-driven
# contracts; see PR 3's wait_for protocol and PR 5's serve loop).
HOT_PATH_SUFFIXES = (
    "core/streaming.py",
    "core/futures.py",
    "core/store.py",
    "core/connectors.py",
    "core/connectors_net.py",
    "core/multi.py",
    "core/executor.py",
    "core/proxy.py",
    "serve/engine.py",
    "serve/client.py",
    "serve/router.py",
)

# Modules whose stores are read across processes: the mutable-key rule
# applies (elsewhere a same-process overwrite invalidates the cache).
CROSS_PROCESS_SUFFIXES = (
    "dist/",
    "data/",
    "ckpt/",
)

_PRAGMA = re.compile(r"#\s*proxylint:\s*disable=([\w\-, ]+)")


@dataclass
class LintViolation:
    path: str
    line: int
    col: int
    rule: str
    message: str
    hint: str = ""

    def to_dict(self) -> dict:
        return {
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "rule": self.rule,
            "message": self.message,
            "hint": self.hint,
        }

    def render(self) -> str:
        s = f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"
        if self.hint:
            s += f"\n    hint: {self.hint}"
        return s


@dataclass
class FileContext:
    path: str  # as given (display)
    relpath: str  # posix, repo-ish relative — suffix matching
    src: str
    tree: ast.AST
    disabled: dict[int, set] = field(default_factory=dict)  # line → rules
    parents: dict = field(default_factory=dict)  # node → parent

    @classmethod
    def load(cls, path: str) -> "FileContext | None":
        try:
            with open(path, "r", encoding="utf-8") as f:
                src = f.read()
            tree = ast.parse(src, filename=path)
        except (OSError, SyntaxError):
            return None
        disabled: dict[int, set] = {}
        for i, line in enumerate(src.splitlines(), start=1):
            m = _PRAGMA.search(line)
            if m:
                disabled[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
        ctx = cls(
            path=path,
            relpath=os.path.abspath(path).replace(os.sep, "/"),
            src=src,
            tree=tree,
            disabled=disabled,
        )
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                ctx.parents[child] = node
        return ctx

    def suppressed(self, line: int, rule: str) -> bool:
        return rule in self.disabled.get(line, ())

    def is_hot_path(self) -> bool:
        return self.relpath.endswith(HOT_PATH_SUFFIXES)

    def is_cross_process(self) -> bool:
        return any(f"/{s}" in self.relpath for s in CROSS_PROCESS_SUFFIXES)

    def in_loop(self, node: ast.AST) -> bool:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.While, ast.For, ast.AsyncFor)):
                return True
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                return False  # a nested def breaks the loop scope
            cur = self.parents.get(cur)
        return False

    def enclosing_function(self, node: ast.AST):
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parents.get(cur)
        return None

    def in_del(self, node: ast.AST) -> bool:
        cur = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur.name == "__del__"
            cur = self.parents.get(cur)
        return False


def _dump(node: ast.AST) -> str:
    return ast.dump(node, annotate_fields=False)


def _terminal_name(node: ast.AST) -> str | None:
    """The last identifier of a Name/Attribute chain (``self.store`` → store)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


class Rule:
    name: str = ""
    description: str = ""
    hint: str = ""

    def check(self, ctx: FileContext) -> list[LintViolation]:  # pragma: no cover
        raise NotImplementedError

    def _v(self, ctx: FileContext, node: ast.AST, message: str) -> LintViolation:
        return LintViolation(
            path=ctx.path,
            line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0),
            rule=self.name,
            message=message,
            hint=self.hint,
        )


class NoSleepPoll(Rule):
    name = "no-sleep-poll"
    description = (
        "time.sleep in a loop (polling), or anywhere in a "
        "notification-driven hot-path module"
    )
    hint = (
        "block on a condition variable or the connector wait_for protocol; "
        "a documented bounded backoff may carry "
        "'# proxylint: disable=no-sleep-poll'"
    )

    def _is_sleep(self, call: ast.Call, ctx: FileContext) -> bool:
        f = call.func
        if isinstance(f, ast.Attribute) and f.attr == "sleep":
            base = f.value
            return isinstance(base, ast.Name) and base.id == "time"
        if isinstance(f, ast.Name) and f.id == "sleep":
            return "from time import" in ctx.src and "sleep" in ctx.src
        return False

    def check(self, ctx: FileContext) -> list[LintViolation]:
        out = []
        hot = ctx.is_hot_path()
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call) and self._is_sleep(node, ctx)):
                continue
            if hot:
                out.append(self._v(
                    ctx, node,
                    "time.sleep in a notification-driven hot-path module",
                ))
            elif ctx.in_loop(node):
                out.append(self._v(
                    ctx, node, "time.sleep inside a loop (sleep-polling)",
                ))
        return out


class ConnectorWaitProtocol(Rule):
    name = "connector-wait-protocol"
    description = (
        "while-loop condition polling channel state (.exists()/.done()) "
        "instead of the connector wait_for protocol"
    )
    hint = (
        "use connectors.wait_for/wait_for_any (or Store.wait_for / "
        "future.result()): native notification waits, no poll interval"
    )

    @staticmethod
    def _negated(ctx: FileContext, call: ast.Call) -> bool:
        cur = ctx.parents.get(call)
        while cur is not None and not isinstance(cur, ast.While):
            if isinstance(cur, ast.UnaryOp) and isinstance(cur.op, ast.Not):
                return True
            cur = ctx.parents.get(cur)
        return False

    @staticmethod
    def _body_sleeps(loop: ast.While) -> bool:
        for sub in ast.walk(loop):
            if (
                isinstance(sub, ast.Call)
                and isinstance(sub.func, ast.Attribute)
                and sub.func.attr == "sleep"
            ):
                return True
        return False

    def check(self, ctx: FileContext) -> list[LintViolation]:
        out = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.While):
                continue
            for sub in ast.walk(node.test):
                if not (
                    isinstance(sub, ast.Call)
                    and isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in ("exists", "done")
                ):
                    continue
                # waiting for appearance (negated test), or a positive
                # probe that sleeps between re-checks, is a busy-wait;
                # a positive probe walking a chain terminates on its own
                if self._negated(ctx, sub) or self._body_sleeps(node):
                    out.append(self._v(
                        ctx, sub,
                        f"busy-wait on .{sub.func.attr}() in a while "
                        "condition",
                    ))
        return out


class MutableKeyFresh(Rule):
    name = "mutable-key-fresh"
    description = (
        "in cross-process modules, reading a key that is elsewhere "
        "overwritten in place (store.put(obj, key=K)) without fresh=True"
    )
    hint = (
        "read mutable cells with store.get(K, fresh=True) / "
        "resolve(K, fresh=True) — the resolve cache is invalidated "
        "in-process only; write-once cells should use put_if_absent"
    )
    _READS = ("get", "resolve")

    @staticmethod
    def _is_store_recv(node: ast.AST) -> bool:
        t = _terminal_name(node)
        return t is not None and "store" in t.lower()

    def check(self, ctx: FileContext) -> list[LintViolation]:
        if not ctx.is_cross_process():
            return []
        mutable: set[str] = set()
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)):
                continue
            if node.func.attr != "put" or not self._is_store_recv(node.func.value):
                continue
            key_expr = None
            for kw in node.keywords:
                if kw.arg == "key":
                    key_expr = kw.value
            if key_expr is None and len(node.args) >= 2:
                key_expr = node.args[1]
            if key_expr is not None and not isinstance(key_expr, ast.Constant):
                mutable.add(_dump(key_expr))
            elif isinstance(key_expr, ast.Constant):
                mutable.add(_dump(key_expr))
        if not mutable:
            return []
        out = []
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)):
                continue
            if node.func.attr not in self._READS:
                continue
            if not self._is_store_recv(node.func.value):
                continue
            if not node.args:
                continue
            if _dump(node.args[0]) not in mutable:
                continue
            safe = any(
                kw.arg in ("fresh", "writable")
                and isinstance(kw.value, ast.Constant)
                and kw.value.value
                for kw in node.keywords
            )
            if not safe:
                out.append(self._v(
                    ctx, node,
                    f"read of mutable key (overwritten via put(key=...) in "
                    f"this module) without fresh=True",
                ))
        return out


class DonatedReuse(Rule):
    name = "donated-reuse"
    description = (
        "argument at a donated jit position referenced after the call "
        "(its buffer is aliased to the output)"
    )
    hint = (
        "reassign the donated name from the call result "
        "(`x, out = jitted(params, x, ...)`) before any later use"
    )

    @staticmethod
    def _donated_positions(call: ast.Call) -> list[int] | None:
        """donate_argnums of a ``jax.jit(...)`` call, else None."""
        f = call.func
        is_jit = (isinstance(f, ast.Attribute) and f.attr == "jit") or (
            isinstance(f, ast.Name) and f.id == "jit"
        )
        if not is_jit:
            return None
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                v = kw.value
                if isinstance(v, ast.Constant) and isinstance(v.value, int):
                    return [v.value]
                if isinstance(v, (ast.Tuple, ast.List)):
                    pos = []
                    for e in v.elts:
                        if isinstance(e, ast.Constant) and isinstance(e.value, int):
                            pos.append(e.value)
                    return pos
        return None

    def check(self, ctx: FileContext) -> list[LintViolation]:
        # name/attr (dump) of the jitted callable → donated positions
        donated: dict[str, list[int]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                pos = self._donated_positions(node.value)
                if pos:
                    for t in node.targets:
                        donated[_dump_no_ctx(t)] = pos
        if not donated:
            return []
        out = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            pos = donated.get(_dump_no_ctx(node.func))
            if not pos:
                continue
            fn = ctx.enclosing_function(node)
            if fn is None:
                continue
            # the statement containing the call: a donated arg reassigned
            # *by that statement* (`x, out = jitted(params, x)`) is the
            # sanctioned shape
            stmt = ctx.parents.get(node)
            while stmt is not None and not isinstance(stmt, ast.stmt):
                stmt = ctx.parents.get(stmt)
            if stmt is None:
                continue
            for p in pos:
                if p >= len(node.args):
                    continue
                arg = node.args[p]
                if not isinstance(arg, (ast.Name, ast.Attribute)):
                    continue
                key = _dump_no_ctx(arg)
                reassigned_here = isinstance(stmt, ast.Assign) and any(
                    _dump_no_ctx(sub) == key
                    for t in stmt.targets
                    for sub in ast.walk(t)
                    if isinstance(sub, (ast.Name, ast.Attribute))
                )
                if reassigned_here:
                    continue
                # occurrences of the donated expr strictly after the call
                # statement, in textual order
                after = (stmt.end_lineno, stmt.end_col_offset)
                occ = [
                    sub for sub in ast.walk(fn)
                    if isinstance(sub, (ast.Name, ast.Attribute))
                    and _dump_no_ctx(sub) == key
                    and (sub.lineno, sub.col_offset) > after
                ]
                occ.sort(key=lambda n: (n.lineno, n.col_offset))
                for sub in occ:
                    if isinstance(sub.ctx, ast.Store):
                        break  # reassigned first: later reads are the new value
                    if isinstance(sub.ctx, ast.Load):
                        out.append(self._v(
                            ctx, sub,
                            f"donated jit argument "
                            f"{ast.unparse(arg)!r} referenced after the "
                            f"call at line {node.lineno}",
                        ))
                        break
        return out


def _dump_no_ctx(node: ast.AST) -> str:
    """Structural dump of a Name/Attribute chain ignoring Load/Store ctx."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return _dump_no_ctx(node.value) + "." + node.attr
    return _dump(node)


class OwnedLifetime(Rule):
    name = "owned-lifetime"
    description = (
        "ownership mint (owned_proxy / PageTable.allocate) without a "
        "reachable free/lifetime attachment"
    )
    hint = (
        "keep the owner and free() it (or free_sequence / attach it to a "
        "Lifetime); returning the mint transfers ownership to the caller"
    )
    _FREE_TOKENS = re.compile(
        r"\bfree\b|\bfree_sequence\b|Lifetime|lifetime|add_proxy|\bclose\b"
    )

    @staticmethod
    def _is_mint(call: ast.Call) -> bool:
        f = call.func
        if isinstance(f, ast.Name) and f.id == "owned_proxy":
            return True
        if isinstance(f, ast.Attribute):
            if f.attr == "owned_proxy":
                return True
            if f.attr == "allocate":
                t = _terminal_name(f.value)
                return t is not None and "page" in t.lower()
        return False

    def check(self, ctx: FileContext) -> list[LintViolation]:
        mints = [
            node for node in ast.walk(ctx.tree)
            if isinstance(node, ast.Call) and self._is_mint(node)
        ]
        if not mints:
            return []
        out = []
        for node in mints:
            parent = ctx.parents.get(node)
            f = node.func
            # The discard check applies to owned_proxy mints only: a
            # discarded PageTable.allocate is fine — the table registers
            # the owner internally and free_sequence reclaims it.
            is_raw_mint = (isinstance(f, ast.Name) and f.id == "owned_proxy") or (
                isinstance(f, ast.Attribute) and f.attr == "owned_proxy"
            )
            if is_raw_mint and isinstance(parent, ast.Expr):
                out.append(self._v(
                    ctx, node,
                    "ownership mint discarded: the owner reference is the "
                    "only handle that can ever free the target",
                ))
        if not self._FREE_TOKENS.search(ctx.src):
            for node in mints:
                out.append(self._v(
                    ctx, node,
                    "module mints owners but never references free/"
                    "free_sequence/Lifetime — the targets can never be "
                    "reclaimed",
                ))
        return out


class SwallowedError(Rule):
    name = "swallowed-error"
    description = (
        "bare except, or broad except Exception/BaseException whose body "
        "only passes — silent failure in puller/watch threads"
    )
    hint = (
        "catch the specific exception, or record/propagate the error "
        "(state['error'] = e; notify) so the failure is loud"
    )

    @staticmethod
    def _is_broad(handler: ast.ExceptHandler) -> bool:
        t = handler.type
        if t is None:
            return True
        if isinstance(t, ast.Name) and t.id in ("Exception", "BaseException"):
            return True
        return False

    @staticmethod
    def _body_swallows(handler: ast.ExceptHandler) -> bool:
        return all(isinstance(s, (ast.Pass, ast.Continue)) for s in handler.body)

    def check(self, ctx: FileContext) -> list[LintViolation]:
        out = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if ctx.in_del(node):
                continue  # __del__ exceptions never propagate anyway
            if node.type is None:
                out.append(self._v(
                    ctx, node, "bare except: catches SystemExit/KeyboardInterrupt "
                    "and hides the failure",
                ))
            elif self._is_broad(node) and self._body_swallows(node):
                out.append(self._v(
                    ctx, node,
                    "broad except whose body only passes: the error "
                    "vanishes silently",
                ))
        return out


RULES: dict[str, Rule] = {
    r.name: r
    for r in (
        NoSleepPoll(),
        ConnectorWaitProtocol(),
        MutableKeyFresh(),
        DonatedReuse(),
        OwnedLifetime(),
        SwallowedError(),
    )
}

DEFAULT_PATHS = ("src", "benchmarks", "examples")


def iter_py_files(paths) -> list[str]:
    files = []
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                files.append(p)
        else:
            for root, dirs, names in os.walk(p):
                dirs[:] = [d for d in dirs if d != "__pycache__"]
                files.extend(
                    os.path.join(root, n) for n in sorted(names)
                    if n.endswith(".py")
                )
    return files


def lint_paths(paths, *, select: set | None = None) -> list[LintViolation]:
    """Run the (selected) rules over every .py file under ``paths``."""
    rules = [r for n, r in RULES.items() if select is None or n in select]
    out: list[LintViolation] = []
    for path in iter_py_files(paths):
        ctx = FileContext.load(path)
        if ctx is None:
            continue
        for rule in rules:
            for v in rule.check(ctx):
                if not ctx.suppressed(v.line, v.rule):
                    out.append(v)
    out.sort(key=lambda v: (v.path, v.line, v.col, v.rule))
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="proxy_lint",
        description="static proxy-lifecycle lint pass (see repro.analysis.lint)",
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to lint (default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule names to run (default: all)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, rule in RULES.items():
            print(f"{name}: {rule.description}")
        return 0

    paths = args.paths or [p for p in DEFAULT_PATHS if os.path.exists(p)]
    select = None
    if args.select:
        select = {s.strip() for s in args.select.split(",") if s.strip()}
        unknown = select - set(RULES)
        if unknown:
            print(f"unknown rule(s): {', '.join(sorted(unknown))}")
            return 2
    violations = lint_paths(paths, select=select)
    if args.as_json:
        print(json.dumps(
            {"violations": [v.to_dict() for v in violations],
             "count": len(violations)},
            indent=2,
        ))
    else:
        for v in violations:
            print(v.render())
        n_files = len(iter_py_files(paths))
        print(f"proxy_lint: {len(violations)} violation(s) in {n_files} file(s)")
    return 1 if violations else 0
