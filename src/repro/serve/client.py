"""Streaming serve client: assembles token deltas back into completions.

The response topic carries two event kinds (see ServeEngine.run):

- ``kind="delta"`` — metadata-only (``StreamProducer.send_meta``): one
  generated token per decode step.  No store payload; the broker event is
  the whole message, so first-token latency is one decode step + one event
  hop, not a full generation.
- ``kind="done"``  — the completion record (tokens, latency, ttft) as bulk
  via proxy; resolving it is the only store round-trip per request.
- ``kind="error"`` — admission rejection (metadata-only).

:class:`ServeClient` consumes the topic with ``next_with_metadata`` and
keeps per-request assembly state; it is the measurement point for the
streamed-vs-complete latency claims (BENCH_serve's ``ttft_speedup``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.proxy import extract
from repro.core.streaming import StreamConsumer


@dataclass
class StreamedResult:
    req_id: str
    stream_tokens: list[int] = field(default_factory=list)
    first_delta_at: float | None = None  # perf_counter of first token delta
    done_at: float | None = None
    result: dict | None = None  # resolved completion bulk
    error: str | None = None

    @property
    def done(self) -> bool:
        return self.done_at is not None or self.error is not None


class ServeClient:
    """Client-side assembler for a serve response topic.

    ``collect(n)`` iterates the topic until ``n`` requests have completed
    (or the topic closes), recording per-request delta order and timing.
    ``on_done(req_id, result)`` fires as completions land — backpressure
    hooks (the launch driver's admission window) attach here.
    """

    def __init__(self, consumer: StreamConsumer, *, on_done=None, on_delta=None):
        self.consumer = consumer
        self.on_done = on_done
        self.on_delta = on_delta
        self.results: dict[str, StreamedResult] = {}
        self.out_of_order: list[tuple[str, int, int]] = []  # (req, got, want)
        self.rejections: list[tuple[str, str]] = []  # duplicate/late errors
        self.ignored_events: list[dict] = []  # unknown kinds, heartbeats
        self.closed = False

    def _rec(self, req_id: str) -> StreamedResult:
        rec = self.results.get(req_id)
        if rec is None:
            rec = self.results[req_id] = StreamedResult(req_id)
        return rec

    def _handle(self, proxy, meta) -> StreamedResult | None:
        """Apply one event; returns the record when it just completed.

        Unknown event kinds (a future heartbeat, someone else's send_meta)
        are counted and ignored, never fatal; an ``error`` for a req_id
        that is already streaming or done is a *rejected duplicate* — it
        lands in ``rejections`` and must not clobber the live record.
        """
        kind = meta.get("kind")
        req_id = meta.get("req_id")
        if (
            req_id is None
            or kind not in ("delta", "error", "done")
            or (kind == "done" and proxy is None)  # done must carry bulk
        ):
            self.ignored_events.append(dict(meta))
            return None
        rec = self._rec(req_id)
        if kind == "delta":
            if rec.first_delta_at is None:
                rec.first_delta_at = time.perf_counter()
            if meta["index"] != len(rec.stream_tokens):
                self.out_of_order.append(
                    (rec.req_id, meta["index"], len(rec.stream_tokens))
                )
            rec.stream_tokens.append(meta["token"])
            if self.on_delta is not None:
                self.on_delta(rec.req_id, meta["token"], meta["index"])
            return None
        if rec.done:  # duplicate error/done for a finished record
            self.rejections.append((req_id, meta.get("error", kind)))
            return None
        if kind == "error":
            if rec.stream_tokens:  # the live request streams on; the
                # rejected duplicate is the one being refused
                self.rejections.append((req_id, meta.get("error", "rejected")))
                return None
            rec.error = meta.get("error", "rejected")
        else:  # "done": the one bulk resolve per request
            rec.result = extract(proxy)
            rec.done_at = time.perf_counter()
        if self.on_done is not None:
            self.on_done(rec.req_id, rec)
        return rec

    def collect(
        self,
        n: int | None = None,
        *,
        timeout: float | None = None,
        deadline: float | None = None,
    ) -> dict[str, StreamedResult]:
        """Consume events until ``n`` completions (or the topic closes when
        ``n`` is None).  ``timeout`` bounds each event wait; ``deadline``
        bounds the whole call — against a dead engine that never publishes
        again, the client surfaces ``TimeoutError`` naming the incomplete
        req_ids instead of blocking forever in the consumer wait."""
        deadline_t = None if deadline is None else time.monotonic() + deadline
        done = sum(1 for r in self.results.values() if r.done)
        while n is None or done < n:
            wait = timeout
            if deadline_t is not None:
                remaining = deadline_t - time.monotonic()
                wait = remaining if wait is None else min(wait, remaining)
                wait = max(wait, 0.0)
            try:
                if wait is None:
                    proxy, meta = self.consumer.next_with_metadata()
                else:
                    proxy, meta = self.consumer.next_with_metadata(timeout=wait)
            except TimeoutError:
                if deadline_t is not None and time.monotonic() >= deadline_t:
                    incomplete = sorted(
                        r for r, rec in self.results.items() if not rec.done
                    )
                    raise TimeoutError(
                        f"serve client deadline ({deadline:g}s) expired; "
                        f"incomplete req_ids: {incomplete}"
                    ) from None
                raise  # caller's per-event timeout contract, unchanged
            except StopIteration:
                self.closed = True
                break
            if self._handle(proxy, meta) is not None:
                done += 1
        return self.results

    # -- derived metrics -----------------------------------------------------
    def ttft_s(self, sent_at: dict[str, float]) -> dict[str, float]:
        """Per-request time-to-first-token against caller-recorded send
        times (same-process ``perf_counter`` values)."""
        return {
            r: rec.first_delta_at - sent_at[r]
            for r, rec in self.results.items()
            if rec.first_delta_at is not None and r in sent_at
        }

    def completion_s(self, sent_at: dict[str, float]) -> dict[str, float]:
        return {
            r: rec.done_at - sent_at[r]
            for r, rec in self.results.items()
            if rec.done_at is not None and r in sent_at
        }
