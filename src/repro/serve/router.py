"""Serve fleet router: front-end fan-out over N engines, with failover.

The router sits between one client-facing request topic and N
:class:`~repro.serve.engine.ServeEngine` processes, speaking *metadata
only* — it never resolves a proxy.  Request events are forwarded verbatim
(same store key, same connector) to a per-engine request topic, and each
engine's response topic is merged back onto the one client response topic,
so clients and engines both run the unmodified serve protocol.

Contract
--------
- **Routing** is least-loaded: engines publish ``pages_available()`` to a
  control store under ``{load_prefix}{name}`` (the ``ServeEngine``
  ``on_load_change`` hook); the router reads those cells ``fresh`` and
  ties break toward the fewest in-flight assignments.
- **Liveness** rides a :class:`~repro.dist.lease.LeaseService`: engines
  register and renew under their fleet name; the router's watch thread
  blocks in ``lease.watch`` and treats a lease expiry as engine death.
- **Failover** re-publishes every non-terminal request assigned to a dead
  engine to a survivor (the original request event is kept verbatim, so
  the survivor resolves the *same* prompt bulk — fleet clients publish
  prompts with ``evict_on_resolve=False`` for exactly this reason).
- **Exactly-once** client delivery is enforced here, not at the engines:
  - deltas are forwarded only when ``index`` equals the per-request
    forwarded count, so a redispatched request's replayed prefix (greedy
    decode is deterministic — the replayed tokens are bit-identical) is
    dropped and the client sees one gapless stream;
  - the first terminal event (``done``/``error``) per request wins; later
    ones count as ``duplicate_dones`` and are dropped.  Engines in fleet
    mode commit completions with ``StreamProducer.send_committed`` at the
    deterministic key ``{done_commit_prefix}{req_id}`` (put-if-absent), so
    twin completions of a redispatched request share ONE payload cell and
    the client's single ``evict_on_resolve`` resolve reclaims it once.
- **Shutdown**: when the intake topic closes and every request is
  terminal, the router closes each live engine's request topic; when every
  engine has closed its response topic (or died), it closes the client
  response topic and :meth:`wait` returns.

Threads: one intake, one forwarder per engine, one lease watcher; all
state transitions and response-topic publishes happen under one lock, so
the response log order matches the dedup decisions exactly.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

from repro.core.streaming import _END, _load_event, publish_event


@dataclass
class _ReqState:
    """Router-side view of one in-flight request."""

    event: dict  # the original request event, re-publishable verbatim
    engine: str  # current assignee
    terminal: bool = False  # a done/error has been forwarded
    forwarded: int = 0  # deltas forwarded (== next expected index)


class Router:
    """Fan requests across engines; merge responses exactly-once.

    Parameters
    ----------
    engines:
        Fleet member names; also the lease worker names and the suffixes
        of the per-engine topics (``{request_topic_prefix}{name}`` in,
        ``responses-{name}`` out via ``make_engine_subscriber``).
    subscriber:
        Broker subscriber on the client-facing request topic.
    publisher:
        Broker publisher used for every router output (per-engine request
        topics and the merged client response topic).
    make_engine_subscriber:
        ``name -> Subscriber`` on that engine's response topic; called in
        the forwarder thread so subprocess log tails attach lazily.
    lease:
        :class:`~repro.dist.lease.LeaseService` the engines renew under;
        ``None`` disables the watch thread (no failover — tests only).
    control_store:
        Store carrying the per-engine load cells (mutable keys, read
        ``fresh``).
    """

    def __init__(
        self,
        engines,
        *,
        subscriber,
        publisher,
        make_engine_subscriber,
        lease=None,
        control_store=None,
        load_prefix: str = "load-",
        request_topic_prefix: str = "requests-",
        response_topic: str = "responses",
        tick: float = 0.25,
    ):
        self.engines = list(engines)
        self.subscriber = subscriber
        self.publisher = publisher
        self.make_engine_subscriber = make_engine_subscriber
        self.lease = lease
        self.control_store = control_store
        self.load_prefix = load_prefix
        self.request_topic_prefix = request_topic_prefix
        self.response_topic = response_topic
        self.tick = tick

        self._lock = threading.RLock()
        self._state: dict[str, _ReqState] = {}
        self._dead: set[str] = set()
        self._engine_closed: set[str] = set()
        self._intake_closed = False
        self._shutdown_sent = False
        self._responses_closed = False
        self._stop_evt = threading.Event()
        self._done_evt = threading.Event()
        # per-engine forwarder gates: cleared = paused (test hook for the
        # "done published but not yet read" chaos window)
        self._gates = {n: threading.Event() for n in self.engines}
        for g in self._gates.values():
            g.set()
        self._threads: list[threading.Thread] = []
        self.metrics = {
            "requests_routed": 0,
            "deltas_forwarded": 0,
            "dones_forwarded": 0,
            "dropped_stale_deltas": 0,
            "duplicate_dones": 0,
            "duplicate_requests": 0,
            "unroutable_requests": 0,
            "redispatches": 0,
            "engine_deaths": 0,
            "failed_requests": 0,
            "ignored_events": 0,
            "watch_errors": 0,
        }

    # -- topology ------------------------------------------------------------
    def _req_topic(self, name: str) -> str:
        return f"{self.request_topic_prefix}{name}"

    def _pick_engine_locked(self) -> str | None:
        """Most free pages wins; ties break toward fewer in-flight."""
        best, best_score = None, None
        for name in self.engines:
            if name in self._dead:
                continue
            load = None
            if self.control_store is not None:
                # mutable cell, written by another process: fresh read
                load = self.control_store.get(
                    self.load_prefix + name, fresh=True
                )
            inflight = sum(
                1
                for r in self._state.values()
                if r.engine == name and not r.terminal
            )
            score = (load if load is not None else -1, -inflight)
            if best_score is None or score > best_score:
                best, best_score = name, score
        return best

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "Router":
        self._threads.append(
            threading.Thread(
                target=self._intake_loop, name="router-intake", daemon=True
            )
        )
        for name in self.engines:
            self._threads.append(
                threading.Thread(
                    target=self._forward_loop,
                    args=(name,),
                    name=f"router-fwd-{name}",
                    daemon=True,
                )
            )
        if self.lease is not None:
            self._threads.append(
                threading.Thread(
                    target=self._watch_loop, name="router-watch", daemon=True
                )
            )
        for t in self._threads:
            t.start()
        return self

    def wait(self, timeout: float | None = None) -> bool:
        """Block until the merged response topic has been closed."""
        return self._done_evt.wait(timeout)

    def close(self) -> None:
        self._stop_evt.set()
        for g in self._gates.values():
            g.set()  # unpark paused forwarders so they see the stop
        for t in self._threads:
            t.join(timeout=5.0)
        self.subscriber.close()

    # -- test / introspection hooks -------------------------------------------
    def snapshot(self) -> dict[str, tuple[str, bool, int]]:
        """``req_id -> (engine, terminal, deltas_forwarded)``."""
        with self._lock:
            return {
                rid: (rec.engine, rec.terminal, rec.forwarded)
                for rid, rec in self._state.items()
            }

    def pause_forwarder(self, name: str) -> None:
        self._gates[name].clear()

    def resume_forwarder(self, name: str) -> None:
        self._gates[name].set()

    def mark_engine_dead(self, name: str) -> None:
        """Out-of-band death report (tests; lease watch calls this too)."""
        with self._lock:
            self._on_engine_dead_locked(name)

    # -- intake ----------------------------------------------------------------
    def _intake_loop(self) -> None:
        while not self._stop_evt.is_set():
            try:
                raw = self.subscriber.next_event(timeout=self.tick)
            except TimeoutError:
                continue
            event = _load_event(raw)
            if event.get(_END):
                with self._lock:
                    self._intake_closed = True
                    self._maybe_shutdown_locked()
                return
            meta = event.get("metadata", {})
            rid = meta.get("req_id")
            with self._lock:
                if rid is None:
                    self.metrics["unroutable_requests"] += 1
                    continue
                if rid in self._state:
                    self.metrics["duplicate_requests"] += 1
                    continue
                target = self._pick_engine_locked()
                if target is None:
                    self.metrics["failed_requests"] += 1
                    self._publish_error_locked(rid, "no live engines")
                    continue
                self._state[rid] = _ReqState(event=event, engine=target)
                self.metrics["requests_routed"] += 1
                topic = self._req_topic(target)
                publish_event(self.publisher, topic, {**event, "topic": topic})

    # -- per-engine response forwarders -----------------------------------------
    def _forward_loop(self, name: str) -> None:
        sub = self.make_engine_subscriber(name)
        gate = self._gates[name]
        try:
            while not self._stop_evt.is_set():
                if not gate.wait(self.tick):
                    continue  # paused (chaos-test window)
                try:
                    raw = sub.next_event(timeout=self.tick)
                except TimeoutError:
                    continue
                event = _load_event(raw)
                if event.get(_END):
                    with self._lock:
                        self._engine_closed.add(name)
                        self._maybe_shutdown_locked()
                    return
                self._forward_one(event)
        finally:
            sub.close()

    def _forward_one(self, event: dict) -> None:
        meta = event.get("metadata", {})
        rid = meta.get("req_id")
        kind = meta.get("kind")
        with self._lock:
            rec = self._state.get(rid) if rid is not None else None
            if rec is None:
                self.metrics["ignored_events"] += 1
                return
            if kind == "delta":
                if rec.terminal or meta.get("index") != rec.forwarded:
                    # replayed prefix of a redispatched request (greedy
                    # decode: the dropped tokens are bit-identical to the
                    # ones already forwarded), or a straggler after done
                    self.metrics["dropped_stale_deltas"] += 1
                    return
                rec.forwarded += 1
                self.metrics["deltas_forwarded"] += 1
            elif kind in ("done", "error"):
                if rec.terminal:
                    # twin completion of a redispatched request; its event
                    # references the same committed cell the winner's
                    # client resolve reclaims — drop, don't double-send
                    self.metrics["duplicate_dones"] += 1
                    return
                rec.terminal = True
                self.metrics["dones_forwarded"] += 1
            else:
                self.metrics["ignored_events"] += 1
                return
            publish_event(
                self.publisher,
                self.response_topic,
                {**event, "topic": self.response_topic},
            )
            if rec.terminal:
                self._maybe_shutdown_locked()

    # -- lease watch / failover --------------------------------------------------
    def _watch_loop(self) -> None:
        known = None
        while not self._stop_evt.is_set():
            try:
                snap = self.lease.watch(known, timeout=1.0)
            except Exception:
                with self._lock:
                    self.metrics["watch_errors"] += 1
                self._stop_evt.wait(self.tick)
                continue
            known = snap
            dead = set(snap.dead) & set(self.engines)
            if not dead:
                continue
            with self._lock:
                for name in sorted(dead):
                    self._on_engine_dead_locked(name)

    def _on_engine_dead_locked(self, name: str) -> None:
        if name in self._dead or name not in self.engines:
            return
        self._dead.add(name)
        self.metrics["engine_deaths"] += 1
        for rid, rec in self._state.items():
            if rec.terminal or rec.engine != name:
                continue
            target = self._pick_engine_locked()
            if target is None:
                rec.terminal = True
                self.metrics["failed_requests"] += 1
                self._publish_error_locked(
                    rid, f"engine {name} died; no live engines"
                )
                continue
            rec.engine = target
            self.metrics["redispatches"] += 1
            topic = self._req_topic(target)
            # verbatim re-publish: same prompt key/connector — the prompt
            # bulk is persistent (evict_on_resolve=False) so the survivor
            # resolves the same bytes the dead engine did
            publish_event(
                self.publisher, topic, {**rec.event, "topic": topic}
            )
        self._maybe_shutdown_locked()

    # -- shutdown ladder ---------------------------------------------------------
    def _publish_error_locked(self, rid: str, error: str) -> None:
        publish_event(
            self.publisher,
            self.response_topic,
            {
                "topic": self.response_topic,
                "meta_only": True,
                "metadata": {"req_id": rid, "kind": "error", "error": error},
                "seq": -1,
            },
        )

    def _maybe_shutdown_locked(self) -> None:
        if not self._intake_closed:
            return
        if any(not r.terminal for r in self._state.values()):
            return
        if not self._shutdown_sent:
            self._shutdown_sent = True
            for name in self.engines:
                if name not in self._dead:
                    topic = self._req_topic(name)
                    publish_event(
                        self.publisher, topic, {_END: True, "topic": topic}
                    )
        if not self._responses_closed and all(
            n in self._engine_closed or n in self._dead for n in self.engines
        ):
            self._responses_closed = True
            publish_event(
                self.publisher,
                self.response_topic,
                {_END: True, "topic": self.response_topic},
            )
            self._done_evt.set()
