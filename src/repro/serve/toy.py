"""Deterministic toy "LM" for serve tests, chaos drills, and fleet benches.

``CountingModel`` speaks the model decode API (``prefill`` /
``decode_step`` / ``cache_specs`` / ``param_specs``) but computes integer
arithmetic instead of a neural net: the next token is

    next = (sum(history[0..pos]) + pos + 1) % vocab

so every generated token depends on the *whole* prefix **and** the exact
position — a wrong per-slot position, a stale cache row, or cross-slot
leakage produces a different token immediately.  Integer sums in float32
are exact at these sizes, so engine-vs-reference comparisons are
bit-identical, with no neural-net reduction-order caveats.

Lives in ``src`` (not ``tests``) because the fleet entry point
(``repro.launch.fleet engine --toy``) and the fleet benchmark run it in
*subprocess* engines, where the tests package is not importable;
``tests/_serve_toy.py`` re-exports it for the existing suite.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec


class CountingModel:
    """Integer-arithmetic stand-in: deterministic, position-sensitive."""

    def __init__(self, cfg):
        self.cfg = cfg

    def param_specs(self) -> dict:
        return {}

    def cache_specs(self, batch_size: int, max_len: int) -> dict:
        return {
            "hist": ParamSpec(
                (1, batch_size, max_len, 1),
                (None, "batch", "kv_seq", None),
                jnp.float32,
                0.0,
            )
        }

    def _next(self, hist, index):
        """(1, B, S, 1) history + scalar position → (B,) next token."""
        S = hist.shape[2]
        mask = (jnp.arange(S) <= index)[None, None, :, None]
        prefix = jnp.sum(jnp.where(mask, hist, 0.0), axis=2)  # (1, B, 1)
        return (prefix[0, :, 0] + index + 1) % self.cfg.vocab

    def prefill(self, params, tokens, max_len: int):
        B, S = tokens.shape
        hist = jnp.zeros((1, B, max_len, 1), jnp.float32)
        hist = hist.at[:, :, :S, 0].set(tokens.astype(jnp.float32)[None])
        nxt = self._next(hist, S - 1)
        logits = jax.nn.one_hot(nxt.astype(jnp.int32), self.cfg.vocab)
        return logits, {"hist": hist}

    def prefill_batch(self, params, tokens, lens, max_len: int):
        """Batched multi-request prefill: (B, S) right-padded prompts with
        per-row valid lengths.  Pad positions hold 0, so the integer prefix
        sums match the per-request ``prefill`` exactly (bit-identical)."""
        B, S = tokens.shape
        valid = jnp.arange(S)[None, :] < lens[:, None]
        toks = jnp.where(valid, tokens, 0).astype(jnp.float32)
        hist = jnp.zeros((1, B, max_len, 1), jnp.float32)
        hist = hist.at[:, :, :S, 0].set(toks[None])
        idx = jnp.maximum(lens - 1, 0)  # (B,) last valid position per row
        mask = (jnp.arange(max_len)[None, :] <= idx[:, None])[None, :, :, None]
        prefix = jnp.sum(jnp.where(mask, hist, 0.0), axis=2)  # (1, B, 1)
        nxt = (prefix[0, :, 0] + idx + 1) % self.cfg.vocab
        logits = jax.nn.one_hot(nxt.astype(jnp.int32), self.cfg.vocab)
        return logits, {"hist": hist}

    def decode_step(self, params, cache, tokens, index):
        """tokens (B, 1) is the token *at* position ``index``; logits
        predict position ``index + 1`` (the convention pinned by
        test_decode_matches_prefill)."""
        hist = cache["hist"]
        tok = tokens[:, 0].astype(jnp.float32)
        hist = hist.at[:, :, index, 0].set(tok[None])
        nxt = self._next(hist, index)
        logits = jax.nn.one_hot(nxt.astype(jnp.int32), self.cfg.vocab)
        return logits, {"hist": hist}

    def decode_multi(self, params, cache, tokens, index):
        """K-token decode (speculative verify): ``tokens`` (B, K) land at
        positions ``index .. index+K-1``; ``logits[:, t]`` predicts
        position ``index+t+1`` from the prefix *through* token ``t``.
        Integer-exact, so K == 1 is bit-identical to ``decode_step``."""
        hist = cache["hist"]
        K = tokens.shape[1]
        outs = []
        for t in range(K):  # static unroll: K is small (spec_k + 1)
            tok = tokens[:, t].astype(jnp.float32)
            hist = hist.at[:, :, index + t, 0].set(tok[None])
            outs.append(self._next(hist, index + t))
        logits = jax.nn.one_hot(jnp.stack(outs, 1).astype(jnp.int32), self.cfg.vocab)
        return logits, {"hist": hist}

    def verify_batch(self, params, cache, tokens, lens):
        """Per-row multi-position decode: row ``b``'s K tokens sit at
        positions ``lens[b] .. lens[b]+K-1`` of its own cache row (same
        contract as ``DecoderLM.verify_batch``)."""

        def one(cache_b, tok_b, len_b):
            cb = jax.tree.map(lambda c: c[:, None], cache_b)
            logits, nc = self.decode_multi(params, cb, tok_b[None], len_b)
            return logits[0], jax.tree.map(lambda c: c[:, 0], nc)

        return jax.vmap(one, in_axes=(1, 0, 0), out_axes=(0, 1))(
            cache, tokens, lens
        )


def reference_decode(cfg, prompt, max_new: int, *, eos_id: int = -1,
                     max_len: int = 64, model=None) -> list[int]:
    """Sequential single-request greedy decode: the ground truth the
    continuous-batching engine must reproduce bit-identically."""
    import numpy as np

    model = model or CountingModel(cfg)
    tokens = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, cache = model.prefill({}, tokens, max_len)
    out = [int(jnp.argmax(logits[0, : cfg.vocab]))]
    pos = tokens.shape[1]
    while (
        out[-1] != eos_id and len(out) < max_new and pos < max_len - 1
    ):
        step = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = model.decode_step({}, cache, step, jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0, : cfg.vocab])))
        pos += 1
    return out
