"""Paged KV-cache bookkeeping under the ownership pattern (paper §IV-C).

The device-side KV cache is a dense (L, B_slots, S_max, …) tensor managed by
XLA; what leaks in real serving systems is the *control-plane* state — which
sequence owns which pages, when they can be reused, and the host-side
prompt/result payloads.  Here every sequence's page list is an
:class:`OwnedProxy` in a Store: finishing a sequence frees the owner, which
deterministically evicts the metadata and returns pages to the free pool —
the MOF-generation behaviour from the paper's Fig 10 (no manual bookkeeping,
no leaks), with runtime borrow rules protecting in-flight reads.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.ownership import OwnedProxy, borrow, free, owned_proxy, release
from repro.core.store import Store


@dataclass
class PageTable:
    """Free-list page allocator for one model's KV pool."""

    num_pages: int
    page_size: int
    store: Store
    _free: list[int] = field(default_factory=list)
    _owners: dict[str, OwnedProxy] = field(default_factory=dict)

    def __post_init__(self):
        self._free = list(range(self.num_pages))

    @property
    def pages_free(self) -> int:
        return len(self._free)

    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def allocate(self, seq_id: str, tokens: int) -> list[int]:
        n = self.pages_needed(tokens)
        if n > len(self._free):
            raise MemoryError(
                f"KV pool exhausted: need {n} pages, {len(self._free)} free"
            )
        pages = [self._free.pop() for _ in range(n)]
        self._owners[seq_id] = owned_proxy(
            self.store, {"seq": seq_id, "pages": pages}, key=f"pages-{seq_id}"
        )
        return pages

    def extend(self, seq_id: str, new_total_tokens: int) -> list[int]:
        owner = self._owners[seq_id]
        meta = dict(owner)
        have = len(meta["pages"])
        need = self.pages_needed(new_total_tokens)
        added = []
        if need > have:
            if need - have > len(self._free):
                raise MemoryError("KV pool exhausted on extend")
            added = [self._free.pop() for _ in range(need - have)]
            meta["pages"] = meta["pages"] + added
            # write-back through the ownership API
            from repro.core.ownership import update
            from repro.core.proxy import extract

            owner["pages"] = meta["pages"]
            update(owner)
        return added

    def pages_of(self, seq_id: str) -> list[int]:
        ref = borrow(self._owners[seq_id])
        try:
            return list(ref["pages"])
        finally:
            release(ref)

    def free_sequence(self, seq_id: str) -> None:
        """End of sequence: the owner frees; pages return to the pool."""
        owner = self._owners.pop(seq_id)
        pages = list(owner["pages"])
        free(owner)  # raises OwnershipError if a borrow is still outstanding
        self._free.extend(pages)

    def live_sequences(self) -> list[str]:
        return list(self._owners)
