"""Paged KV-cache bookkeeping under the ownership pattern (paper §IV-C).

Page-pool layout and block-table convention
-------------------------------------------
Device-side, the engine keeps each stacked cache leaf as a **page pool**
``(L, P+1, page_size, ...)``: axis 1 is the physical page id, axis 2 the
within-page token offset, and token ``t`` of a sequence lives at page
``pages_of(seq)[t // page_size]``, offset ``t % page_size``.  Index ``P``
(one past the allocator's range) is the **null page** — a scratch target
idle slots read and write so the jit'd decode step needs no masking.  A
sequence's *block table* is simply its ``pages_of`` list, null-padded on
the right; the paged-attention kernel gathers K/V through it and the
per-slot length bounds the gather, so short sequences stop paying for
``max_len``.

Host-side, every sequence carries real store state:

- a *page-list owner* (:class:`OwnedProxy` over ``{"seq", "pages"}``) — the
  control-plane record, mutated through the ownership API on extend;
- one *Owned KV cell per page* in the store (``page_bytes`` of backing
  memory each, keyed ``kvpage-{creator}-{page}``) — the host-side paged KV
  residency.  ``free_sequence`` frees every owner, which deterministically
  evicts the cells and **returns the store memory**, not just the page ids
  — the MOF-generation behaviour from the paper's Fig 10 (no manual
  bookkeeping, no leaks), with runtime borrow rules protecting in-flight
  reads.

Prefix sharing and copy-on-write
--------------------------------
``allocate(seq, tokens, prefix_of=parent, prefix_tokens=p)`` aliases the
parent's leading pages instead of copying them: the child holds a runtime
``borrow`` on each shared cell, so a page's refcount is *1 (creator) + its
borrow count* and ``free_sequence`` returns a page to the free list only
at refcount zero (a creator that exits first leaves the cell orphaned but
resident until the last borrower releases).  A *partial* boundary page is
shared too — readers mask by length — but the first extend past
``prefix_tokens`` (or a divergent prompt at allocate) triggers
**copy-on-write**: a fresh page is drawn, the cell payload is copied, the
borrow is dropped, and the ``(seq, src, dst)`` event is queued for the
engine to mirror on the device pool (``drain_cow_events``).  Reservations
price the potential COW page in, so an admitted extend still never fails.

Admission control rides on *reservations*: ``allocate(seq, tokens,
reserve_tokens=total)`` holds back the pages a sequence may grow into, so
``can_admit``/``pages_available`` answer "will this request ever OOM
mid-decode?" at admission time — backpressure instead of a MemoryError
halfway through a generation.

Speculative decode and rollback
-------------------------------
Speculative decode (engine ``spec_k > 0``) extends a sequence by up to
``k+1`` tokens per step *before* knowing how many the target model will
accept.  The table never rolls back: ``extend`` is monotone, and rejection
is expressed entirely on the device pool — pages past the accepted length
are simply not scattered back, so their cells hold stale bytes that the
next step overwrites before anything reads them.  Reservations make this
safe: a k-token extend stays within the admission-time reservation because
the engine clamps the per-step speculation depth to ``remaining - 1``
tokens (``reserve_tokens`` already prices the full generation), so an
admitted sequence's speculative extends can never fail — the
admitted-⇒-extend-never-fails contract is unchanged by speculation.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.ownership import (
    OwnedProxy,
    RefProxy,
    borrow,
    free,
    num_borrows,
    owned_proxy,
    release,
    update,
)
from repro.core.store import Store


def page_bytes_for(model, dtype, page_size: int) -> int:
    """Host-side KV bytes one page of ``model``'s cache represents.

    The PageTable cell size for a pool serving this model: per-token cache
    footprint (from ``cache_specs``) times the page's token capacity.  The
    engine prices its target pool with its own model here and — under
    speculative decode — the *draft* pool with the draft model's (usually
    much smaller) per-token cache, so the two pools' store residency each
    reflect their real KV weight."""
    import jax.numpy as jnp

    from repro.dist.sharding import count_params

    per_token = count_params(model.cache_specs(1, 1))
    return page_size * per_token * jnp.dtype(dtype).itemsize


@dataclass
class PageTable:
    """Free-list page allocator for one model's KV pool.

    ``pages_in_use() + pages_free() == num_pages`` always; reserved pages
    are *free but spoken for* (``pages_available`` subtracts them), so an
    admitted sequence's ``extend`` within its reservation can never fail —
    including the one copy-on-write page a shared partial prefix may need.
    """

    num_pages: int
    page_size: int
    store: Store
    page_bytes: int = 0  # per-page KV backing in the store (0 → id marker)
    pages_allocated_total: int = 0  # free-list pops ever (sharing saves these)
    _free: list[int] = field(default_factory=list)
    _owners: dict[str, OwnedProxy] = field(default_factory=dict)
    _cells: dict[str, dict[int, OwnedProxy]] = field(default_factory=dict)
    _reserved: dict[str, int] = field(default_factory=dict)
    # prefix sharing state ---------------------------------------------------
    _borrowed: dict[str, dict[int, RefProxy]] = field(default_factory=dict)
    _page_owner: dict[int, str] = field(default_factory=dict)  # page → creator
    _orphans: dict[int, OwnedProxy] = field(default_factory=dict)
    _prefix_tokens: dict[str, int] = field(default_factory=dict)
    _tokens: dict[str, int] = field(default_factory=dict)  # max length seen
    _cow_pending: dict[str, int] = field(default_factory=dict)  # seq → page
    _cow_events: list[tuple[str, int, int]] = field(default_factory=list)

    def __post_init__(self):
        self._free = list(range(self.num_pages))

    # -- accounting ----------------------------------------------------------
    def pages_free(self) -> int:
        """Pages in the free list (including reserved-but-unallocated)."""
        return len(self._free)

    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def pages_reserved(self) -> int:
        """Free pages already promised to admitted sequences' growth."""
        return sum(self._reserved.values())

    def pages_available(self) -> int:
        """Pages a *new* sequence may claim: free minus reserved."""
        return len(self._free) - self.pages_reserved()

    def pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def can_admit(self, tokens: int) -> bool:
        """Admission check: can a sequence of ``tokens`` total length be
        allocated *and grown to completion* without exhausting the pool?
        (Conservative under prefix sharing: assumes no pages are shared.)"""
        return self.pages_needed(tokens) <= self.pages_available()

    def page_refcount(self, page: int) -> int:
        """Sequences referencing ``page``: creator (if live) + borrowers."""
        if page in self._orphans:
            return num_borrows(self._orphans[page])[0]
        creator = self._page_owner.get(page)
        if creator is None:
            return 0
        return 1 + num_borrows(self._cells[creator][page])[0]

    def borrowed_pages(self, seq_id: str) -> set[int]:
        """Pages ``seq_id`` references but does not own (shared prefix)."""
        return set(self._borrowed.get(seq_id, {}))

    def orphan_pages(self) -> set[int]:
        """Pages whose creator freed while borrows were still outstanding."""
        return set(self._orphans)

    def drain_cow_events(self) -> list[tuple[str, int, int]]:
        """Pop the queued copy-on-write ``(seq, src, dst)`` events; the
        engine mirrors each as a device-pool page copy before decoding and
        refreshes only ``seq``'s block table (other borrowers keep src)."""
        ev, self._cow_events = self._cow_events, []
        return ev

    # -- store cells ---------------------------------------------------------
    def page_key(self, seq_id: str, page: int) -> str:
        return f"kvpage-{seq_id}-{page}"

    def _make_cells(self, seq_id: str, pages: list[int]) -> None:
        cells = self._cells.setdefault(seq_id, {})
        for p in pages:
            payload = bytes(self.page_bytes) if self.page_bytes else p
            cells[p] = owned_proxy(self.store, payload, key=self.page_key(seq_id, p))
            self._page_owner[p] = seq_id

    def _cell_of(self, page: int) -> OwnedProxy:
        if page in self._orphans:
            return self._orphans[page]
        return self._cells[self._page_owner[page]][page]

    def _borrow_page(self, seq_id: str, page: int) -> None:
        self._borrowed.setdefault(seq_id, {})[page] = borrow(self._cell_of(page))

    def _drop_borrow(self, seq_id: str, page: int) -> None:
        release(self._borrowed[seq_id].pop(page))
        self._collect_orphan(page)

    def _collect_orphan(self, page: int) -> None:
        """Free an orphaned cell once its last borrower releases."""
        cell = self._orphans.get(page)
        if cell is not None and num_borrows(cell)[0] == 0:
            free(cell)
            del self._orphans[page]
            self._free.append(page)

    def _copy_cell(self, seq_id: str, src: int, dst: int) -> None:
        """COW: materialize ``dst`` as ``seq_id``'s own copy of ``src``."""
        r = borrow(self._cell_of(src))
        try:
            payload = bytes(r) if self.page_bytes else dst
        finally:
            release(r)
        cells = self._cells.setdefault(seq_id, {})
        cells[dst] = owned_proxy(self.store, payload, key=self.page_key(seq_id, dst))
        self._page_owner[dst] = seq_id

    def _take(self, n: int) -> list[int]:
        self.pages_allocated_total += n
        return [self._free.pop() for _ in range(n)]

    # -- allocate / extend / free -------------------------------------------
    def allocate(
        self,
        seq_id: str,
        tokens: int,
        *,
        reserve_tokens: int | None = None,
        prefix_of: str | None = None,
        prefix_tokens: int | None = None,
    ) -> list[int]:
        """Claim pages for ``tokens``; optionally reserve growth headroom
        and/or alias a live sequence's prefix pages instead of copying.

        ``reserve_tokens`` is the total length the sequence may reach
        (prompt + max new tokens): the delta beyond ``tokens`` stays in the
        free list but is held out of ``pages_available`` until this
        sequence extends into it or frees.

        ``prefix_of``/``prefix_tokens`` share the parent's leading pages by
        refcount (runtime borrows on the page cells).  A partial boundary
        page is shared too; if this sequence's prompt already diverges past
        it the copy-on-write happens here, otherwise it is deferred to the
        first extend beyond ``prefix_tokens`` — and the reservation prices
        that future copy in, so extend stays infallible.
        """
        if seq_id in self._owners:
            raise ValueError(f"sequence {seq_id!r} already allocated")
        n_total = self.pages_needed(tokens)
        shared: list[int] = []
        ptok = 0
        if prefix_of is not None:
            parent_pages = list(self._owners[prefix_of]["pages"])
            ptok = prefix_tokens if prefix_tokens is not None else self._tokens.get(prefix_of, 0)
            ptok = max(0, min(ptok, tokens, self._tokens.get(prefix_of, 0)))
            n_shared = min(self.pages_needed(ptok), len(parent_pages))
            ptok = min(ptok, n_shared * self.page_size)
            shared = parent_pages[:n_shared] if ptok > 0 else []
            if not shared:
                ptok = 0
        n_shared = len(shared)
        boundary_partial = n_shared > 0 and ptok % self.page_size != 0
        cow_now = boundary_partial and tokens > ptok
        reach = max(tokens, reserve_tokens or 0)
        cow_ever = boundary_partial and reach > ptok
        fresh_now = n_total - n_shared + (1 if cow_now else 0)
        fresh_ever = max(
            self.pages_needed(reach) - n_shared + (1 if cow_ever else 0), fresh_now
        )
        if fresh_ever > self.pages_available():
            raise MemoryError(
                f"KV pool exhausted: need {fresh_ever} pages (incl. "
                f"reservation), {self.pages_available()} available "
                f"({len(self._free)} free, {self.pages_reserved()} reserved)"
            )
        fresh = self._take(fresh_now)
        self._reserved[seq_id] = fresh_ever - fresh_now
        if cow_now:
            # prompt already diverges inside the boundary page: copy it now
            for p in shared[:-1]:
                self._borrow_page(seq_id, p)
            dst, rest = fresh[0], fresh[1:]
            self._copy_cell(seq_id, shared[-1], dst)
            self._cow_events.append((seq_id, shared[-1], dst))
            pages = shared[:-1] + [dst] + rest
            new_cells = rest
        else:
            for p in shared:
                self._borrow_page(seq_id, p)
            if boundary_partial:
                self._cow_pending[seq_id] = shared[-1]
            pages = shared + fresh
            new_cells = fresh
        self._prefix_tokens[seq_id] = ptok
        self._tokens[seq_id] = tokens
        self._owners[seq_id] = owned_proxy(
            self.store, {"seq": seq_id, "pages": pages}, key=f"pages-{seq_id}"
        )
        self._make_cells(seq_id, new_cells)
        return pages

    def extend(self, seq_id: str, new_total_tokens: int) -> list[int]:
        """Grow ``seq_id`` to cover ``new_total_tokens``; returns new pages.

        Growth within the sequence's reservation always succeeds; growth
        beyond it competes with everyone else's unreserved pages.  The
        first growth past a shared partial boundary page copies it
        (copy-on-write) — the parent's page is never written through."""
        owner = self._owners[seq_id]
        pages = list(owner["pages"])
        have = len(pages)
        need = self.pages_needed(new_total_tokens)
        cow_src = self._cow_pending.get(seq_id)
        cow = (
            cow_src is not None
            and new_total_tokens > self._prefix_tokens.get(seq_id, 0)
        )
        extra = max(0, need - have)
        take = extra + (1 if cow else 0)
        if take == 0:
            self._tokens[seq_id] = max(self._tokens.get(seq_id, 0), new_total_tokens)
            return []
        own_reserved = self._reserved.get(seq_id, 0)
        beyond_reservation = max(0, take - own_reserved)
        if beyond_reservation > self.pages_available():
            raise MemoryError(
                f"KV pool exhausted on extend of {seq_id!r}: need {take} "
                f"pages ({own_reserved} reserved, "
                f"{self.pages_available()} available)"
            )
        fresh = self._take(take)
        self._reserved[seq_id] = max(0, own_reserved - take)
        added = fresh
        if cow:
            dst, added = fresh[0], fresh[1:]
            self._copy_cell(seq_id, cow_src, dst)
            self._drop_borrow(seq_id, cow_src)
            pages[pages.index(cow_src)] = dst
            del self._cow_pending[seq_id]
            self._cow_events.append((seq_id, cow_src, dst))
        pages = pages + added
        # write-back through the ownership API (the owner is the one legal
        # mutator of the page-list record)
        owner["pages"] = pages
        update(owner)
        self._make_cells(seq_id, added)
        self._tokens[seq_id] = max(self._tokens.get(seq_id, 0), new_total_tokens)
        return added

    def pages_of(self, seq_id: str) -> list[int]:
        ref = borrow(self._owners[seq_id])
        try:
            return list(ref["pages"])
        finally:
            release(ref)

    def free_sequence(self, seq_id: str) -> None:
        """End of sequence: the owner frees; pages *and their store
        memory* return to the pool at refcount zero (pages other live
        sequences still borrow stay resident as orphans until the last
        borrower releases).  Raises OwnershipError while the page-list
        record itself is borrowed.

        The owner frees *before* any table state mutates, so a rejected
        free (outstanding borrow) leaves the sequence fully intact and
        retryable — no leaked pages, no wedged retry."""
        owner = self._owners[seq_id]
        free(owner)  # the only call that can raise: state untouched so far
        self._owners.pop(seq_id)
        returned = []
        for p, cell in self._cells.pop(seq_id, {}).items():
            if num_borrows(cell)[0]:
                self._orphans[p] = cell  # shared: resident until last release
                self._page_owner.pop(p, None)
            else:
                free(cell)  # evicts the KV backing from the store
                self._page_owner.pop(p, None)
                returned.append(p)
        self._free.extend(returned)
        for p, ref in self._borrowed.pop(seq_id, {}).items():
            release(ref)
            self._collect_orphan(p)
        self._reserved.pop(seq_id, None)
        self._prefix_tokens.pop(seq_id, None)
        self._tokens.pop(seq_id, None)
        self._cow_pending.pop(seq_id, None)

    def live_sequences(self) -> list[str]:
        return list(self._owners)
