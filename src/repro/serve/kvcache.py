"""Paged KV-cache bookkeeping under the ownership pattern (paper §IV-C).

The device-side KV cache is a dense (L, B_slots, S_max, …) tensor managed by
XLA; what leaks in real serving systems is the *control-plane* state — which
sequence owns which pages, when they can be reused, and the host-side
prompt/result payloads.  Here every sequence carries real store state:

- a *page-list owner* (:class:`OwnedProxy` over ``{"seq", "pages"}``) — the
  control-plane record, mutated through the ownership API on extend;
- one *Owned KV cell per page* in the store (``page_bytes`` of backing
  memory each, keyed ``kvpage-{seq}-{page}``) — the host-side paged KV
  residency.  ``free_sequence`` frees every owner, which deterministically
  evicts the cells and **returns the store memory**, not just the page ids
  — the MOF-generation behaviour from the paper's Fig 10 (no manual
  bookkeeping, no leaks), with runtime borrow rules protecting in-flight
  reads.

Admission control rides on *reservations*: ``allocate(seq, tokens,
reserve_tokens=total)`` holds back the pages a sequence may grow into, so
``can_admit``/``pages_available`` answer "will this request ever OOM
mid-decode?" at admission time — backpressure instead of a MemoryError
halfway through a generation.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.ownership import OwnedProxy, borrow, free, owned_proxy, release, update
from repro.core.store import Store


@dataclass
class PageTable:
    """Free-list page allocator for one model's KV pool.

    ``pages_in_use() + pages_free() == num_pages`` always; reserved pages
    are *free but spoken for* (``pages_available`` subtracts them), so an
    admitted sequence's ``extend`` within its reservation can never fail.
    """

    num_pages: int
    page_size: int
    store: Store
    page_bytes: int = 0  # per-page KV backing in the store (0 → id marker)
    _free: list[int] = field(default_factory=list)
    _owners: dict[str, OwnedProxy] = field(default_factory=dict)
    _cells: dict[str, dict[int, OwnedProxy]] = field(default_factory=dict)
    _reserved: dict[str, int] = field(default_factory=dict)

    def __post_init__(self):
        self._free = list(range(self.num_pages))

    # -- accounting ----------------------------------------------------------
    def pages_free(self) -> int:
        """Pages in the free list (including reserved-but-unallocated)."""
        return len(self._free)

    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def pages_reserved(self) -> int:
        """Free pages already promised to admitted sequences' growth."""
        return sum(self._reserved.values())

    def pages_available(self) -> int:
        """Pages a *new* sequence may claim: free minus reserved."""
        return len(self._free) - self.pages_reserved()

    def pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def can_admit(self, tokens: int) -> bool:
        """Admission check: can a sequence of ``tokens`` total length be
        allocated *and grown to completion* without exhausting the pool?"""
        return self.pages_needed(tokens) <= self.pages_available()

    # -- store cells ---------------------------------------------------------
    def page_key(self, seq_id: str, page: int) -> str:
        return f"kvpage-{seq_id}-{page}"

    def _make_cells(self, seq_id: str, pages: list[int]) -> None:
        cells = self._cells.setdefault(seq_id, {})
        for p in pages:
            payload = bytes(self.page_bytes) if self.page_bytes else p
            cells[p] = owned_proxy(self.store, payload, key=self.page_key(seq_id, p))

    # -- allocate / extend / free -------------------------------------------
    def allocate(
        self, seq_id: str, tokens: int, *, reserve_tokens: int | None = None
    ) -> list[int]:
        """Claim pages for ``tokens``; optionally reserve growth headroom.

        ``reserve_tokens`` is the total length the sequence may reach
        (prompt + max new tokens): the delta beyond ``tokens`` stays in the
        free list but is held out of ``pages_available`` until this
        sequence extends into it or frees.
        """
        if seq_id in self._owners:
            raise ValueError(f"sequence {seq_id!r} already allocated")
        n = self.pages_needed(tokens)
        r = max(n, self.pages_needed(reserve_tokens)) if reserve_tokens else n
        if r > self.pages_available():
            raise MemoryError(
                f"KV pool exhausted: need {r} pages (incl. reservation), "
                f"{self.pages_available()} available "
                f"({len(self._free)} free, {self.pages_reserved()} reserved)"
            )
        pages = [self._free.pop() for _ in range(n)]
        self._reserved[seq_id] = r - n
        self._owners[seq_id] = owned_proxy(
            self.store, {"seq": seq_id, "pages": pages}, key=f"pages-{seq_id}"
        )
        self._make_cells(seq_id, pages)
        return pages

    def extend(self, seq_id: str, new_total_tokens: int) -> list[int]:
        """Grow ``seq_id`` to cover ``new_total_tokens``; returns new pages.

        Growth within the sequence's reservation always succeeds; growth
        beyond it competes with everyone else's unreserved pages.
        """
        owner = self._owners[seq_id]
        have = len(owner["pages"])
        need = self.pages_needed(new_total_tokens)
        if need <= have:
            return []
        extra = need - have
        own_reserved = self._reserved.get(seq_id, 0)
        beyond_reservation = max(0, extra - own_reserved)
        if beyond_reservation > self.pages_available():
            raise MemoryError(
                f"KV pool exhausted on extend of {seq_id!r}: need {extra} "
                f"pages ({own_reserved} reserved, "
                f"{self.pages_available()} available)"
            )
        added = [self._free.pop() for _ in range(extra)]
        self._reserved[seq_id] = max(0, own_reserved - extra)
        # write-back through the ownership API (the owner is the one legal
        # mutator of the page-list record)
        owner["pages"] = owner["pages"] + added
        update(owner)
        self._make_cells(seq_id, added)
        return added

    def pages_of(self, seq_id: str) -> list[int]:
        ref = borrow(self._owners[seq_id])
        try:
            return list(ref["pages"])
        finally:
            release(ref)

    def free_sequence(self, seq_id: str) -> None:
        """End of sequence: every owner frees; pages *and their store
        memory* return to the pool (raises OwnershipError while borrowed).

        The owner frees *before* any table state mutates, so a rejected
        free (outstanding borrow) leaves the sequence fully intact and
        retryable — no leaked pages, no wedged retry."""
        owner = self._owners[seq_id]
        pages = list(owner["pages"])
        free(owner)  # the only call that can raise: state untouched so far
        self._owners.pop(seq_id)
        for cell in self._cells.pop(seq_id, {}).values():
            free(cell)  # evicts the KV backing from the store
        self._reserved.pop(seq_id, None)
        self._free.extend(pages)

    def live_sequences(self) -> list[str]:
        return list(self._owners)
