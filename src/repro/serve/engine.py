"""Continuous-batching serving engine on the proxy patterns.

Architecture = the paper's Fig 4 applied to inference:

- requests arrive on a **ProxyStream**: the scheduler (dispatcher) consumes
  *metadata only* (request id, prompt length, max tokens); the prompt bulk
  stays in the store until the engine actually admits the request.
- each admitted sequence's control-plane state (pages, prompt) is
  **ownership**-managed (kvcache.PageTable) — completion deterministically
  frees everything.
- results are published back on a response stream; the paper's persistent-
  inference-task DeepDriveMD integration is exactly this loop (one
  long-lived engine, streamed batches in/out, no per-task model reloads).

Decode is a single jit'd batched step over slot-packed caches; slots admit
new requests as others finish (continuous batching).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.proxy import Proxy, extract, is_resolved
from repro.core.store import Store
from repro.core.streaming import StreamConsumer, StreamProducer
from repro.models.api import build_model
from repro.models.layers import ModelContext
from repro.serve.kvcache import PageTable


@dataclass
class Request:
    req_id: str
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    arrived: float = field(default_factory=time.perf_counter)


@dataclass
class SlotState:
    req: Request | None = None
    pos: int = 0  # current length (prompt + generated)
    generated: list[int] = field(default_factory=list)


class ServeEngine:
    def __init__(
        self,
        ctx: ModelContext,
        params,
        *,
        slots: int = 4,
        max_len: int = 128,
        page_size: int = 16,
        eos_id: int = 0,
    ):
        self.ctx = ctx
        self.cfg = ctx.cfg
        self.model = build_model(ctx)
        self.params = params
        self.slots = [SlotState() for _ in range(slots)]
        self.max_len = max_len
        self.eos_id = eos_id
        self.kv_store = Store(f"kv-{id(self)}")
        self.pages = PageTable(
            num_pages=slots * (max_len // page_size),
            page_size=page_size,
            store=self.kv_store,
        )
        self._decode = jax.jit(
            lambda p, c, t, lens: self._decode_body(p, c, t, lens)
        )
        self._cache = None  # stacked (L, B, S, ...) pytree
        self.completed: dict[str, dict] = {}
        self.metrics = {"prefills": 0, "decode_steps": 0, "tokens": 0}

    # -- model glue ---------------------------------------------------------
    def _decode_body(self, params, cache, tokens, lens):
        """Per-slot positions: decode each slot at its own index.

        The batched decode step uses a shared scalar index in the model API;
        for continuous batching each slot has its own position, so we decode
        with per-slot gather/scatter via vmap over the batch axis.
        """
        B = tokens.shape[0]

        def one(cache_b, tok_b, len_b):
            c = jax.tree.map(lambda x: x[:, None], cache_b)  # re-add batch dim
            logits, nc = self.model.decode_step(params, c, tok_b[None], len_b)
            return jax.tree.map(lambda x: x[:, 0], nc), logits[0]

        new_cache, logits = jax.vmap(
            one, in_axes=(1, 0, 0), out_axes=(1, 0)
        )(cache, tokens, lens)
        return new_cache, logits

    def _ensure_cache(self):
        if self._cache is None:
            from repro.dist.sharding import materialize_params

            specs = self.model.cache_specs(len(self.slots), self.max_len)
            self._cache = materialize_params(specs, jax.random.PRNGKey(0))

    # -- request admission ------------------------------------------------------
    def admit(self, req: Request, slot_idx: int):
        cfg = self.cfg
        slot = self.slots[slot_idx]
        prompt = jnp.asarray(req.prompt[None], jnp.int32)
        self.pages.allocate(req.req_id, len(req.prompt))
        _, cache1 = self.model.prefill(self.params, prompt, self.max_len)
        self._ensure_cache()
        # write this slot's prefill cache into the batched cache
        self._cache = jax.tree.map(
            lambda full, one: full.at[:, slot_idx].set(one[:, 0]), self._cache, cache1
        )
        slot.req = req
        slot.pos = len(req.prompt)
        slot.generated = []
        self.metrics["prefills"] += 1

    def _finish(self, slot_idx: int):
        slot = self.slots[slot_idx]
        req = slot.req
        self.pages.free_sequence(req.req_id)  # ownership free → pages recycled
        self.completed[req.req_id] = {
            "tokens": list(slot.generated),
            "latency": time.perf_counter() - req.arrived,
        }
        slot.req = None
        slot.pos = 0
        slot.generated = []

    # -- main loop -----------------------------------------------------------------
    def run(
        self,
        request_consumer: StreamConsumer,
        response_producer: StreamProducer | None = None,
        *,
        max_requests: int | None = None,
        greedy: bool = True,
    ):
        """Serve until the request stream closes and all slots drain."""
        pending: list[Request] = []
        stream_open = True
        served = 0

        def pull_requests():
            nonlocal stream_open
            while stream_open:
                try:
                    proxy, meta = request_consumer.next_with_metadata()
                except StopIteration:
                    stream_open = False
                    break
                except TimeoutError:
                    break
                # metadata-only dispatch: bulk prompt resolves here, in the
                # engine, not in any intermediate scheduler
                body = extract(proxy)
                pending.append(
                    Request(
                        req_id=meta["req_id"],
                        prompt=np.asarray(body["prompt"], np.int32),
                        max_new_tokens=int(meta.get("max_new_tokens", 16)),
                    )
                )
                if len(pending) >= len(self.slots):
                    break

        while True:
            pull_requests()
            # admit into free slots
            for i, slot in enumerate(self.slots):
                if slot.req is None and pending:
                    self.admit(pending.pop(0), i)
            active = [i for i, s in enumerate(self.slots) if s.req is not None]
            if not active:
                if not stream_open and not pending:
                    break
                if max_requests is not None and served >= max_requests:
                    break
                time.sleep(0.005)
                continue
            # batched decode step (idle slots decode garbage at pos 0 — masked)
            tokens = np.zeros((len(self.slots),), np.int32)
            lens = np.zeros((len(self.slots),), np.int32)
            for i, s in enumerate(self.slots):
                if s.req is not None:
                    last = (
                        s.generated[-1]
                        if s.generated
                        else int(s.req.prompt[-1])
                    )
                    tokens[i] = last
                    lens[i] = s.pos
            self._ensure_cache()
            self._cache, logits = self._decode(
                self.params, self._cache, jnp.asarray(tokens[:, None]),
                jnp.asarray(lens),
            )
            self.metrics["decode_steps"] += 1
            logits_np = np.asarray(logits, np.float32)
            for i in active:
                s = self.slots[i]
                nxt = int(np.argmax(logits_np[i, : self.cfg.vocab]))
                s.generated.append(nxt)
                s.pos += 1
                self.pages.extend(s.req.req_id, s.pos)
                self.metrics["tokens"] += 1
                done = (
                    nxt == self.eos_id
                    or len(s.generated) >= s.req.max_new_tokens
                    or s.pos >= self.max_len - 1
                )
                if done:
                    req_id = s.req.req_id
                    self._finish(i)
                    served += 1
                    if response_producer is not None:
                        response_producer.send(
                            "responses",
                            {"req_id": req_id, **self.completed[req_id]},
                            metadata={"req_id": req_id},
                        )
                        response_producer.flush_topic("responses")
        if response_producer is not None:
            response_producer.close_topic("responses")
        return self.completed
