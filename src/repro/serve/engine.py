"""Continuous-batching serving engine on the proxy patterns.

Architecture = the paper's Fig 4 applied to inference:

- requests arrive on a **ProxyStream**: the admission thread consumes
  *metadata only* (request id, prompt length, max tokens) and resolves the
  bulk prompt just-in-time, overlapped with the decode loop;
- each admitted sequence's control-plane state (page list, per-page KV
  cells) is **ownership**-managed (kvcache.PageTable) — completion
  deterministically frees everything, including the store memory;
- results stream back on a response topic as **incremental token deltas**
  (metadata-only events, one per token) plus a final bulk completion
  proxy — a client sees its first token the moment the prefill admits the
  request, not a whole generation later (serve/client.ServeClient
  assembles them).

The engine loop is *notification-driven*: no sleep-poll anywhere.  A puller
thread blocks in the request consumer (broker condition wait / connector
``wait_for`` under PR 3's protocol) and hands requests over a condition
variable; the decode loop blocks on that condition only when every slot is
idle, and otherwise drains admissions between jit'd decode steps (the
decode deadline: an active batch never waits on the request stream).

Decode is a single jit'd batched step over slot-packed caches; admission
writes one slot's prefilled cache into the batch with a jit'd, donated
``dynamic_update_index_in_dim`` update — O(slot), traced once for every
slot index, instead of an op-by-op full-tree ``.at[:, i].set`` rebuild.
Admission is backpressured through PageTable reservations: a request is
admitted only when the pool can cover its *whole* generation, so decode
never OOMs mid-sequence; requests the pool can never fit are rejected onto
the response stream as errors.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.proxy import extract
from repro.core.store import Store
from repro.core.streaming import StreamConsumer, StreamProducer
from repro.dist.sharding import materialize_params, sharding_tree
from repro.models.api import build_model
from repro.models.layers import ModelContext

# How often the puller/idle waits re-check stop/exit flags.  This is NOT a
# poll interval for events — both waits are notification-driven (broker
# condition / connector wait_for) and wake immediately on traffic; the tick
# only bounds how long shutdown can lag.
_WAIT_TICK = 0.25


def serve_context(cfg, mesh=None, *, use_kernels: bool = False) -> ModelContext:
    """ModelContext with the ``serve`` rules profile applied.

    The serve profile shards the KV cache's sequence axis over the model
    axis (``kv_seq`` wins the model axis; decode is KV-bound) — the rules
    flow into both param placement and the cache shardings the engine
    applies in :meth:`ServeEngine._ensure_cache`.
    """
    from repro.launch.mesh import make_host_mesh, rules_for

    mesh = mesh if mesh is not None else make_host_mesh()
    return ModelContext(cfg, mesh, rules_for(mesh, "serve"), use_kernels)


@dataclass
class Request:
    req_id: str
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    arrived: float = field(default_factory=time.perf_counter)


@dataclass
class SlotState:
    req: Request | None = None
    pos: int = 0  # current length (prompt + generated)
    generated: list[int] = field(default_factory=list)
    first_token_at: float | None = None


class ServeEngine:
    def __init__(
        self,
        ctx: ModelContext,
        params,
        *,
        slots: int = 4,
        max_len: int = 128,
        page_size: int = 16,
        eos_id: int = 0,
        model=None,
        kv_store: Store | None = None,
    ):
        from repro.core.connectors import new_key
        from repro.serve.kvcache import PageTable

        self.ctx = ctx
        self.cfg = ctx.cfg
        self.model = model if model is not None else build_model(ctx)
        self.params = params
        self.slots = [SlotState() for _ in range(slots)]
        self.max_len = max_len
        self.eos_id = eos_id
        self._owns_store = kv_store is None
        self.kv_store = kv_store if kv_store is not None else Store(f"kv-{new_key()}")
        self.pages = PageTable(
            num_pages=slots * (max_len // page_size),
            page_size=page_size,
            store=self.kv_store,
            page_bytes=self._page_bytes(page_size),
        )
        self._cache_specs = self.model.cache_specs(len(self.slots), self.max_len)
        # serve-profile shardings for the batched cache (kv_seq over the
        # model axis); a no-op placement on the 1-device smoke mesh
        self._cache_shardings = sharding_tree(self._cache_specs, ctx.rules, ctx.mesh)
        # cache donated on the per-token hot path too: the step rewrites
        # the KV buffers in place instead of allocating a full copy per
        # token (self._cache is reassigned from the result, so the donated
        # input is never reused)
        self._decode = jax.jit(self._decode_body, donate_argnums=(1,))
        # per-slot cache insert: donated so XLA updates the batch buffers in
        # place; the slot index is traced, so one compilation covers every
        # slot instead of re-lowering per admission target
        self._admit_cache = jax.jit(self._admit_body, donate_argnums=(0,))
        self._prefill = jax.jit(
            lambda p, tokens: self.model.prefill(p, tokens, self.max_len)
        )
        self._cache = None  # stacked (L, B, S, ...) pytree
        self.completed: dict[str, dict] = {}
        self.rejected: dict[str, str] = {}
        self.metrics = {
            "prefills": 0,
            "decode_steps": 0,
            "tokens": 0,
            "loop_iters": 0,
            "idle_waits": 0,
            "queued_admissions": 0,
            "max_pending": 0,
            "malformed_events": 0,
        }

    def _page_bytes(self, page_size: int) -> int:
        """Host-side KV bytes one page represents (the PageTable cell size)."""
        from repro.dist.sharding import count_params

        per_token = count_params(self.model.cache_specs(1, 1))
        return page_size * per_token * jnp.dtype(self.cfg.dtype).itemsize

    # -- model glue ---------------------------------------------------------
    def _decode_body(self, params, cache, tokens, lens):
        """Per-slot positions: decode each slot at its own index.

        The batched decode step uses a shared scalar index in the model API;
        for continuous batching each slot has its own position, so we decode
        with per-slot gather/scatter via vmap over the batch axis.
        """

        def one(cache_b, tok_b, len_b):
            c = jax.tree.map(lambda x: x[:, None], cache_b)  # re-add batch dim
            logits, nc = self.model.decode_step(params, c, tok_b[None], len_b)
            return jax.tree.map(lambda x: x[:, 0], nc), logits[0]

        new_cache, logits = jax.vmap(
            one, in_axes=(1, 0, 0), out_axes=(1, 0)
        )(cache, tokens, lens)
        return new_cache, logits

    def _admit_body(self, cache, one, slot_idx):
        """Insert a (batch=1) prefill cache at slot ``slot_idx``: a dynamic
        per-slot update on donated buffers, never a full-tree rebuild."""
        return jax.tree.map(
            lambda full, o: jax.lax.dynamic_update_index_in_dim(
                full, o[:, 0].astype(full.dtype), slot_idx, 1
            ),
            cache,
            one,
        )

    def _ensure_cache(self):
        if self._cache is None:
            cache = materialize_params(self._cache_specs, jax.random.PRNGKey(0))
            self._cache = jax.device_put(cache, self._cache_shardings)

    # -- request admission --------------------------------------------------
    def admit(self, req: Request, slot_idx: int) -> int:
        """Prefill into ``slot_idx``; returns the request's *first* token.

        The first generated token comes from the prefill logits — it exists
        the moment the request is admitted, before any decode step (the
        decode loop's job is tokens 2..n, each fed back at its own per-slot
        position).
        """
        slot = self.slots[slot_idx]
        total = min(len(req.prompt) + req.max_new_tokens, self.max_len)
        self.pages.allocate(req.req_id, len(req.prompt), reserve_tokens=total)
        prompt = jnp.asarray(req.prompt[None], jnp.int32)
        logits, cache1 = self._prefill(self.params, prompt)
        self._ensure_cache()
        self._cache = self._admit_cache(self._cache, cache1, jnp.int32(slot_idx))
        first = int(np.argmax(np.asarray(logits[0, : self.cfg.vocab], np.float32)))
        slot.req = req
        # pos = KV entries in the cache; the first token's KV is written by
        # the decode step that consumes it
        slot.pos = len(req.prompt)
        slot.generated = [first]
        slot.first_token_at = time.perf_counter()
        self.metrics["prefills"] += 1
        self.metrics["tokens"] += 1
        return first

    def _finish(self, slot_idx: int):
        slot = self.slots[slot_idx]
        req = slot.req
        self.pages.free_sequence(req.req_id)  # ownership free → pages + store
        now = time.perf_counter()
        self.completed[req.req_id] = {
            "tokens": list(slot.generated),
            "latency": now - req.arrived,
            "ttft": (slot.first_token_at or now) - req.arrived,
        }
        slot.req = None
        slot.pos = 0
        slot.generated = []
        slot.first_token_at = None

    # -- main loop ----------------------------------------------------------
    def run(
        self,
        request_consumer: StreamConsumer,
        response_producer: StreamProducer | None = None,
        *,
        max_requests: int | None = None,
        response_topic: str = "responses",
        stream_deltas: bool = True,
        close_responses: bool = True,
    ):
        """Serve until the request stream closes (or ``max_requests`` have
        been served) and all slots drain.  Re-entrant: a later ``run`` on a
        consumer that resumes the topic continues where this one stopped
        (the engine-restart path).

        No polling: while idle the loop sleeps on a condition variable the
        puller thread notifies; while decoding it never waits on the
        request stream at all.
        """
        pending: deque[Request] = deque()
        cond = threading.Condition()
        state = {
            "open": True, "pulled": 0, "error": None, "stop": False,
            "failed": [],  # (req_id, why) from the puller → rejected here
        }

        def want_more() -> bool:
            return max_requests is None or state["pulled"] < max_requests

        # Pull-side backpressure: resolve at most this many requests ahead
        # of admission (the seed engine's slots-bounded drain, kept) — a
        # 100k-deep request topic must not materialize 100k prompt arrays.
        high_water = 2 * len(self.slots)

        def pull_loop():
            # Blocks in the consumer (broker condition wait / connector
            # wait_for); the tick only makes stop/max_requests responsive.
            while True:
                with cond:
                    while (
                        not state["stop"]
                        and state["open"]
                        and want_more()
                        and len(pending) >= high_water
                    ):
                        cond.wait(_WAIT_TICK)  # admission drains → notify
                    if state["stop"] or not (state["open"] and want_more()):
                        return
                try:
                    proxy, meta = request_consumer.next_with_metadata(
                        timeout=_WAIT_TICK
                    )
                except StopIteration:
                    with cond:
                        state["open"] = False
                        cond.notify_all()
                    return
                except TimeoutError:
                    continue
                except BaseException as e:  # stream-level failure (broker,
                    # subscriber): fatal for the run, surfaced by run() —
                    # never a silently dead puller and a hung engine
                    with cond:
                        state["error"] = e
                        state["open"] = False
                        cond.notify_all()
                    return
                if proxy is None:
                    continue  # stray meta-only event: not a request
                # Per-request failures are NOT fatal: one tenant's evicted
                # payload or missing field must not abort everyone else's
                # generation.  Addressable bad requests become rejections;
                # unaddressable events (no req_id) can only be counted.
                req_id = None
                try:
                    req_id = meta["req_id"]
                    # metadata-only dispatch: the bulk prompt resolves
                    # here, in the engine — overlapped with the decode
                    # loop, never in an intermediate scheduler
                    body = extract(proxy)
                    req = Request(
                        req_id=req_id,
                        prompt=np.asarray(body["prompt"], np.int32),
                        max_new_tokens=int(meta.get("max_new_tokens", 16)),
                    )
                except BaseException as e:
                    with cond:
                        state["pulled"] += 1
                        if req_id is None:
                            self.metrics["malformed_events"] += 1
                        else:
                            state["failed"].append(
                                (req_id, f"bad request: {e!r}")
                            )
                        cond.notify_all()
                    continue
                with cond:
                    state["pulled"] += 1
                    pending.append(req)
                    self.metrics["max_pending"] = max(
                        self.metrics["max_pending"], len(pending)
                    )
                    cond.notify_all()

        puller = threading.Thread(target=pull_loop, daemon=True)
        puller.start()

        def send_done(req_id: str):
            if response_producer is None:
                return
            entry = self.completed[req_id]
            response_producer.send(
                response_topic,
                {"req_id": req_id, **entry},
                metadata={
                    "req_id": req_id,
                    "kind": "done",
                    "n_tokens": len(entry["tokens"]),
                },
            )
            response_producer.flush_topic(response_topic)

        def send_reject(req_id: str, why: str):
            self.rejected[req_id] = why
            if response_producer is not None:
                response_producer.send_meta(
                    response_topic,
                    {"req_id": req_id, "kind": "error", "error": why},
                )

        def send_delta(req_id: str, token: int, index: int):
            if stream_deltas and response_producer is not None:
                # incremental token delta: metadata-only, no store put — the
                # client's first token beats the full completion
                response_producer.send_meta(
                    response_topic,
                    {"req_id": req_id, "kind": "delta",
                     "token": token, "index": index},
                )

        def finish_if_done(slot_idx: int) -> bool:
            s = self.slots[slot_idx]
            last = s.generated[-1]
            done = (
                last == self.eos_id
                or len(s.generated) >= s.req.max_new_tokens
                or s.pos >= self.max_len - 1
            )
            if done:
                req_id = s.req.req_id
                self._finish(slot_idx)
                send_done(req_id)
            return done

        def admit_pending() -> int:
            admitted = 0
            with cond:
                failed, state["failed"] = state["failed"], []
            for rid, why in failed:  # puller-detected per-request failures
                send_reject(rid, why)
            while True:
                target = reject = None
                with cond:
                    if not pending:
                        return admitted
                    req = pending[0]
                    total = min(len(req.prompt) + req.max_new_tokens, self.max_len)
                    if req.req_id in self.pages.live_sequences():
                        pending.popleft()  # one bad request must not crash
                        reject = (            # every other tenant's serve
                            f"req_id {req.req_id!r} is already being served"
                        )
                    elif len(req.prompt) > self.max_len - 1:
                        pending.popleft()  # prompt alone overflows the cache
                        reject = (
                            f"prompt of {len(req.prompt)} tokens exceeds "
                            f"max_len-1 ({self.max_len - 1})"
                        )
                    elif self.pages.pages_needed(total) > self.pages.num_pages:
                        pending.popleft()  # can never fit: reject, don't wedge
                        reject = (
                            f"request needs {self.pages.pages_needed(total)} "
                            f"pages; the pool has {self.pages.num_pages}"
                        )
                    elif not self.pages.can_admit(total):
                        # backpressure: head-of-line waits for pages (FIFO —
                        # later requests must not starve an earlier one)
                        self.metrics["queued_admissions"] += 1
                        return admitted
                    else:
                        free = [i for i, s in enumerate(self.slots) if s.req is None]
                        if not free:
                            return admitted
                        pending.popleft()
                        target = free[0]
                    cond.notify_all()  # wake a pull blocked at high water
                if reject is not None:
                    send_reject(req.req_id, reject)
                    continue
                first = self.admit(req, target)
                send_delta(req.req_id, first, 0)
                finish_if_done(target)  # 1-token request: done at admission
                admitted += 1

        def serve_loop():
            while True:
                self.metrics["loop_iters"] += 1
                admit_pending()
                active = [
                    i for i, s in enumerate(self.slots) if s.req is not None
                ]
                if not active:
                    with cond:
                        if state["error"] is not None:
                            raise state["error"]
                        if not pending and not state["failed"]:
                            # every pulled request is resolved once pending
                            # is empty and no slot is active
                            if not state["open"] or not want_more():
                                return
                            # notification wait: woken by the puller on
                            # arrival or close; the tick bounds shutdown,
                            # not wake-up
                            self.metrics["idle_waits"] += 1
                            cond.wait(_WAIT_TICK)
                    continue
                # batched decode step: every slot's last generated token is
                # fed back at that slot's own position (idle slots decode
                # garbage at pos 0 — their outputs are masked by never
                # being read)
                tokens = np.zeros((len(self.slots),), np.int32)
                lens = np.zeros((len(self.slots),), np.int32)
                for i in active:
                    s = self.slots[i]
                    tokens[i] = s.generated[-1]
                    lens[i] = s.pos
                self._ensure_cache()
                self._cache, logits = self._decode(
                    self.params, self._cache, jnp.asarray(tokens[:, None]),
                    jnp.asarray(lens),
                )
                self.metrics["decode_steps"] += 1
                logits_np = np.asarray(logits, np.float32)
                for i in active:
                    s = self.slots[i]
                    nxt = int(np.argmax(logits_np[i, : self.cfg.vocab]))
                    s.generated.append(nxt)
                    s.pos += 1  # the fed-back token's KV is now cached
                    self.pages.extend(s.req.req_id, s.pos)
                    self.metrics["tokens"] += 1
                    send_delta(s.req.req_id, nxt, len(s.generated) - 1)
                    finish_if_done(i)

        try:
            serve_loop()
        finally:
            # Whatever exits the loop — drain, max_requests, or an
            # exception (decode failure, a response-store error) — the
            # puller must die with this run: an orphaned puller would keep
            # stealing requests into a dead run's pending deque forever.
            with cond:
                state["stop"] = True
                cond.notify_all()
            puller.join(timeout=5 * _WAIT_TICK)
        if response_producer is not None and close_responses:
            response_producer.close_topic(response_topic)
        return self.completed

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        for seq in self.pages.live_sequences():
            self.pages.free_sequence(seq)
        if self._owns_store:  # never close a store the caller handed in
            self.kv_store.close()
